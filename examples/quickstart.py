"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_arch
from repro.core.advisor import ScalabilityAdvisor
from repro.data import synth
from repro.kernels import ops
from repro.models import model as M

key = jax.random.PRNGKey(0)

# --- 1. the paper in three lines: dataset characters -> scalability advice
ds = synth.make_realsim_like(key, n=1000, d=400, density=0.03)
report = ScalabilityAdvisor().from_dataset(ds.X, tau_max=8, batch_size=8)
print("dataset characters:", {k: round(float(report[k]), 4)
                              for k in ("sparsity", "mean_feature_variance",
                                        "diversity_ratio", "csim_async")})
print("predicted Hogwild! m_max:", report["hogwild"]["predicted_m_max"])
print("advice:", report["recommendation"])

# --- 2. any of the 10 assigned architectures, reduced for CPU
print("\narchs:", ", ".join(ARCH_IDS))
cfg = get_arch("gemma3-1b").reduced()
params = M.init_params(key, cfg)
batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
loss, aux = M.loss_fn(params, cfg, batch)
print(f"\n{cfg.name}: loss at init = {float(loss):.3f} "
      f"(ln V = {float(jnp.log(cfg.vocab_size)):.3f})")

# --- 3. one decode step against a KV cache
state = M.init_decode_state(cfg, batch=2, max_len=64)
logits, state = M.decode_step(params, cfg, batch["tokens"][:, :1], state)
print("decode_step ->", logits.shape, "position:", int(state["position"]))

# --- 4. the Pallas kernels (interpret mode on CPU, BlockSpec-tiled for TPU)
q = jax.random.normal(key, (1, 128, 4, 64))
k = jax.random.normal(key, (1, 128, 2, 64))
v = jax.random.normal(key, (1, 128, 2, 64))
out = ops.flash_attention(q, k, v, causal=True)
print("flash_attention ->", out.shape)
print("csim (paper Eq. 3) of the sparse dataset:",
      float(ops.csim(ds.X[:256], 8)))
