"""Batched serving example: mixed prompts, prefill + decode slots, throughput
report — the serve-side counterpart of the dry-run's decode shapes.

  PYTHONPATH=src python examples/serve_batch.py --arch zamba2-1.2b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_arch
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="zamba2-1.2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)

    # mixed-length prompts padded into one batch (left-padding via position)
    lens = [4 + (i * 3) % 12 for i in range(args.requests)]
    max_prompt = max(lens)
    prompts = jax.random.randint(key, (args.requests, max_prompt),
                                 0, cfg.vocab_size)
    enc = None
    if cfg.encoder_layers:
        frames = 0.1 * jax.random.normal(
            key, (args.requests, cfg.encoder_seq, cfg.d_model))
        enc = M.encode(params["encoder"], cfg, frames)

    state = M.init_decode_state(cfg, args.requests,
                                max_prompt + args.gen + 8)
    decode = jax.jit(lambda p, t, s: M.decode_step(p, cfg, t, s, enc_out=enc))

    t0 = time.time()
    logits = None
    for t in range(max_prompt):                      # prefill token-by-token
        logits, state = decode(params, prompts[:, t:t + 1], state)
    prefill_s = time.time() - t0

    t0 = time.time()
    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    outs = []
    for _ in range(args.gen):
        outs.append(tok)
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    total = args.requests * args.gen
    print(f"arch={cfg.name} batch={args.requests}")
    print(f"prefill: {max_prompt} steps in {prefill_s:.2f}s")
    print(f"decode: {total} tokens in {decode_s:.2f}s "
          f"({total / decode_s:.1f} tok/s)")
    print("first request:", jnp.concatenate(outs, 1)[0, :12].tolist())


if __name__ == "__main__":
    main()
