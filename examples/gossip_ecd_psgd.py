"""ECD-PSGD as a production exchange strategy: per-shard model replicas on
an 8-device debug mesh (4 data x 2 model), ring collective_permute of
stochastically-quantized extrapolation variables (paper Alg 4 on ICI).

  PYTHONPATH=src python examples/gossip_ecd_psgd.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.distributed import make_debug_mesh
from repro.train.steps import make_gossip_step, init_gossip_state

mesh = make_debug_mesh(data=4, model=2)
cfg = get_arch("gemma3-1b").reduced()
make, R = make_gossip_step(cfg, mesh, lr=2e-3, compress_bits=8)
key = jax.random.PRNGKey(0)
state = init_gossip_state(key, cfg, R)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
step_fn, st_specs, b_specs = make(jax.eval_shape(lambda: state), jax.eval_shape(lambda: batch))
from jax.sharding import NamedSharding
import jax.tree_util as jtu
with mesh:
    jstep = jax.jit(step_fn)
    losses = []
    for i in range(8):
        state, metrics = jstep(state, batch)
        losses.append(float(metrics["loss"]))
print("gossip losses:", [round(x,4) for x in losses])
assert losses[-1] < losses[0], "gossip should descend on a fixed batch"
# replicas should agree approximately after ring averaging rounds
p0 = jax.tree.leaves(state["params"])[3]
spread = float(jnp.max(jnp.abs(p0 - p0.mean(0, keepdims=True))))
print("replica spread:", spread, "OK")
