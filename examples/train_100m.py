"""End-to-end driver: train a ~100M-param decoder for a few hundred steps on
the synthetic HMM corpus, with the scalability advisor probing gradient
characters along the way.

  PYTHONPATH=src python examples/train_100m.py --steps 300
(defaults to 60 steps so the smoke run finishes quickly; pass --steps 300
for the full run — loss drops from ~ln(8192)=9.0 to well under 5.)
"""

import argparse

from repro.configs.base import ArchConfig
from repro.launch.train import train_loop

# ~100M params: 12L, d=768, MHA 12 heads, SwiGLU ff 2048, vocab 8192
CONFIG_100M = ArchConfig(
    name="examples-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=8192,
    rope_theta=10000.0,
    mlp_kind="swiglu",
    norm="rmsnorm",
    max_seq_len=1024,
    dtype="float32",
    source="examples",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    import jax
    n_params = sum(
        int(x.size) for x in jax.tree.leaves(
            jax.eval_shape(lambda: __import__("repro.models.model",
                                              fromlist=["init_params"])
                           .init_params(jax.random.PRNGKey(0), CONFIG_100M))))
    print(f"examples-100m: {n_params / 1e6:.1f}M params")
    train_loop(CONFIG_100M, steps=args.steps, batch_size=args.batch_size,
               seq_len=args.seq_len, lr=args.lr, log_every=10,
               advisor_every=50, ckpt=args.ckpt)


if __name__ == "__main__":
    main()
