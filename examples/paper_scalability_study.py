"""The paper, end-to-end: build the Table-I-style datasets, measure their
characters, run all four parallel algorithms across worker counts, compare
the measured scalability against the characters' predictions.

  PYTHONPATH=src python examples/paper_scalability_study.py          (quick)
  PYTHONPATH=src python examples/paper_scalability_study.py --full
"""

import argparse

import jax
import numpy as np

from repro.core import metrics as MX
from repro.core import scalability as SC
from repro.core.algorithms import (run_dadm, run_ecd_psgd, run_hogwild,
                                   run_minibatch)
from repro.data import synth


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    iters = 3000 if args.full else 800
    n = 4000 if args.full else 1500
    key = jax.random.PRNGKey(0)

    datasets = {
        "higgs_like(dense)": synth.make_higgs_like(key, n=n, d=28),
        "realsim_like(sparse)": synth.make_realsim_like(key, n=n, d=400,
                                                        density=0.05),
    }
    print("=" * 72)
    print("dataset characters (paper §IV)")
    print("=" * 72)
    for name, ds in datasets.items():
        c = MX.summarize(ds.X[:800], tau_max=8, batch_size=8)
        print(f"{name:24s} var={c['mean_feature_variance']:.3f} "
              f"sparsity={c['sparsity']:.3f} div={c['diversity_ratio']:.2f} "
              f"csim={c['csim_async']:.1f}")
        hw = SC.predict_hogwild_mmax(ds.X[:800])
        sy = SC.predict_sync_mmax(ds.X[:800])
        print(f"{'':24s} predicted m_max: hogwild={hw['predicted_m_max']} "
              f"sync={sy['predicted_m_max']}")

    print()
    print("=" * 72)
    print("measured scalability (gap between m=1 and m=8 convergence curves)")
    print("=" * 72)
    for name, ds in datasets.items():
        tr, te = ds.split(key=key)
        for algo, runner, kw in [("minibatch", run_minibatch, "batch_size"),
                                 ("hogwild", run_hogwild, "m"),
                                 ("ecd_psgd", run_ecd_psgd, "m"),
                                 ("dadm", run_dadm, "m")]:
            r1 = runner(tr, te, iters=iters, eval_every=iters // 8, **{kw: 1})
            r8 = runner(tr, te, iters=iters, eval_every=iters // 8, **{kw: 8})
            gap = float(np.mean(np.array(r1["losses"])
                                - np.array(r8["losses"])))
            print(f"{name:24s} {algo:10s} gap(m1->m8)={gap:+.4f} "
                  f"final(m8)={r8['losses'][-1]:.4f}")
    print()
    print("paper conclusion check: dense/high-variance should show the big "
          "minibatch/ecd gaps; sparse should show ~zero Hogwild! penalty.")


if __name__ == "__main__":
    main()
