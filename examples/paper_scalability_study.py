"""The paper, end-to-end: build the Table-I-style datasets, measure their
characters, run all four parallel algorithms across worker counts, compare
the measured scalability against the characters' predictions — all through
the `repro.experiments` sweep engine (spec: ``scalability_study``).

  PYTHONPATH=src python examples/paper_scalability_study.py          (quick)
  PYTHONPATH=src python examples/paper_scalability_study.py --full
"""

import argparse

import numpy as np

from repro.experiments import curves_by_m, get_spec, run_sweep

DISPLAY = {"higgs_like": "higgs_like(dense)",
           "realsim_like": "realsim_like(sparse)"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute even if the sweep artifact is cached")
    args = ap.parse_args()

    spec = get_spec("scalability_study", quick=not args.full)
    res = run_sweep(spec, force=args.force)

    print("=" * 72)
    print("dataset characters (paper §IV)")
    print("=" * 72)
    for ds_name, info in res["datasets"].items():
        name = DISPLAY[ds_name]
        c = info["characters"]
        print(f"{name:24s} var={c['mean_feature_variance']:.3f} "
              f"sparsity={c['sparsity']:.3f} div={c['diversity_ratio']:.2f} "
              f"csim={c['csim_async']:.1f}")
        hw = res["jobs"][f"hogwild/{ds_name}"]["predicted"]
        sy = res["jobs"][f"minibatch/{ds_name}"]["predicted"]
        print(f"{'':24s} predicted m_max: hogwild={hw['predicted_m_max']} "
              f"sync={sy['predicted_m_max']}")

    print()
    print("=" * 72)
    print("measured scalability (gap between m=1 and m=8 convergence curves)")
    print("=" * 72)
    for ds_name in res["datasets"]:
        name = DISPLAY[ds_name]
        for algo in ("minibatch", "hogwild", "ecd_psgd", "dadm"):
            curves = curves_by_m(res["jobs"][f"{algo}/{ds_name}"])
            gap = float(np.mean(np.array(curves[1]) - np.array(curves[8])))
            print(f"{name:24s} {algo:10s} gap(m1->m8)={gap:+.4f} "
                  f"final(m8)={curves[8][-1]:.4f}")
    print()
    print("paper conclusion check: dense/high-variance should show the big "
          "minibatch/ecd gaps; sparse should show ~zero Hogwild! penalty.")
    cache = res.get("cache", {})
    if cache.get("hit"):
        print(f"(served from sweep cache: {cache['path']})")


if __name__ == "__main__":
    main()
