"""repro.data — dataset construction.  `synth` builds the paper's Table-I
analogues offline (HIGGS-like dense, real-sim-like sparse, LS-controlled
sampling sequences, diversity-duplication variants, the §VII.E upper-bound
set) with the ruler labeling rule; `lm` streams HMM token data with
measurable characters for the language-model tier.  Sweep specs reference
these generators by name via `repro.experiments.spec.GENERATORS`.
"""
