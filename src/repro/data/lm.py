"""Token-stream pipeline for the LM examples: a synthetic corpus with
learnable n-gram structure (so a few hundred steps show a real loss drop),
sharding-aware batching, and the paper's dataset-character probes applied to
token space.

The generator is a tiny deterministic HMM over the vocab: hidden state walks
a ring; emissions are state-local vocab bands — giving non-trivial bigram
statistics a 100M-param model can chew on.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LMConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    n_states: int = 64
    band: int = 32            # emissions per hidden state


def hmm_stream(key, cfg: LMConfig, steps: int):
    """Yields ``steps`` batches of {tokens, labels} (host-side numpy)."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    trans_jump = rng.integers(1, 7, size=cfg.n_states)
    for _ in range(steps):
        B, S = cfg.batch_size, cfg.seq_len
        state = rng.integers(0, cfg.n_states, size=B)
        toks = np.zeros((B, S + 1), np.int32)
        for t in range(S + 1):
            base = (state * cfg.band) % max(cfg.vocab_size - cfg.band, 1)
            toks[:, t] = base + rng.integers(0, cfg.band, size=B)
            state = (state + trans_jump[state]) % cfg.n_states
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}


def token_characters(tokens, *, window=8):
    """Paper indices in token space: one-hot sparsity is 1 - 1/V by
    construction, so the informative characters are diversity (distinct
    sequences) and the windowed similarity of consecutive sequences."""
    t = np.asarray(tokens)
    B = t.shape[0]
    uniq = len({t[i].tobytes() for i in range(B)})
    # consecutive-sequence hamming distance (token-level L0), windowed
    dists = []
    for j in range(1, min(window, B)):
        dists.append((t != np.roll(t, -j, axis=0)).mean())
    return {"sequence_diversity": uniq / B,
            "token_csim": float(np.mean(dists)) if dists else 0.0}
