"""Synthetic datasets matching the paper's Table I constructions (offline
container: the real real-sim / HIGGS downloads are reproduced as scaled
generators with the same *characters* — sparsity, feature range, density).

Generators register declaratively via :func:`register_generator` — the
registry (:data:`GENERATORS`) is what `repro.experiments` specs reference
by name, and registered source is hashed into spec fingerprints, so
editing a generator invalidates exactly the cached sweeps that used it.
A new dataset scenario is one decorated function; no engine edits.

Labels everywhere follow the paper: label_i = sign(xi_i . ruler),
ruler = (-1, 2, -3, 4, ..., (-1)^d * d).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

#: name -> generator ``fn(key, **kwargs) -> Dataset``.  Live registry;
#: latest registration wins.
GENERATORS: Dict[str, Callable] = {}


def register_generator(name: str):
    """Decorator: register a dataset generator under a spec-facing name."""
    def deco(fn):
        GENERATORS[name] = fn
        return fn
    return deco


def get_generator(name: str) -> Callable:
    try:
        return GENERATORS[name]
    except KeyError:
        raise KeyError(f"unknown generator {name!r}; "
                       f"known: {sorted(GENERATORS)}") from None


def ruler(d):
    r = jnp.arange(1, d + 1, dtype=jnp.float32)
    return r * ((-1.0) ** r)


def label_with_ruler(X):
    y = jnp.sign(X @ ruler(X.shape[1]))
    return jnp.where(y == 0, 1.0, y)


@dataclasses.dataclass
class Dataset:
    X: jax.Array                 # (n, d)
    y: jax.Array                 # (n,) in {-1, +1}
    name: str = ""

    def split(self, train_frac=0.7, valid_frac=0.2, key=None,
              with_test=False):
        """Paper §VII.A fractions: 70% train / 20% valid / 10% held-out
        test.

        ``key=None`` deliberately keeps the row order (NO shuffle) — the
        LS-sequence experiments depend on it, because the sampling order
        *is* the dataset character under study.  Pass a PRNGKey to
        shuffle.  The remaining ``1 - train_frac - valid_frac`` tail is
        the held-out test slice: returned as a third dataset when
        ``with_test=True`` (it may be empty if the fractions sum to 1),
        never silently re-used for training.
        """
        if not (0.0 < train_frac <= 1.0 and 0.0 <= valid_frac <= 1.0
                and train_frac + valid_frac <= 1.0 + 1e-9):
            raise ValueError(
                f"bad split fractions: train={train_frac} valid={valid_frac}"
                f" (need 0 < train, 0 <= valid, train + valid <= 1)")
        n = self.X.shape[0]
        idx = (jax.random.permutation(key, n) if key is not None
               else jnp.arange(n))
        ntr = int(n * train_frac)
        nva = int(n * valid_frac)
        tr = Dataset(self.X[idx[:ntr]], self.y[idx[:ntr]], self.name + ":train")
        va = Dataset(self.X[idx[ntr:ntr + nva]], self.y[idx[ntr:ntr + nva]],
                     self.name + ":valid")
        if not with_test:
            return tr, va
        te = Dataset(self.X[idx[ntr + nva:]], self.y[idx[ntr + nva:]],
                     self.name + ":test")
        return tr, va, te


@register_generator("realsim_like")
def make_realsim_like(key, n=8000, d=2000, density=0.03, lo=0.0, hi=1.0):
    """Sparse, small-feature-variance dataset (real-sim analogue, scaled to
    the container: 20958 features / 72309 rows in the paper)."""
    k1, k2 = jax.random.split(key)
    mask = jax.random.bernoulli(k1, density, (n, d))
    vals = jax.random.uniform(k2, (n, d), minval=lo, maxval=hi)
    X = jnp.where(mask, vals, 0.0)
    return Dataset(X, label_with_ruler(X), "realsim_like")


@register_generator("higgs_like")
def make_higgs_like(key, n=8000, d=28, lo=-4.0, hi=3.0):
    """Dense, large-feature-variance dataset (HIGGS analogue)."""
    X = jax.random.uniform(key, (n, d), minval=lo, maxval=hi)
    return Dataset(X, label_with_ruler(X), "higgs_like")


@register_generator("ls_sequence")
def make_ls_sequence(key, n=8000, d=28, mutate_frac=0.1, density=1.0,
                     lo=-4.0, hi=3.0, first_sample=None):
    """LS-controlled sampling sequence (§VII.A): sample t is sample t-1 with
    ``mutate_frac`` of features re-drawn; small frac => small C_sim (similar
    neighbors => LOW local distance), large frac => large C_sim.

    For density < 1 the mutated sample is re-sparsified to the density of the
    first sample (paper's sparse LS variants).
    """
    keys = jax.random.split(key, 4)
    if first_sample is None:
        first_sample = jax.random.uniform(keys[0], (d,), minval=lo, maxval=hi)
        if density < 1.0:
            m0 = jax.random.bernoulli(keys[1], density, (d,))
            first_sample = jnp.where(m0, first_sample, 0.0)

    n_mut = max(1, int(mutate_frac * d))

    def step(x, k):
        k1, k2, k3 = jax.random.split(k, 3)
        idx = jax.random.choice(k1, d, (n_mut,), replace=False)
        newv = jax.random.uniform(k2, (n_mut,), minval=lo, maxval=hi)
        x_new = x.at[idx].set(newv)
        if density < 1.0:
            keep = jax.random.bernoulli(k3, density, (d,))
            x_new = jnp.where(keep, x_new, 0.0)
        return x_new, x_new

    _, X = jax.lax.scan(step, first_sample, jax.random.split(keys[2], n))
    return Dataset(X, label_with_ruler(X), f"ls_seq_mut{mutate_frac}")


def make_diversity_variants(base: Dataset):
    """real_sim / real_sim2 / real_sim4 duplication construction (§VII.A):
    cut into 4 equal parts; middle = {p1,p1,p2,p2}; low = {p1,p1,p1,p1}."""
    n = (base.X.shape[0] // 4) * 4
    X, y = base.X[:n], base.y[:n]
    q = n // 4
    p = [(X[i * q:(i + 1) * q], y[i * q:(i + 1) * q]) for i in range(4)]
    high = Dataset(X, y, base.name + ":div_high")
    mid = Dataset(jnp.concatenate([p[0][0], p[0][0], p[1][0], p[1][0]]),
                  jnp.concatenate([p[0][1], p[0][1], p[1][1], p[1][1]]),
                  base.name + ":div_mid")
    low = Dataset(jnp.concatenate([p[0][0]] * 4),
                  jnp.concatenate([p[0][1]] * 4),
                  base.name + ":div_low")
    return high, mid, low


@register_generator("upper_bound")
def make_upper_bound_dataset(key, n=6000, d=400, density=0.7, lo=0.0, hi=1.0):
    """§VII.E: 70%-density simulated dataset whose Hogwild! upper bound is
    reachable with few workers."""
    k1, k2 = jax.random.split(key)
    mask = jax.random.bernoulli(k1, density, (n, d))
    vals = jax.random.uniform(k2, (n, d), minval=lo, maxval=hi)
    X = jnp.where(mask, vals, 0.0)
    return Dataset(X, label_with_ruler(X), "upper_bound_sim")


@register_generator("one_sample")
def make_one_sample_dataset(key, n=1024, d=64):
    """Example 12: dataset = one sample duplicated n times (diversity 1)."""
    x = jax.random.uniform(key, (d,))
    X = jnp.tile(x[None], (n, 1))
    return Dataset(X, label_with_ruler(X), "one_sample")


@register_generator("label_noise")
def make_label_noise(key, base="higgs_like", flip_frac=0.2, **base_kwargs):
    """Label-noise variant of any registered base generator: ruler labels
    with a ``flip_frac`` fraction flipped uniformly at random.  The feature
    characters (variance, sparsity, diversity, LS) are untouched — only the
    gradient *variance* at the optimum grows, isolating the paper's
    variance-drives-parallel-gain claim from the feature geometry."""
    kb, kf = jax.random.split(key)
    ds = get_generator(base)(kb, **base_kwargs)
    flip = jax.random.bernoulli(kf, flip_frac, ds.y.shape)
    return Dataset(ds.X, jnp.where(flip, -ds.y, ds.y),
                   f"{ds.name}:noise{flip_frac}")


@register_generator("character_knob")
def make_character_knob(key, n=1024, d=64, variance=1.0, density=1.0,
                        duplication=0.0):
    """Continuous §IV character surface: one generator, three independent
    knobs, each mapped to one paper character.

      ``variance``     target per-feature variance *as measured* — features
                       are uniform on a zero-centered interval whose span
                       compensates the density mask (masking a zero-mean
                       variable scales its variance by the density, so the
                       span is sqrt(12 var / density)); the knobs stay
                       independent instead of variance collapsing onto the
                       sparsity axis
      ``density``      nonzero fraction (sparsity = 1 - density)
      ``duplication``  fraction of rows replaced by copies of the retained
                       head (diversity_ratio ~ 1 - duplication); sweeps
                       measuring characters must look at ALL rows
                       (``characters_rows=n``), as the unique head alone
                       reads as full diversity

    The `character_surface` spec sweeps these knobs over a grid and maps
    the measured/fitted m_max surface — the paper's "dataset characters
    decide scalability" thesis as a fitted, testable model
    (`repro.analysis.fit.characters_regression`).
    """
    if not (0.0 <= duplication < 1.0):
        raise ValueError(f"duplication={duplication} must be in [0, 1)")
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density={density} must be in (0, 1]")
    k1, k2 = jax.random.split(key)
    half_span = 0.5 * (12.0 * variance / density) ** 0.5
    X = jax.random.uniform(k1, (n, d), minval=-half_span, maxval=half_span)
    if density < 1.0:
        X = jnp.where(jax.random.bernoulli(k2, density, (n, d)), X, 0.0)
    n_unique = max(1, int(round(n * (1.0 - duplication))))
    if n_unique < n:
        X = X[jnp.arange(n) % n_unique]       # tile the retained head
    return Dataset(X, label_with_ruler(X),
                   f"character_knob_v{variance}_p{density}_dup{duplication}")


@register_generator("heavy_tailed")
def make_heavy_tailed(key, n=8000, d=28, df=3.0, scale=1.0):
    """Heavy-tailed feature-variance dataset: Student-t features with ``df``
    degrees of freedom (df <= 4 has infinite kurtosis, df <= 2 infinite
    variance), dense like higgs_like but with rare huge-magnitude samples —
    the adversarial regime for the variance-based sync predictors, where
    the *mean* feature variance under-states per-sample gradient spread."""
    X = jax.random.t(key, df, (n, d)) * scale
    return Dataset(X, label_with_ruler(X), f"heavy_tailed_t{df}")
