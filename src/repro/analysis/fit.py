"""Scaling-law fits: the paper's theorems as fitted, testable models.

Thm 2 gives Hogwild!'s per-worker training cost the shape

    t/m = (1/m + a + b m) * c        i.e.   cost(m) = A/m + B + C m

with A = c, B = a c, C = b c — a 1/m serial term, a constant, and a
linearly growing coordination term; Thm 3/4 give the synchronous
algorithms the same qualitative U-shape through the variance-driven
sqrt(m) gain.  :func:`fit_cost_curve` least-squares fits that law to a
*measured* cost curve, so the scalability upper bound stops being a
single crossing read off one noisy curve and becomes a parameter of a
fitted model with a bootstrap CI (:func:`fit_job`), comparable to the
theory-side prediction on equal terms.

:func:`characters_regression` is the paper's thesis itself as a model:
across sweep cells (e.g. the `character_surface` spec's knob grid) it
regresses log2(m_max) on the measured §IV characters — variance,
sparsity, diversity — and reports coefficients and R^2: "dataset
characters decide scalability" as a number, not a slogan.

The module also hosts the **vectorized theory-side m_max predictors**
(:func:`sync_mmax`, :func:`dadm_mmax`, :func:`hogwild_mmax` and their
dataset-level `predict_*` wrappers).  They replace the `while m < 4096`
Python loops in `repro.core.scalability` — which stay as the scalar
oracles the parity tests in `tests/test_analysis.py` pin against — and
are what `repro.core.advisor.ScalabilityAdvisor` and
`repro.experiments.runner` consume.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.analysis import stats
from repro.core import metrics as MX

#: predictor search cap, matching the scalar oracles in core.scalability
M_CAP = 4096


# ---------------------------------------------------------------------------
# vectorized theory-side predictors (scalar oracles: core.scalability)
# ---------------------------------------------------------------------------

def sync_mmax(sigma: float, parallel_cost: float = 1e-3,
              m_cap: int = M_CAP) -> int:
    """First m where the Thm-3 gain growth sigma (1/sqrt(m) - 1/sqrt(m+1))
    can no longer cover the parallel cost — the vectorized form of the
    `predict_sync_mmax` while-loop (same answer for every input)."""
    ms = np.arange(1, m_cap, dtype=float)
    stop = sigma * (1.0 / np.sqrt(ms) - 1.0 / np.sqrt(ms + 1.0)) \
        <= parallel_cost
    return int(ms[stop.argmax()]) if stop.any() else m_cap


def dadm_mmax(diversity_ratio: float, parallel_cost: float = 1e-3,
              m_cap: int = M_CAP) -> int:
    """First m where the diversity-limited 1/m gain growth falls below the
    parallel cost (vectorized `predict_dadm_mmax` search)."""
    ms = np.arange(1, m_cap, dtype=float)
    stop = diversity_ratio * (1.0 / ms - 1.0 / (ms + 1.0)) <= parallel_cost
    return int(ms[stop.argmax()]) if stop.any() else m_cap


def hogwild_mmax(omega_frac: float, delta: float, rho: float,
                 m_cap: int = M_CAP) -> int:
    """Largest m whose Thm-2 cost still beats the 1-worker cost, scanning
    contiguously from m=2 (vectorized form of the `predict_hogwild_mmax`
    for/break loop: the first non-improving m stops the scan)."""
    ms = np.arange(2, m_cap + 1, dtype=float)
    cost = 1.0 / ms + 6.0 * rho + 6.0 * ms * omega_frac * math.sqrt(delta)
    c1 = 1.0 + 6.0 * rho + 6.0 * omega_frac * math.sqrt(delta)
    fails = cost >= c1
    if not fails.any():
        return m_cap
    return int(fails.argmax()) + 1          # m before the first failure


def momentum_mmax(sigma: float, beta: float = 0.9,
                  parallel_cost: float = 1e-3, m_cap: int = M_CAP) -> int:
    """Critical batch size under heavy-ball momentum: the buffer already
    geometrically averages ~1/(1-beta) past gradients, consuming part of
    the noise budget batch parallelism would otherwise spend, so the
    Thm-3 gain growth runs on an effective sigma sqrt(1-beta) and the
    cliff moves DOWN with beta (beta=0 recovers :func:`sync_mmax`)."""
    return sync_mmax(sigma * math.sqrt(max(1.0 - beta, 0.0)),
                     parallel_cost, m_cap)


def local_sgd_mmax(sigma: float, sync_every: int = 4,
                   parallel_cost: float = 1e-3, m_cap: int = M_CAP) -> int:
    """Critical worker count under a local-update window: communication is
    paid once per ``sync_every`` local steps, so the per-iteration parallel
    cost divides by the window and the cliff moves UP with it
    (sync_every=1 recovers :func:`sync_mmax`)."""
    return sync_mmax(sigma, parallel_cost / max(int(sync_every), 1), m_cap)


def svrg_mmax(omega_frac: float, delta: float, rho: float,
              theta: float = 0.5, m_cap: int = M_CAP) -> int:
    """Critical staleness under semi-stochastic gradients: near the anchor
    the two point-gradient terms cancel, damping the Thm-2 coordination
    term 6 m omega sqrt(delta) by a variance-reduction factor
    theta in (0, 1] (theta=1 recovers :func:`hogwild_mmax`; theta -> 0 is
    the full-gradient limit with unbounded staleness tolerance)."""
    return hogwild_mmax(omega_frac * min(max(theta, 0.0), 1.0), delta, rho,
                        m_cap)


def predict_sync_from_characters(ch: Dict, *, parallel_cost: float = 1e-3,
                                 m_cap: int = M_CAP) -> Dict:
    """Sync predictor from an already-measured characters dict (the
    batched-service path: `repro.service.tiers` feeds the masked-batch
    characters here, so N probes never re-touch the raw data).  The
    X-level :func:`predict_sync_mmax` delegates here — one formula, two
    entry points, identical answers by construction."""
    sigma = math.sqrt(max(ch["mean_feature_variance"], 1e-12))
    return {"sigma_proxy": sigma, "parallel_cost": parallel_cost,
            "predicted_m_max": sync_mmax(sigma, parallel_cost, m_cap)}


def predict_sync_mmax(X, *, parallel_cost: float = 1e-3,
                      m_cap: int = M_CAP) -> Dict:
    """Dataset-level sync predictor (vectorized `core.scalability` twin —
    identical payload, no Python m-loop)."""
    return predict_sync_from_characters(
        {"mean_feature_variance": MX.mean_feature_variance(X)},
        parallel_cost=parallel_cost, m_cap=m_cap)


def predict_dadm_from_characters(ch: Dict, *, parallel_cost: float = 1e-3,
                                 m_cap: int = M_CAP) -> Dict:
    div = ch["diversity_ratio"]
    return {"diversity_ratio": div, "parallel_cost": parallel_cost,
            "predicted_m_max": dadm_mmax(div, parallel_cost, m_cap)}


def predict_dadm_mmax(X, *, parallel_cost: float = 1e-3,
                      m_cap: int = M_CAP) -> Dict:
    return predict_dadm_from_characters(
        {"diversity_ratio": MX.diversity_ratio(X)},
        parallel_cost=parallel_cost, m_cap=m_cap)


def predict_hogwild_from_characters(ch: Dict, *, m_cap: int = M_CAP) -> Dict:
    hw = {k: ch[k] for k in ("omega", "omega_frac", "delta", "rho")}
    omega_term = hw["omega_frac"] * math.sqrt(hw["delta"])
    m_star = 1.0 / math.sqrt(6.0 * omega_term) if omega_term > 0 else m_cap
    return {**hw, "omega_delta_term": omega_term, "m_star": m_star,
            "predicted_m_max": hogwild_mmax(hw["omega_frac"], hw["delta"],
                                            hw["rho"], m_cap)}


def predict_hogwild_mmax(X, *, m_cap: int = M_CAP) -> Dict:
    return predict_hogwild_from_characters(MX.hogwild_params(X), m_cap=m_cap)


def predict_momentum_from_characters(ch: Dict, *, beta: float = 0.9,
                                     parallel_cost: float = 1e-3,
                                     m_cap: int = M_CAP) -> Dict:
    sigma = math.sqrt(max(ch["mean_feature_variance"], 1e-12))
    return {"sigma_proxy": sigma, "beta": beta,
            "parallel_cost": parallel_cost,
            "predicted_m_max": momentum_mmax(sigma, beta, parallel_cost,
                                             m_cap)}


def predict_momentum_mmax(X, *, beta: float = 0.9,
                          parallel_cost: float = 1e-3,
                          m_cap: int = M_CAP) -> Dict:
    """Dataset-level critical batch size for momentum mini-batch SGD; the
    job's ``beta`` reaches here via the runner's predictor-kwargs pass."""
    return predict_momentum_from_characters(
        {"mean_feature_variance": MX.mean_feature_variance(X)},
        beta=beta, parallel_cost=parallel_cost, m_cap=m_cap)


def predict_local_sgd_from_characters(ch: Dict, *, sync_every: int = 4,
                                      parallel_cost: float = 1e-3,
                                      m_cap: int = M_CAP) -> Dict:
    sigma = math.sqrt(max(ch["mean_feature_variance"], 1e-12))
    return {"sigma_proxy": sigma, "sync_every": int(sync_every),
            "parallel_cost": parallel_cost,
            "predicted_m_max": local_sgd_mmax(sigma, sync_every,
                                              parallel_cost, m_cap)}


def predict_local_sgd_mmax(X, *, sync_every: int = 4,
                           parallel_cost: float = 1e-3,
                           m_cap: int = M_CAP) -> Dict:
    """Dataset-level critical worker count for local SGD at a given sync
    window (the window amortizes the communication cost)."""
    return predict_local_sgd_from_characters(
        {"mean_feature_variance": MX.mean_feature_variance(X)},
        sync_every=sync_every, parallel_cost=parallel_cost, m_cap=m_cap)


def predict_svrg_from_characters(ch: Dict, *, anchor_every: int = 100,
                                 m_cap: int = M_CAP) -> Dict:
    """Needs the Thm-2 params plus ``n`` (the epoch length that sets the
    variance-reduction factor theta = H / (H + n))."""
    hw = {k: ch[k] for k in ("omega", "omega_frac", "delta", "rho")}
    theta = anchor_every / (anchor_every + ch["n"])
    return {**hw, "anchor_every": int(anchor_every), "theta": theta,
            "predicted_m_max": svrg_mmax(hw["omega_frac"], hw["delta"],
                                         hw["rho"], theta, m_cap)}


def predict_svrg_mmax(X, *, anchor_every: int = 100,
                      m_cap: int = M_CAP) -> Dict:
    """Dataset-level critical staleness for async-SVRG.  The variance-
    reduction factor interpolates with the anchor period H relative to the
    epoch length n: theta = H / (H + n) — a fresh anchor every step
    (H -> 0) is the full-gradient limit, a never-refreshed anchor
    (H -> inf) degenerates to raw Hogwild!."""
    return predict_svrg_from_characters(
        {**MX.hogwild_params(X), "n": X.shape[0]},
        anchor_every=anchor_every, m_cap=m_cap)


#: characters-dict predictor per kind — what `repro.service.tiers` and any
#: other batched-characters consumer dispatches through (the X-level
#: ``predict_*_mmax`` wrappers above delegate to these, so both entry
#: points give identical answers for identical characters)
PREDICTORS_FROM_CHARACTERS = {
    "sync": predict_sync_from_characters,
    "dadm": predict_dadm_from_characters,
    "hogwild": predict_hogwild_from_characters,
    "momentum": predict_momentum_from_characters,
    "local_sgd": predict_local_sgd_from_characters,
    "svrg": predict_svrg_from_characters,
}


# ---------------------------------------------------------------------------
# measured-cost-curve fits (Thm 2 / Thm 3 shape)
# ---------------------------------------------------------------------------

def _law_mmax(A: float, B: float, C: float, m_cap: int = M_CAP) -> int:
    """Largest m whose fitted cost A/m + B + C m still beats the 1-worker
    cost, same contiguous-scan semantics as the theory-side predictors.
    A non-positive coordination term C means the fitted law never turns
    up within the cap."""
    ms = np.arange(2, m_cap + 1, dtype=float)
    fails = A / ms + B + C * ms >= A + B + C
    if not fails.any():
        return m_cap
    return int(fails.argmax()) + 1


def fit_cost_curve(ms: Sequence[int], costs: Sequence[float], *,
                   m_cap: int = M_CAP) -> Dict:
    """Least-squares fit of cost(m) = A/m + B + C m to a measured curve.

    Returns the raw coefficients, the paper's (a, b, c) parameterization
    of ``t/m = (1/m + a + b m) c`` (c = A, a = B/A, b = C/A), the analytic
    interior minimum ``m_star = sqrt(A/C)``, the integer ``fitted_m_max``
    (largest m still beating the 1-worker fitted cost, scanned like the
    theory predictors), the fitted curve, and R^2.
    """
    ms_arr = np.asarray(ms, dtype=float)
    y = np.asarray(costs, dtype=float)
    F = np.stack([1.0 / ms_arr, np.ones_like(ms_arr), ms_arr], axis=1)
    coef, *_ = np.linalg.lstsq(F, y, rcond=None)
    A, B, C = (float(v) for v in coef)
    pred = F @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    m_star = math.sqrt(A / C) if A > 0 and C > 0 else math.inf
    return {"A": A, "B": B, "C": C,
            "c": A, "a": B / A if A else math.nan,
            "b": C / A if A else math.nan,
            "m_star": m_star, "fitted_m_max": _law_mmax(A, B, C, m_cap),
            "r2": r2, "fitted": pred.tolist()}


def fit_job(job: Dict, *, probe_m: int, frac: float,
            asynchronous: Optional[bool] = None, m_cap: int = M_CAP,
            ci: float = stats.CI, n_boot: int = stats.N_BOOT,
            rng_seed: int = 0) -> Dict:
    """Fit the cost law to a job's seed-mean cost curve, with a bootstrap
    CI over ``fitted_m_max`` (resample seeds, re-average, refit)."""
    costs = stats.cost_samples(job, asynchronous=asynchronous,
                               probe_m=probe_m, frac=frac)   # (seeds, S)
    ms = [int(m) for m in job["ms"]]
    out = fit_cost_curve(ms, costs.mean(axis=0), m_cap=m_cap)
    n_seeds = costs.shape[0]
    if n_seeds > 1:
        idx = stats._resample(np.random.default_rng(rng_seed), n_seeds,
                              n_boot)
        samples = np.array([
            fit_cost_curve(ms, costs[i].mean(axis=0),
                           m_cap=m_cap)["fitted_m_max"] for i in idx])
    else:
        samples = np.array([out["fitted_m_max"]])
    lo, hi = stats._ci_bounds(samples, ci)
    out.update(fitted_m_max_lo=int(lo), fitted_m_max_hi=int(hi),
               fitted_m_max_median=int(np.median(samples)),
               ci=ci, n_seeds=n_seeds)
    return out


# ---------------------------------------------------------------------------
# characters -> m_max regression (the thesis as a fitted model)
# ---------------------------------------------------------------------------

#: character keys regressed on (order fixes the coefficient layout)
REGRESSION_FEATURES = ("log10_variance", "sparsity", "diversity_ratio")


def collect_character_points(results: Iterable[Dict]) -> List[Dict]:
    """Harvest (characters, m_max) points from `run_sweep` results — every
    *healthy* job with a cost readout contributes one point, using the
    bootstrap point estimate when the job carries seed replicates and the
    scalar seed-0 bound otherwise.  Diverged/failed jobs (the runner's
    ``status`` field) are excluded — one NaN curve must not bend the
    regression for its healthy neighbors."""
    points = []
    for result in results:
        eps = (result.get("spec") or {}).get("epsilon") or {}
        for key, jr in result.get("jobs", {}).items():
            status = str(jr.get("status", "ok"))
            if not (status == "ok" or status.startswith("retried")):
                continue
            if "measured_m_max" not in jr:
                continue
            ch = result["datasets"][jr["dataset"]].get("characters")
            if not ch:
                continue
            m_max = jr["measured_m_max"]
            if jr.get("n_seeds", 1) > 1:
                m_max = stats.mmax_bootstrap(
                    jr, probe_m=eps.get("probe_m", jr["ms"][0]),
                    frac=eps.get("frac", 0.7))["m_max"]
            points.append({"sweep": result.get("name", "?"), "job": key,
                           "characters": ch, "m_max": int(m_max),
                           "predicted_m_max": (jr.get("predicted") or {})
                           .get("predicted_m_max")})
    return points


def characters_regression(points: Sequence[Dict]) -> Optional[Dict]:
    """Linear regression log2(m_max) ~ 1 + log10(variance) + sparsity +
    diversity_ratio across sweep cells.  Needs more points than
    coefficients; returns None otherwise.  The paper's claim says variance
    should push the bound up for the sync algorithms and duplication pull
    it down — here those are fitted signs with an R^2, testable."""
    if len(points) < len(REGRESSION_FEATURES) + 2:
        return None
    rows, y = [], []
    for p in points:
        ch = p["characters"]
        rows.append([1.0,
                     math.log10(max(ch["mean_feature_variance"], 1e-12)),
                     ch["sparsity"], ch["diversity_ratio"]])
        y.append(math.log2(max(p["m_max"], 1)))
    X = np.asarray(rows)
    y = np.asarray(y)
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    pred = X @ coef
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    return {"n_points": len(points),
            "coef": {name: float(c) for name, c in
                     zip(("intercept",) + REGRESSION_FEATURES, coef)},
            "r2": 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0,
            "predicted_log2_mmax": pred.tolist(),
            # residual scale + fitted-cloud envelope, the inputs of
            # `analytic_confidence` (log2 units: rmse 1 = a factor-2
            # miss on m_max)
            "residual_rmse": math.sqrt(ss_res / len(points)),
            "feature_mean": {name: float(X[:, i + 1].mean()) for i, name
                             in enumerate(REGRESSION_FEATURES)},
            "feature_std": {name: float(X[:, i + 1].std()) for i, name
                            in enumerate(REGRESSION_FEATURES)}}


# ---------------------------------------------------------------------------
# analytic-tier confidence (the service's early-exit gate)
# ---------------------------------------------------------------------------

#: confidence assigned to an analytic answer when no characters->m_max
#: regression history exists yet — the theory predictors are the only
#: evidence, so this is a prior, not a measurement (`repro.service`
#: escalates below its threshold; the default threshold sits under this
#: prior, so a fresh service trusts the theory until history says not to)
CONFIDENCE_PRIOR = 0.75


def _regression_features(ch: Dict) -> Dict[str, float]:
    return {"log10_variance":
            math.log10(max(ch["mean_feature_variance"], 1e-12)),
            "sparsity": ch["sparsity"],
            "diversity_ratio": ch["diversity_ratio"]}


def analytic_confidence(model: Optional[Dict], ch: Dict) -> Dict:
    """How much to trust an *analytic* (predictor-only) answer for a
    dataset with characters ``ch``, derived from the characters->m_max
    regression residuals (:func:`characters_regression` over the measured
    sweeps already in the artifact cache):

      confidence = clip(R^2, 0, 1) * exp(-residual_rmse)
                   * exp(-max(z - 2, 0) / 2)

    — the regression's explanatory power, discounted by its residual
    scale (rmse in log2(m_max): a 1-bit typical miss costs e^-1) and by
    extrapolation (z = the character point's largest |z-score| against
    the fitted cloud; inside 2 sigma is free, beyond decays).  With no
    model (an empty cache) the answer is the :data:`CONFIDENCE_PRIOR`.
    Deterministic and unit-tested — the service's tier gate, not a
    calibrated probability."""
    if model is None:
        return {"confidence": CONFIDENCE_PRIOR, "source": "prior",
                "detail": "no measured characters->m_max history yet"}
    feats = _regression_features(ch)
    z = 0.0
    for name, v in feats.items():
        std = model["feature_std"].get(name, 0.0)
        mean = model["feature_mean"].get(name, 0.0)
        if std <= 1e-9:
            z = max(z, 0.0 if abs(v - mean) <= 1e-9 else math.inf)
        else:
            z = max(z, abs(v - mean) / std)
    r2 = min(max(model["r2"], 0.0), 1.0)
    rmse = model["residual_rmse"]
    conf = r2 * math.exp(-rmse) * math.exp(-max(z - 2.0, 0.0) / 2.0)
    coef = model["coef"]
    log2_mmax = coef["intercept"] + sum(
        coef[name] * v for name, v in feats.items())
    return {"confidence": float(conf), "source": "regression",
            "r2": r2, "residual_rmse": rmse, "extrapolation_z": float(z),
            "n_points": model["n_points"],
            "regression_log2_mmax": float(log2_mmax)}
