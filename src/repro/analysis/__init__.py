"""repro.analysis — seed-replicated statistics, scaling-law fits, and the
paper-report subsystem.

The paper's headline claims are statistical — "performance reproducibility
of parallel ML training is limited", "dataset characters decide
scalability", "there is an upper bound m_max" — but a single-seed sweep
reports point estimates, so a measured m_max is one noisy draw.  This
package turns raw sweep curves (now seed-replicated via
``SweepSpec.n_seeds``, ENGINE_VERSION 4) into statistically defensible
artifacts:

  `stats`   per-(job, m) mean/std/bootstrap-CI loss curves, seed-replicated
            per-worker costs, and a bootstrap distribution over the
            measured m_max — the vectorized superset of the scalar §V
            helpers in `repro.core.scalability` (which stay as thin
            single-curve oracles)
  `fit`     least-squares fits of the Thm-2/Thm-3 cost laws
            ``t/m = (1/m + a + b m) c`` with fitted-vs-predicted m_max and
            bootstrap CIs, the characters -> m_max regression across
            sweeps, and the vectorized theory-side m_max predictors the
            advisor and runner consume
  `report`  ``python -m repro.analysis.report`` — renders a markdown
            report (bootstrap-CI Table II, fitted-vs-predicted m_max,
            character-surface regression, ASCII/SVG curves) from the
            sweep cache or a fresh run

`report` imports `repro.experiments` and is therefore *not* imported
here — `repro.experiments.runner` and `repro.core.advisor` import
`stats`/`fit` without a cycle.
"""

from repro.analysis import fit, stats

__all__ = ["fit", "stats"]
