"""Bench-trajectory analysis: every ``BENCH_N.json`` as one time series.

Each PR lands a ``BENCH_N.json`` anchor (`scripts/bench_engine.py`) with
a point-in-time ``vs_benchM`` comparison against the previous anchor.
Those pairwise blocks answer "did THIS PR regress", but nobody was
reading the *trajectory* — nine anchors deep, a slow 10%-per-PR drift
would pass every pairwise gate and still double the engine's wall-clock.
This module turns the anchors into one series and gates on it:

  * :func:`load_trajectory` parses every ``BENCH_N.json`` in a directory
    (sorted by N) into flat per-anchor points — engine/pr1/vmap
    wall-clocks, cache roundtrip, telemetry on/off tax — tolerating the
    early anchors that predate a section (BENCH_2..8 have no
    ``telemetry`` block; missing values are ``None``).
  * :func:`check_regression` applies the trajectory gates: the newest
    anchor's ``engine_default`` within ``band``x of the previous
    anchor's, and the telemetry-enabled tax (``trace_on / trace_off``)
    within ``band`` — both against the *last anchor that has the
    number*, not blindly N-1.  ``band`` defaults to 2.0: these anchors
    are measured on a shared 2-core CI container where run-to-run noise
    of 30-50% is routine (see docs/observability.md), so the gate
    catches step-function regressions (a quadratic slipped in, tracing
    accidentally always-on), not percentage drift.  The full series is
    rendered precisely so humans can see the drift the gate tolerates.
  * :func:`render_history` writes the series as markdown
    (``docs/bench_history.md``): per-anchor table, unicode sparklines,
    and inline-SVG trend charts via the report's helpers.

``scripts/bench_check.py`` is the CLI; CI runs it on every push and
fails the build when a gate trips.
"""

from __future__ import annotations

import json
import os
import re
from typing import Dict, List, Optional

_BENCH_RE = re.compile(r"^BENCH_(\d+)\.json$")


def _get(d: Dict, *path):
    """Nested dict get -> None on any missing step (anchors grow
    sections over time; absence is data, not an error)."""
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def load_trajectory(root: str = ".") -> List[Dict]:
    """Parse every ``BENCH_N.json`` under ``root`` into one sorted list
    of flat per-anchor points (``None`` where an anchor predates a
    measurement)."""
    points: List[Dict] = []
    for fname in sorted(os.listdir(root)):
        m = _BENCH_RE.match(fname)
        if not m:
            continue
        path = os.path.join(root, fname)
        with open(path) as f:
            raw = json.load(f)
        trace_off = _get(raw, "telemetry", "results", "trace_off_s")
        trace_on = _get(raw, "telemetry", "results", "trace_on_s")
        tax = (trace_on / trace_off
               if trace_on is not None and trace_off else None)
        points.append({
            "pr": int(m.group(1)),
            "path": path,
            "quick": bool(raw.get("quick", False)),
            "engine_version": raw.get("engine_version"),
            "engine_default": _get(raw, "main", "wall_clock_s",
                                   "engine_default"),
            "pr1": _get(raw, "main", "wall_clock_s", "pr1"),
            "vmap_flat": _get(raw, "main", "wall_clock_s", "vmap_flat"),
            "sequential": _get(raw, "main", "wall_clock_s", "sequential"),
            "speedup_vs_pr1": raw.get("speedup_vs_pr1"),
            "cache_fresh": _get(raw, "cache_roundtrip_s", "fresh"),
            "cache_cached": _get(raw, "cache_roundtrip_s", "cached"),
            "trace_off_s": trace_off,
            "trace_on_s": trace_on,
            "telemetry_tax": tax,
            "metrics_scrape_ms": _get(raw, "observability", "results",
                                      "metrics_scrape_ms"),
            "flight_scrape_ms": _get(raw, "observability", "results",
                                     "flight_scrape_ms"),
        })
    points.sort(key=lambda p: p["pr"])
    return points


def _last_with(points: List[Dict], key: str, *, before: int) -> Optional[Dict]:
    """Newest point earlier than index ``before`` that carries ``key``."""
    for p in reversed(points[:before]):
        if p.get(key) is not None:
            return p
    return None


def check_regression(points: List[Dict], *, band: float = 2.0) -> Dict:
    """Trajectory gates over the newest anchor.  Returns
    ``{"ok", "band", "checks": [{"name", "ok", "value", "limit",
    "detail"}, ...]}`` — ``ok`` is the AND of every applicable check;
    gates whose inputs are missing are reported ``ok`` with a detail
    saying why (an early trajectory must not fail CI)."""
    checks: List[Dict] = []
    if len(points) < 2:
        return {"ok": True, "band": band,
                "checks": [{"name": "trajectory", "ok": True,
                            "value": len(points), "limit": 2,
                            "detail": "fewer than 2 anchors — nothing to "
                                      "compare yet"}]}
    last = points[-1]

    # gate 1: engine_default vs the previous anchor that measured it
    prev = _last_with(points, "engine_default", before=len(points) - 1)
    if last["engine_default"] is None or prev is None:
        checks.append({"name": "engine_default", "ok": True, "value": None,
                       "limit": band,
                       "detail": "engine_default missing from an anchor"})
    else:
        ratio = last["engine_default"] / prev["engine_default"]
        checks.append({
            "name": "engine_default", "ok": ratio <= band,
            "value": round(ratio, 3), "limit": band,
            "detail": f"BENCH_{last['pr']} {last['engine_default']:.2f}s vs "
                      f"BENCH_{prev['pr']} {prev['engine_default']:.2f}s "
                      f"(ratio {ratio:.2f}, gate {band:.1f}x)"})

    # gate 2: the telemetry-enabled tax of the newest measuring anchor
    if last["telemetry_tax"] is None:
        checks.append({"name": "telemetry_tax", "ok": True, "value": None,
                       "limit": band,
                       "detail": "no telemetry section in the newest "
                                 "anchor"})
    else:
        checks.append({
            "name": "telemetry_tax", "ok": last["telemetry_tax"] <= band,
            "value": round(last["telemetry_tax"], 3), "limit": band,
            "detail": f"trace_on {last['trace_on_s']:.2f}s / trace_off "
                      f"{last['trace_off_s']:.2f}s = "
                      f"{last['telemetry_tax']:.2f} (gate {band:.1f}x)"})

    # gate 3: the traced-off baseline vs the previous telemetry anchor —
    # the disabled contract must not quietly become the enabled one
    prev_t = _last_with(points, "trace_off_s", before=len(points) - 1)
    if last["trace_off_s"] is None or prev_t is None:
        checks.append({"name": "trace_off_baseline", "ok": True,
                       "value": None, "limit": band,
                       "detail": "needs two anchors with telemetry "
                                 "sections"})
    else:
        ratio = last["trace_off_s"] / prev_t["trace_off_s"]
        checks.append({
            "name": "trace_off_baseline", "ok": ratio <= band,
            "value": round(ratio, 3), "limit": band,
            "detail": f"BENCH_{last['pr']} {last['trace_off_s']:.2f}s vs "
                      f"BENCH_{prev_t['pr']} {prev_t['trace_off_s']:.2f}s "
                      f"(ratio {ratio:.2f}, gate {band:.1f}x)"})

    return {"ok": all(c["ok"] for c in checks), "band": band,
            "checks": checks}


def _fmt(v, spec: str = "{:.2f}") -> str:
    return spec.format(v) if v is not None else "—"


def render_history(points: List[Dict], verdict: Optional[Dict] = None,
                   ) -> str:
    """The trajectory as markdown (docs/bench_history.md)."""
    # report carries the shared presentation helpers; imported here, not
    # at module top, to keep `repro.analysis` importable without jax
    from repro.analysis.report import sparkline, svg_timeseries

    lines = [
        "# Bench trajectory",
        "",
        "Every `BENCH_N.json` anchor as one time series — regenerate with",
        "`PYTHONPATH=src python scripts/bench_check.py` (CI runs it per",
        "push and fails on the gates below; see docs/observability.md for",
        "the noise band these anchors carry).",
        "",
        "| bench | engine_default s | pr1 s | vmap_flat s | cache hit s | "
        "trace off s | trace on s | tax |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for p in points:
        lines.append(
            f"| BENCH_{p['pr']} | {_fmt(p['engine_default'])} | "
            f"{_fmt(p['pr1'])} | {_fmt(p['vmap_flat'])} | "
            f"{_fmt(p['cache_cached'], '{:.3f}')} | "
            f"{_fmt(p['trace_off_s'])} | {_fmt(p['trace_on_s'])} | "
            f"{_fmt(p['telemetry_tax'])} |")
    lines.append("")

    def series(key):
        return [p[key] for p in points]

    labels = [str(p["pr"]) for p in points]
    for key, title in (("engine_default",
                        "engine_default wall-clock (s) per bench anchor"),
                       ("vmap_flat",
                        "vmap_flat wall-clock (s) per bench anchor")):
        vals = [v for v in series(key) if v is not None]
        if len(vals) >= 2:
            lines += [f"`{key}`: `{sparkline(vals)}` "
                      f"({vals[0]:.1f}s → {vals[-1]:.1f}s)", "",
                      svg_timeseries(labels, series(key), title=title,
                                     fmt="{:.1f}s"), ""]
    taxes = [v for v in series("telemetry_tax") if v is not None]
    if taxes:
        lines += ["`telemetry_tax` (trace_on / trace_off): " +
                  ", ".join(f"{t:.2f}" for t in taxes), ""]

    if verdict is not None:
        lines += [f"## Gates (band {verdict['band']:.1f}x)", ""]
        for c in verdict["checks"]:
            mark = "PASS" if c["ok"] else "**FAIL**"
            lines.append(f"- {mark} `{c['name']}`: {c['detail']}")
        lines.append("")
    return "\n".join(lines) + "\n"
