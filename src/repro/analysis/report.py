"""``python -m repro.analysis.report`` — the paper's tables and figures as
a statistically defensible markdown report.

The report reproduces Table II and the §VII readouts **with error bars**:
every number that used to be a single-seed point estimate is rendered as
a seed-replicated mean with a bootstrap CI (`repro.analysis.stats`), the
scalability upper bound additionally as a fitted parameter of the Thm-2
cost law next to the theory-side prediction (`repro.analysis.fit`), and
the thesis itself — dataset characters decide m_max — as a regression
across every cached sweep with a cost readout.

Sections:

  1. **Table II, replicated** — the ``upper_bound`` spec re-run with a
     seed batch: per-m cost mean +- std, bootstrap-CI measured m_max,
     fitted and predicted m_max side by side, with a loss-curve sparkline
     per worker count and an inline SVG cost curve with its CI band.
  2. **Character surface** — the ``character_surface`` spec: the
     (variance x density x duplication) knob grid with measured / fitted /
     predicted m_max per cell.
  3. **Critical-parameter surface** — the ``critical_params`` spec:
     momentum lr x local-SGD sync window x async-SVRG anchor period, each
     at two dataset-character settings, with the per-knob m_max cliff and
     its character-driven shift spelled out.
  4. **Fault tolerance** — the ``fault_tolerance`` spec: Hogwild! and
     local SGD under seeded delivery-fault rates (straggle + sign-flip,
     `repro.resilience.faults`) at the two character settings, with the
     measured m_max degradation vs fault rate spelled out per cell —
     the hi-variance, all-unique dataset collapses faster than the
     duplicated lo-variance one (docs/robustness.md).
  5. **characters -> m_max regression** — fitted coefficients and R^2
     across all cached sweeps (anything `run_sweep` ever stored in the
     cache dir contributes points; diverged/failed jobs are excluded by
     their ``status``).
  6. **where the time went** — the report's own sweep executions run
     under the span tracer (`repro.telemetry`), and the last computed
     sweep's phase breakdown (datasets / per-bucket lower-compile-execute
     / journal / cache IO) is rendered as a table.  All-cache-hit renders
     have nothing to attribute and say so.

Results come from the artifact cache when fingerprints match (a report
re-render is then pure formatting) or from a fresh run; ``--quick``,
``--iters``, ``--n``, ``--seeds`` scale the sweeps exactly like the
`repro.experiments.run` CLI.

  PYTHONPATH=src python -m repro.analysis.report --quick
  PYTHONPATH=src python -m repro.analysis.report --quick --iters 60 --n 160
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, List, Optional

from repro.analysis import fit, stats
from repro.experiments import cache as artifact_cache
from repro.experiments import registry, runner
from repro.experiments.spec import ENGINE_VERSION
from repro.telemetry import trace

#: specs the report runs; upper_bound ships single-seed, so the report
#: replicates it with this many seeds unless --seeds overrides
REPORT_SPECS = ("upper_bound", "character_surface", "critical_params",
                "fault_tolerance")
DEFAULT_SEEDS = {"quick": 3, "full": 8}
DEFAULT_OUT = os.path.join("results", "analysis_report.md")

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values) -> str:
    """Unicode block sparkline, per-curve normalized."""
    vals = [float(v) for v in values]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[min(int((v - lo) / span * len(_SPARK)),
                              len(_SPARK) - 1)] for v in vals)


def _fmt_ci(point: int, lo: int, hi: int) -> str:
    return f"{point}" if lo == hi == point else f"{point} [{lo}, {hi}]"


def svg_cost_curve(ms, mean, lo, hi, *, title: str) -> str:
    """Minimal inline SVG: the per-worker cost curve (one series — no
    legend, the title names it) with its bootstrap-CI band.  Neutral ink
    line over a light gray band, muted text, no chart junk."""
    w, h, pad = 380, 140, 34
    xs = [math.log2(m) for m in ms]
    x0, x1 = min(xs), max(xs)
    ymin = min(lo)
    ymax = max(hi) or 1.0
    yspan = (ymax - ymin) or 1.0

    def X(v):
        return pad + (v - x0) / ((x1 - x0) or 1.0) * (w - 2 * pad)

    def Y(v):
        return h - pad - (v - ymin) / yspan * (h - 2 * pad)

    band = " ".join(f"{X(x):.1f},{Y(u):.1f}" for x, u in zip(xs, hi))
    band += " " + " ".join(f"{X(x):.1f},{Y(u):.1f}"
                           for x, u in zip(reversed(xs), reversed(lo)))
    line = " ".join(f"{X(x):.1f},{Y(v):.1f}" for x, v in zip(xs, mean))
    ticks = "".join(
        f'<text x="{X(x):.1f}" y="{h - pad + 14}" font-size="9" '
        f'fill="#6b7280" text-anchor="middle">{m}</text>'
        for x, m in zip(xs, ms))
    return (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" '
        f'viewBox="0 0 {w} {h}" role="img" aria-label="{title}">'
        f'<text x="{pad}" y="14" font-size="10" fill="#374151">{title}'
        f' &#8212; cost/worker vs m (band: bootstrap CI)</text>'
        f'<line x1="{pad}" y1="{h - pad}" x2="{w - pad}" y2="{h - pad}" '
        f'stroke="#e5e7eb" stroke-width="1"/>'
        f'<polygon points="{band}" fill="#d1d5db" fill-opacity="0.55"/>'
        f'<polyline points="{line}" fill="none" stroke="#1f2937" '
        f'stroke-width="2" stroke-linejoin="round"/>'
        f'{ticks}'
        f'<text x="{w - pad}" y="{Y(mean[-1]) - 6:.1f}" font-size="9" '
        f'fill="#374151" text-anchor="end">{mean[-1]:.0f}</text>'
        f'</svg>')


def svg_timeseries(labels, values, *, title: str,
                   fmt: str = "{:.1f}") -> str:
    """Minimal inline SVG for an ordered series (one point per label,
    e.g. wall-clock per bench anchor).  Same visual language as
    `svg_cost_curve`: one neutral ink line, muted ticks, no chart junk.
    ``None`` values are skipped (a bench that predates the measurement);
    the last point is annotated with ``fmt``."""
    w, h, pad = 380, 140, 34
    pts = [(i, float(v)) for i, v in enumerate(values) if v is not None]
    if not pts:
        return ""
    ymin = min(v for _, v in pts)
    ymax = max(v for _, v in pts)
    yspan = (ymax - ymin) or 1.0
    x1 = max(len(labels) - 1, 1)

    def X(i):
        return pad + i / x1 * (w - 2 * pad)

    def Y(v):
        return h - pad - (v - ymin) / yspan * (h - 2 * pad)

    line = " ".join(f"{X(i):.1f},{Y(v):.1f}" for i, v in pts)
    dots = "".join(f'<circle cx="{X(i):.1f}" cy="{Y(v):.1f}" r="2.5" '
                   f'fill="#1f2937"/>' for i, v in pts)
    ticks = "".join(
        f'<text x="{X(i):.1f}" y="{h - pad + 14}" font-size="9" '
        f'fill="#6b7280" text-anchor="middle">{lab}</text>'
        for i, lab in enumerate(labels))
    last_i, last_v = pts[-1]
    return (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" '
        f'viewBox="0 0 {w} {h}" role="img" aria-label="{title}">'
        f'<text x="{pad}" y="14" font-size="10" fill="#374151">{title}'
        f'</text>'
        f'<line x1="{pad}" y1="{h - pad}" x2="{w - pad}" y2="{h - pad}" '
        f'stroke="#e5e7eb" stroke-width="1"/>'
        f'<polyline points="{line}" fill="none" stroke="#1f2937" '
        f'stroke-width="2" stroke-linejoin="round"/>'
        f'{dots}{ticks}'
        f'<text x="{X(last_i):.1f}" y="{Y(last_v) - 6:.1f}" font-size="9" '
        f'fill="#374151" text-anchor="end">{fmt.format(last_v)}</text>'
        f'</svg>')


# ---------------------------------------------------------------------------
# section renderers
# ---------------------------------------------------------------------------

def _eps_of(result: Dict):
    eps = (result.get("spec") or {}).get("epsilon") or {}
    return eps.get("probe_m"), eps.get("frac")


def render_upper_bound(result: Dict, *, svg: bool = True) -> List[str]:
    probe_m, frac = _eps_of(result)
    lines = ["## 1. Table II, replicated (`upper_bound`)", ""]
    spec = result["spec"]
    lines += [f"m grid {list(spec['ms'])}, iters {spec['iters']}, "
              f"{spec.get('n_seeds', 1)} seed replicate(s) per job; costs "
              f"are iterations/worker to the per-seed probe epsilon "
              f"(probe m={probe_m}, frac={frac}).", ""]
    ms = list(next(iter(result["jobs"].values()))["ms"])
    head = (["job", "epsilon (seed 0)"]
            + [f"cost m={m}" for m in ms]
            + ["measured m_max [CI]", "fitted m_max [CI]", "predicted"])
    rows = []
    figs: List[str] = []
    for key, jr in result["jobs"].items():
        boot = stats.mmax_bootstrap(jr, probe_m=probe_m, frac=frac)
        law = fit.fit_job(jr, probe_m=probe_m, frac=frac)
        cm, cs = boot["cost_mean"], boot["cost_std"]
        pred = (jr.get("predicted") or {}).get("predicted_m_max", "-")
        rows.append(
            [key, f"{jr['epsilon']:.4f}"]
            + [f"{m_:.0f} &#177; {s_:.0f}" for m_, s_ in zip(cm, cs)]
            + [_fmt_ci(boot["m_max"], boot["lo"], boot["hi"]),
               _fmt_ci(law["fitted_m_max"], law["fitted_m_max_lo"],
                       law["fitted_m_max_hi"]) + f" (R&#178;={law['r2']:.2f})",
               str(pred)])
        if svg:
            band_lo = [m_ - s_ for m_, s_ in zip(cm, cs)]
            band_hi = [m_ + s_ for m_, s_ in zip(cm, cs)]
            figs.append(svg_cost_curve(jr["ms"], cm, band_lo, band_hi,
                                       title=key))
    lines += _table(head, rows)
    lines += ["", "Loss curves (seed-mean, one sparkline per worker "
              "count; final loss mean &#177; std):", ""]
    for key, jr in result["jobs"].items():
        cs_ = stats.curve_stats(jr)
        mean = cs_["mean"]
        std = cs_["std"]
        per_m = "  ".join(
            f"m{m}:{sparkline(mean[i])} {mean[i][-1]:.3f}&#177;"
            f"{std[i][-1]:.3f}" for i, m in enumerate(cs_["ms"]))
        lines.append(f"- `{key}` {per_m}")
    if figs:
        lines += [""] + figs
    return lines + [""]


def render_character_surface(result: Dict) -> List[str]:
    probe_m, frac = _eps_of(result)
    lines = ["## 2. Character surface (`character_surface`)", ""]
    lines += ["One generator (`character_knob`), three knobs, one cell per "
              "combination: the paper's thesis as a surface.  `measured` "
              "is the bootstrap point estimate over seed replicates, "
              "`fitted` the Thm-2 law's bound on the seed-mean cost curve, "
              "`predicted` the theory-side character bound.", ""]
    head = ["variance", "density", "dup", "measured m_max [CI]",
            "fitted m_max [CI]", "predicted", "fit R&#178;"]
    rows = []
    for key, jr in result["jobs"].items():
        ds = result["spec"]["datasets"][jr["dataset"]]["kwargs"]
        boot = stats.mmax_bootstrap(jr, probe_m=probe_m, frac=frac)
        law = fit.fit_job(jr, probe_m=probe_m, frac=frac)
        pred = (jr.get("predicted") or {}).get("predicted_m_max", "-")
        rows.append([f"{ds.get('variance', 1.0):g}",
                     f"{ds.get('density', 1.0):g}",
                     f"{ds.get('duplication', 0.0):g}",
                     _fmt_ci(boot["m_max"], boot["lo"], boot["hi"]),
                     _fmt_ci(law["fitted_m_max"], law["fitted_m_max_lo"],
                             law["fitted_m_max_hi"]),
                     str(pred), f"{law['r2']:.2f}"])
    return lines + _table(head, rows) + [""]


def render_critical_params(result: Dict) -> List[str]:
    from repro.experiments.spec import JobSpec

    probe_m, frac = _eps_of(result)
    lines = ["## 3. Critical-parameter surface (`critical_params`)", ""]
    lines += ["Three optimizer classes, one critical knob each — the "
              "momentum step size, the local-SGD sync window `H`, the "
              "async-SVRG anchor period `A` — swept at two "
              "`character_knob` settings.  The worker grid is the batch "
              "axis for the synchronous pair and the staleness axis "
              "(tau_max = m) for async-SVRG; the question is whether the "
              "m_max cliff moves with the knob AND with the dataset "
              "characters.", ""]
    head = ["algorithm", "knob", "dataset", "var", "density", "dup",
            "measured m_max [CI]", "fitted m_max [CI]", "predicted"]
    rows = []
    # fitted/measured bounds per (algorithm, knob) across the character
    # settings, in spec dataset order — the cliff shift spelled out below
    shifts: Dict[str, Dict[str, tuple]] = {}
    for j in result["spec"]["jobs"]:
        key = JobSpec(**j).key
        jr = result["jobs"][key]
        ds = result["spec"]["datasets"][jr["dataset"]]["kwargs"]
        boot = stats.mmax_bootstrap(jr, probe_m=probe_m, frac=frac)
        law = fit.fit_job(jr, probe_m=probe_m, frac=frac)
        pred = (jr.get("predicted") or {}).get("predicted_m_max", "-")
        knob = j.get("label") or "-"
        rows.append([j["algorithm"], knob, jr["dataset"],
                     f"{ds.get('variance', 1.0):g}",
                     f"{ds.get('density', 1.0):g}",
                     f"{ds.get('duplication', 0.0):g}",
                     _fmt_ci(boot["m_max"], boot["lo"], boot["hi"]),
                     _fmt_ci(law["fitted_m_max"], law["fitted_m_max_lo"],
                             law["fitted_m_max_hi"]),
                     str(pred)])
        shifts.setdefault(f"{j['algorithm']}[{knob}]", {})[
            jr["dataset"]] = (boot["m_max"], law["fitted_m_max"])
    lines += _table(head, rows)
    lines += ["", "m_max cliff across the character settings "
              "(measured, fitted in parentheses):", ""]
    for cell, per_ds in shifts.items():
        path = " &#8594; ".join(
            f"{name} {m} ({f_})" for name, (m, f_) in per_ds.items())
        lines.append(f"- `{cell}`: {path}")
    return lines + [""]


def render_fault_tolerance(result: Dict) -> List[str]:
    from repro.experiments.spec import JobSpec

    probe_m, frac = _eps_of(result)
    lines = ["## 4. Fault tolerance (`fault_tolerance`)", ""]
    lines += ["Deterministic fault injection (`repro.resilience.faults`) "
              "as a sweep axis: each cell runs under a seeded stream of "
              "straggling (extra staleness, capped at tau = m) and "
              "sign-flipped updates at the row's rate.  The fault seed is "
              "pinned, so every cell is bit-reproducible and the seed "
              "replicates share the fault schedule.  `measured` is the "
              "bootstrap m_max point estimate; degradation is relative "
              "to the same cell's clean (rate 0) run.", ""]
    head = ["algorithm", "fault rate", "dataset", "var", "dup",
            "status", "measured m_max [CI]", "vs clean"]
    rows = []
    # (algorithm, dataset) -> {rate: bootstrap m_max}, spec job order
    cells: Dict[tuple, Dict[float, int]] = {}
    for j in result["spec"]["jobs"]:
        key = JobSpec(**j).key
        jr = result["jobs"][key]
        ds = result["spec"]["datasets"][jr["dataset"]]["kwargs"]
        rate = float((j["kwargs"].get("fault") or {})
                     .get("straggle_rate", 0.0))
        status = str(jr.get("status", "ok"))
        if status == "ok" or status.startswith("retried"):
            boot = stats.mmax_bootstrap(jr, probe_m=probe_m, frac=frac)
            cell = cells.setdefault((j["algorithm"], jr["dataset"]), {})
            cell[rate] = boot["m_max"]
            clean = cell.get(0.0)
            vs = ("-" if not clean or rate == 0.0
                  else f"{boot['m_max'] / clean:.0%}")
            measured = _fmt_ci(boot["m_max"], boot["lo"], boot["hi"])
        else:
            # a diverged/failed cell still renders — as its status, not
            # as a number pretending to be one
            vs, measured = "-", "-"
        rows.append([j["algorithm"], f"{rate:g}", jr["dataset"],
                     f"{ds.get('variance', 1.0):g}",
                     f"{ds.get('duplication', 0.0):g}",
                     status, measured, vs])
    lines += _table(head, rows)
    lines += ["", "m_max degradation at the top fault rate (bootstrap "
              "estimate, relative to the clean cell):", ""]
    for (algo, ds_name), byrate in cells.items():
        clean = byrate.get(0.0)
        top = max(byrate)
        if not clean or top == 0.0:
            continue
        kept = byrate[top] / clean
        lines.append(f"- `{algo}` on `{ds_name}`: {clean} &#8594; "
                     f"{byrate[top]} at rate {top:g} "
                     f"({kept:.0%} of clean m_max)")
    return lines + [""]


def render_regression(results: List[Dict]) -> List[str]:
    points = fit.collect_character_points(results)
    lines = ["## 5. characters &#8594; m_max regression", ""]
    reg = fit.characters_regression(points)
    if reg is None:
        return lines + [f"not enough cost-readout points "
                        f"({len(points)}) to regress.", ""]
    lines += [f"log2(m_max) ~ intercept + log10(variance) + sparsity + "
              f"diversity_ratio over **{reg['n_points']} sweep cells** "
              f"(every cached sweep with a cost readout contributes):", ""]
    head = ["coefficient", "value"]
    rows = [[k, f"{v:+.3f}"] for k, v in reg["coef"].items()]
    rows.append(["R&#178;", f"{reg['r2']:.3f}"])
    return lines + _table(head, rows) + [""]


def render_telemetry(events: List[Dict]) -> List[str]:
    """Section 6: phase breakdown of the report's last *computed* sweep
    (cache hits execute nothing, so an all-hit render has no phases)."""
    lines = ["## 6. where the time went (span trace)", ""]
    bd = trace.phase_breakdown(events, root="sweep")
    if bd["root"] is None:
        return lines + ["every sweep above was served from the artifact "
                        "cache — nothing was computed, so there is no "
                        "compute to attribute (`--force` recomputes and "
                        "fills this section).", ""]
    lines += [f"last computed sweep: **{bd['wall_us'] / 1e6:.2f} s** "
              f"wall-clock, {bd['coverage']:.0%} attributed to child "
              f"phases (`repro.telemetry.trace`; re-run any spec with "
              f"`repro.experiments.run --trace` for the full "
              f"Perfetto-loadable timeline).", ""]
    head = ["phase", "total (s)", "spans", "% of sweep"]
    rows = [[name, f"{p['total_us'] / 1e6:.3f}", p["count"],
             f"{p['frac_of_wall']:.1%}"]
            for name, p in sorted(bd["phases"].items(),
                                  key=lambda kv: -kv[1]["total_us"])]
    return lines + _table(head, rows) + [""]


def _table(head: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(head) + " |",
           "|" + "|".join("---" for _ in head) + "|"]
    out += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
    return out


def load_cached_results(cache_dir: str) -> List[Dict]:
    """Every readable artifact in the sweep cache (the regression's point
    pool); malformed files are skipped."""
    if not os.path.isdir(cache_dir):
        return []
    out = []
    for name in sorted(os.listdir(cache_dir)):
        if not name.endswith(".json"):
            continue
        try:
            with open(os.path.join(cache_dir, name)) as f:
                out.append(json.load(f))
        except (OSError, json.JSONDecodeError):
            continue
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.report",
        description="render the seed-replicated scalability report")
    ap.add_argument("--quick", action="store_true",
                    help="CI-scale sweeps (and 3 seed replicates)")
    ap.add_argument("--iters", type=int, help="override iteration budget")
    ap.add_argument("--n", type=int, help="override dataset size")
    ap.add_argument("--seeds", type=int,
                    help="seed replicates per job (default: 3 quick / 8)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"report path (default {DEFAULT_OUT})")
    ap.add_argument("--cache-dir", help="sweep artifact cache directory")
    ap.add_argument("--force", action="store_true",
                    help="recompute sweeps even on cache hits")
    ap.add_argument("--no-svg", action="store_true",
                    help="tables + sparklines only")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    cache_dir = args.cache_dir or artifact_cache.DEFAULT_CACHE_DIR
    seeds = args.seeds or DEFAULT_SEEDS["quick" if args.quick else "full"]

    results = {}
    # the report traces its own sweep executions; section 6 renders the
    # phase breakdown of the last computed one (hits trace only lookups)
    tracer = trace.start()
    try:
        for name in REPORT_SPECS:
            spec = registry.get_spec(name, quick=args.quick,
                                     iters=args.iters, n=args.n,
                                     seeds=seeds)
            if args.verbose:
                print(f"[report] running {name} "
                      f"(n_seeds={spec.n_seeds}) ...", flush=True)
            results[name] = runner.run_sweep(spec, cache_dir=cache_dir,
                                             force=args.force,
                                             verbose=args.verbose)
    finally:
        trace.stop()

    lines = ["# Scalability report — seed-replicated statistics",
             "",
             f"engine version {ENGINE_VERSION}; "
             f"{seeds} seed replicate(s) per job; bootstrap "
             f"{int(stats.CI * 100)}% CIs over {stats.N_BOOT} resamples.",
             ""]
    lines += render_upper_bound(results["upper_bound"], svg=not args.no_svg)
    lines += render_character_surface(results["character_surface"])
    lines += render_critical_params(results["critical_params"])
    lines += render_fault_tolerance(results["fault_tolerance"])
    lines += render_regression(load_cached_results(cache_dir))
    lines += render_telemetry(tracer.events)

    md = "\n".join(lines) + "\n"
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)

    for name, result in results.items():
        src = "cache" if result["cache"]["hit"] else \
            f"{result.get('elapsed_s', 0.0):.1f}s"
        print(f"[report] {name}: {len(result['jobs'])} jobs ({src})")
    print(f"[report] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
