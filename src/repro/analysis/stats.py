"""Seed-replicated sweep statistics (paper §V, replicated).

Every function here consumes the plain-dict job results `repro.
experiments.runner.run_sweep` produces (and caches): ``losses`` is the
seed-0 curve block, ``losses_seeds`` — present when the spec ran with
``n_seeds > 1`` — the full (S, n_seeds, n_evals) replicate block.  This
module is the vectorized superset of the scalar §V helpers in
`repro.core.scalability` (`iterations_to_epsilon`, `cost_per_worker`,
`gain_growth_from_costs`, `measured_upper_bound`): those stay as thin
single-curve oracles — the parity tests in `tests/test_analysis.py` pin
each vectorized form to its oracle — while everything here broadcasts
over arbitrary leading axes (seeds, grid rows) and adds the replication
statistics the single-seed engine could not support:

  `curve_stats`     per-(job, m) mean / std / bootstrap-CI loss curves
  `cost_samples`    the (n_seeds, S) per-worker cost block under the
                    paper's probe-epsilon policy, applied within-seed
  `mmax_bootstrap`  the bootstrap distribution of the measured m_max —
                    resample seeds, average cost curves, re-read §V.B

Bootstrap draws use a fixed `numpy.random.default_rng` seed so reports
are reproducible; pass ``rng_seed`` to vary them.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.algorithms import base as alg_base

#: default bootstrap resamples / confidence level for the CI helpers
N_BOOT = 400
CI = 0.95


# ---------------------------------------------------------------------------
# views over job results
# ---------------------------------------------------------------------------

def seed_curves(job: Dict) -> np.ndarray:
    """(n_seeds, S, n_evals) float view of a job's loss curves.

    Single-seed results (no ``losses_seeds`` key — any pre-ENGINE_VERSION-4
    artifact, or ``n_seeds=1``) come back with a length-1 seed axis, so
    every statistic below degrades gracefully to the point estimate.
    """
    if "losses_seeds" in job:
        arr = np.asarray(job["losses_seeds"], dtype=float)  # (S, seeds, E)
        return np.moveaxis(arr, 1, 0)
    return np.asarray(job["losses"], dtype=float)[None]


def _async_flag(job: Dict, asynchronous: Optional[bool]) -> bool:
    """Resolve the §V.A.1 cost-division flag off the Algorithm registry
    when the caller doesn't pass it."""
    if asynchronous is not None:
        return asynchronous
    return alg_base.get_algorithm(job["algorithm"]).asynchronous


# ---------------------------------------------------------------------------
# vectorized §V measurement helpers (scalar oracles: core.scalability)
# ---------------------------------------------------------------------------

def iterations_to_epsilon(losses, eval_every: int, epsilon) -> np.ndarray:
    """Server iterations until loss <= epsilon, vectorized over leading
    axes of ``losses`` (..., n_evals); ``epsilon`` may be a scalar or an
    array aligned with the LEADING axes (e.g. shape (n_seeds,) against
    curves (n_seeds, S, n_evals) — one epsilon per seed).  inf where never
    hit — parity with `core.scalability.iterations_to_epsilon` per curve."""
    L = np.asarray(losses, dtype=float)
    eps = np.asarray(epsilon, dtype=float)
    if eps.ndim > L.ndim:
        raise ValueError(f"epsilon shape {eps.shape} has more axes than "
                         f"losses shape {L.shape}")
    # pad trailing axes so eps aligns with the leading axes of L, never
    # with the grid/eval axes
    eps = eps.reshape(eps.shape + (1,) * (L.ndim - eps.ndim))
    hit = L <= eps
    first = hit.argmax(axis=-1)
    return np.where(hit.any(axis=-1), (first + 1.0) * eval_every, np.inf)


def cost_per_worker(iters_to_eps, ms, asynchronous: bool) -> np.ndarray:
    """§V.A.1 cost: async algorithms divide server iterations among the
    workers (the Perfect Computer Assumption); ``ms`` broadcasts against
    the trailing grid axis."""
    it = np.asarray(iters_to_eps, dtype=float)
    return it / np.asarray(ms, dtype=float) if asynchronous else it


def gain_growth(costs) -> np.ndarray:
    """cost_m - cost_{m+1} along the trailing grid axis (positive =
    still gaining)."""
    c = np.asarray(costs, dtype=float)
    return c[..., :-1] - c[..., 1:]


def measured_upper_bound(ms: Sequence[int], gain_growths,
                         threshold: float = 0.0) -> np.ndarray:
    """First m whose gain growth drops to <= threshold (the lower of the
    paper's 'between two red values'), vectorized over leading axes of
    ``gain_growths``; ``ms`` aligns with its trailing axis and ``ms[-1]``
    is the not-reached fallback, exactly like the scalar oracle."""
    gg = np.asarray(gain_growths, dtype=float)
    ms = np.asarray(ms)
    below = gg <= threshold
    idx = below.argmax(axis=-1)
    return np.where(below.any(axis=-1), ms[idx], ms[-1])


# ---------------------------------------------------------------------------
# seed-replicated readouts
# ---------------------------------------------------------------------------

def epsilon_per_seed(job: Dict, probe_m: int, frac: float) -> np.ndarray:
    """Paper Table II policy applied within-seed: each replicate's epsilon
    is the loss *its own* probe_m-worker run reaches after ``frac`` of the
    eval budget (seed 0 therefore equals the runner's scalar
    ``job["epsilon"]``)."""
    curves = seed_curves(job)                       # (seeds, S, E)
    si = list(job["ms"]).index(probe_m)
    idx = min(int(curves.shape[-1] * frac), curves.shape[-1] - 1)
    return curves[:, si, idx]


def cost_samples(job: Dict, *, asynchronous: Optional[bool] = None,
                 probe_m: Optional[int] = None, frac: Optional[float] = None,
                 epsilon: Optional[float] = None) -> np.ndarray:
    """The (n_seeds, S) per-worker cost block.

    Epsilon policy: a shared scalar ``epsilon``, or the per-seed probe
    policy via ``probe_m``/``frac`` (mirroring the spec's `EpsilonSpec`).
    Never-reached costs clamp to the iteration budget, matching the
    runner's scalar readout.
    """
    if epsilon is None:
        if probe_m is None or frac is None:
            raise ValueError("pass either epsilon= or probe_m=/frac=")
        eps = epsilon_per_seed(job, probe_m, frac)   # (n_seeds,) per seed
    else:
        eps = float(epsilon)
    it = iterations_to_epsilon(seed_curves(job), job["eval_every"], eps)
    costs = cost_per_worker(it, job["ms"], _async_flag(job, asynchronous))
    return np.where(np.isfinite(costs), costs, float(job["iters"]))


def _resample(rng: np.random.Generator, n: int, n_boot: int) -> np.ndarray:
    return rng.integers(0, n, size=(n_boot, n))


def _ci_bounds(samples: np.ndarray, ci: float):
    lo_q = 100.0 * (1.0 - ci) / 2.0
    return (np.percentile(samples, lo_q, axis=0),
            np.percentile(samples, 100.0 - lo_q, axis=0))


def curve_stats(job: Dict, *, ci: float = CI, n_boot: int = N_BOOT,
                rng_seed: int = 0) -> Dict:
    """Per-(m, eval) statistics of the loss curves over the seed axis:
    mean, std (ddof=1 when replicated), and a bootstrap CI of the mean.
    All arrays are (S, n_evals) lists, row-aligned with ``job["ms"]``."""
    curves = seed_curves(job)                       # (seeds, S, E)
    n_seeds = curves.shape[0]
    mean = curves.mean(axis=0)
    std = (curves.std(axis=0, ddof=1) if n_seeds > 1
           else np.zeros_like(mean))
    if n_seeds > 1:
        idx = _resample(np.random.default_rng(rng_seed), n_seeds, n_boot)
        boot = curves[idx].mean(axis=1)             # (n_boot, S, E)
        lo, hi = _ci_bounds(boot, ci)
    else:
        lo = hi = mean
    return {"ms": [int(m) for m in job["ms"]], "n_seeds": n_seeds,
            "ci": ci, "mean": mean.tolist(), "std": std.tolist(),
            "lo": lo.tolist(), "hi": hi.tolist()}


def mmax_bootstrap(job: Dict, *, probe_m: int, frac: float,
                   asynchronous: Optional[bool] = None,
                   threshold: float = 0.0, ci: float = CI,
                   n_boot: int = N_BOOT, rng_seed: int = 0) -> Dict:
    """Bootstrap distribution of the measured scalability upper bound.

    Each resample draws seeds with replacement, averages their per-worker
    cost curves, and re-reads the §V.B bound off the averaged curve — the
    replication Stich et al. (2021) show these crossover points need
    before they stabilize.  Returns the point estimate (all-seed mean
    curve), per-seed bounds, the bootstrap samples' CI, and the
    distribution as {m: fraction of resamples}.
    """
    costs = cost_samples(job, asynchronous=asynchronous,
                         probe_m=probe_m, frac=frac)       # (seeds, S)
    ms = [int(m) for m in job["ms"]]
    grid = ms[:-1]                                  # gain growth pairs

    def bound_of(c):
        return measured_upper_bound(grid, gain_growth(c), threshold)

    point = int(bound_of(costs.mean(axis=0)))
    per_seed = bound_of(costs).astype(int)          # (seeds,) row-wise
    n_seeds = costs.shape[0]
    if n_seeds > 1:
        idx = _resample(np.random.default_rng(rng_seed), n_seeds, n_boot)
        samples = bound_of(costs[idx].mean(axis=1)).astype(int)
    else:
        samples = np.array([point])
    lo, hi = _ci_bounds(samples, ci)
    values, counts = np.unique(samples, return_counts=True)
    return {"m_max": point, "lo": int(lo), "hi": int(hi), "ci": ci,
            "median": int(np.median(samples)),
            "per_seed": per_seed.tolist(), "n_seeds": n_seeds,
            "distribution": {int(v): float(c) / samples.size
                             for v, c in zip(values, counts)},
            "cost_mean": costs.mean(axis=0).tolist(),
            "cost_std": (costs.std(axis=0, ddof=1) if n_seeds > 1
                         else np.zeros(costs.shape[1])).tolist()}
