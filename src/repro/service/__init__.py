"""repro.service — the scalability advisor as a long-lived service.

The paper's deliverable (`core.advisor.ScalabilityAdvisor`) answers one
probe at a time; this package turns it into a front end that batches,
gates, and dedups concurrent probes:

  * :mod:`repro.service.batcher` coalesces concurrent dataset-character
    probes into ONE masked-batch jitted call on a `serve.SlotDriver`
    (pad-to-slot, per-slot validity masks — the continuous-batching-lite
    idiom of the serving tier),
  * :mod:`repro.service.tiers` is the early-exit escalation path: the
    cheap analytic tier (the `analysis.fit` predictors) answers
    immediately with a residual-derived confidence; low-confidence
    probes escalate to a measured sweep through `experiments.runner`,
  * :mod:`repro.service.queue` bounds admission — overflow sheds load
    with structured ``overloaded`` responses instead of queueing
    unboundedly,
  * escalations sharing a `SweepSpec` fingerprint collapse into one
    in-flight sweep (`runner.run_sweep(dedup=True)`) whose stored
    artifact fans out to every waiter.

`repro.service.api.AdvisorService` wires the three together; run
``python -m repro.service`` for the CLI.  docs/service.md documents the
tier semantics, the confidence gate, and the dedup/overload contracts.
"""

from repro.service.api import (AdvisorService, ProbeRequest,  # noqa: F401
                               ProbeResponse)
