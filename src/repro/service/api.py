"""The advisor service facade: request/response types + `AdvisorService`.

One public entry point, two shapes:

  * :meth:`AdvisorService.probe` — one request, full path (admission ->
    batched character measurement -> tier routing -> response).  Safe to
    call from many threads at once; concurrent escalations sharing a
    spec fingerprint collapse into one sweep (`tiers.TierRouter`).
  * :meth:`AdvisorService.probe_batch` — N requests coalesced so their
    character measurements ride ONE masked-batch jitted call
    (`batcher.ProbeBatcher`), then each routes through the tiers
    independently.

Every response is a `ProbeResponse`; nothing raises for bad probes —
invalid inputs come back ``status="invalid"`` with the advisor's
structured low-confidence report, and admission overflow comes back
``status="overloaded"`` (see `queue.AdmissionQueue`).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import advisor as advisor_mod
from repro.experiments import runner as runner_mod
from repro.experiments import spec as spec_mod
from repro.experiments.spec import DatasetSpec, SweepSpec
from repro.service.batcher import ProbeBatcher
from repro.service.queue import AdmissionQueue
from repro.service.tiers import DEFAULT_CONFIDENCE_THRESHOLD, TierRouter
from repro.telemetry import metrics, trace

_REQUEST_IDS = itertools.count()

#: per-tier routing latency (seconds), labeled by the tier that answered:
#: "analytic" is sub-ms formula evaluation, "measured" includes the
#: escalated sweep (or its cache/dedup hit) — the split IS the service's
#: latency story
_TIER_LATENCY = {
    t: metrics.histogram("repro_service_tier_latency_seconds",
                         help="probe routing latency by answering tier",
                         labels={"tier": t})
    for t in ("analytic", "measured", "invalid")
}

#: distribution of analytic confidences at routing time — mass below the
#: escalation threshold is the fraction of traffic buying measurements
_CONFIDENCE = metrics.histogram(
    "repro_service_confidence",
    help="analytic confidence observed per routed probe",
    buckets=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0))


@dataclasses.dataclass
class ProbeRequest:
    """One scalability probe.

    Exactly one of ``X`` (raw dataset), ``grads`` (per-shard gradient
    pytrees), ``dataset`` (a reproducible `DatasetSpec`), or ``sweep``
    (a full `SweepSpec` — its first dataset is probed) should be set.
    Only the spec-carrying shapes can escalate to a measured sweep: raw
    arrays have no fingerprintable identity (see docs/service.md).

    ``escalate``: None = confidence-gated (the default), True = force
    the measured tier, False = never escalate.
    """
    X: Optional[Any] = None
    grads: Optional[List] = None
    dataset: Optional[DatasetSpec] = None
    sweep: Optional[SweepSpec] = None
    algorithm: str = "hogwild"
    escalate: Optional[bool] = None
    kwargs: Dict = dataclasses.field(default_factory=dict)
    request_id: str = dataclasses.field(
        default_factory=lambda: f"probe-{next(_REQUEST_IDS)}")

    @property
    def kind(self) -> str:
        return "grads" if self.grads is not None else "dataset"

    def materialize_X(self, rows_cap: int) -> Optional[np.ndarray]:
        """The dataset the analytic tier measures: the raw ``X``, or the
        (deterministically generated) spec dataset, row-capped like the
        runner's characters report."""
        if self.X is not None:
            return np.asarray(self.X)
        ds = self.dataset
        if ds is None and self.sweep is not None and self.sweep.datasets:
            ds = next(iter(self.sweep.datasets.values()))
        if ds is None:
            return None
        X = np.asarray(spec_mod.build_dataset(ds).X)
        return X[:rows_cap] if rows_cap else X


@dataclasses.dataclass
class ProbeResponse:
    """status: "ok" | "invalid" | "overloaded"; tier: "analytic" |
    "measured" | None (shed/invalid requests never reach a tier)."""
    request_id: str
    status: str
    tier: Optional[str]
    confidence: float
    confidence_detail: Dict
    report: Dict
    escalation: Optional[Dict] = None
    note: Optional[str] = None

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class AdvisorService:
    """Batching + tiering + admission in front of `ScalabilityAdvisor`."""

    def __init__(self, *, n_slots: int = 8, max_rows: int = 512,
                 max_cols: int = 64, queue_depth: int = 32,
                 confidence_threshold: float = DEFAULT_CONFIDENCE_THRESHOLD,
                 cache_dir: Optional[str] = None,
                 cache_cap: Optional[int] = None,
                 parallel_cost: float = 1e-3,
                 sweep_ms=(1, 2, 4), sweep_iters: int = 200,
                 sweep_eval_every: int = 20,
                 characters_rows: int = runner_mod.DEFAULT_CHARACTERS_ROWS):
        self.queue = AdmissionQueue(queue_depth)
        self.batcher = ProbeBatcher(n_slots=n_slots, max_rows=max_rows,
                                    max_cols=max_cols)
        self.tiers = TierRouter(
            confidence_threshold=confidence_threshold, cache_dir=cache_dir,
            cache_cap=cache_cap, parallel_cost=parallel_cost,
            sweep_ms=sweep_ms, sweep_iters=sweep_iters,
            sweep_eval_every=sweep_eval_every)
        self.characters_rows = int(characters_rows)
        self._batch_lock = threading.Lock()

    # -- the front door -----------------------------------------------------
    def probe(self, request: ProbeRequest) -> ProbeResponse:
        return self.probe_batch([request])[0]

    def probe_batch(self, requests: List[ProbeRequest]
                    ) -> List[ProbeResponse]:
        responses: Dict[str, ProbeResponse] = {}
        admitted: List[ProbeRequest] = []
        stamps: List[float] = []
        for r in requests:
            stamp = self.queue.try_admit()
            if stamp is not None:
                admitted.append(r)
                stamps.append(stamp)
            else:
                responses[r.request_id] = ProbeResponse(
                    request_id=r.request_id, status="overloaded",
                    tier=None, confidence=0.0, confidence_detail={},
                    report={}, note=f"admission queue full (depth "
                                    f"{self.queue.depth}); shed — retry "
                                    f"after in-flight probes drain")
        try:
            with trace.span("measure_batch", n=len(admitted)):
                characters = self._measure(admitted)
            for r in admitted:
                t0 = time.perf_counter()
                with trace.span("respond", request_id=r.request_id):
                    resp = self._respond(r, characters.get(r.request_id))
                tier = resp.tier if resp.tier is not None else "invalid"
                _TIER_LATENCY[tier].observe(time.perf_counter() - t0)
                if resp.tier is not None:
                    # the analytic confidence that routed the probe — for
                    # measured answers that's the pre-escalation one
                    conf = resp.confidence_detail
                    if resp.tier == "measured":
                        conf = conf.get("analytic", {})
                    _CONFIDENCE.observe(float(conf.get("confidence", 0.0)))
                responses[r.request_id] = resp
        finally:
            for stamp in stamps:
                self.queue.release(admitted_at=stamp)
        return [responses[r.request_id] for r in requests]

    # -- stage 1: batched character measurement -----------------------------
    def _measure(self, requests: List[ProbeRequest]
                 ) -> Dict[str, Optional[Dict]]:
        """One masked-batch call for the dataset probes (slot driver) and
        one for the gradient probes; the lock serializes driver state,
        NOT escalation — concurrent `probe()` callers still overlap in
        the measured tier, which is what the dedup table collapses."""
        ds_items, grad_items = [], []
        for r in requests:
            if r.kind == "grads":
                grad_items.append(r)
            else:
                ds_items.append(
                    (r.request_id, r.materialize_X(self.characters_rows)))
        out: Dict[str, Optional[Dict]] = {}
        with self._batch_lock:
            if ds_items:
                out.update(self.batcher.measure(ds_items))
            if grad_items:
                chs = self.batcher._advisor.grad_characters_batch(
                    [r.grads for r in grad_items],
                    n_slots=self.batcher.n_slots)
                out.update({r.request_id: ch
                            for r, ch in zip(grad_items, chs)})
        return out

    # -- stage 2: per-request tier routing ----------------------------------
    def _respond(self, request: ProbeRequest,
                 ch: Optional[Dict]) -> ProbeResponse:
        adv = self.batcher._advisor
        if ch is None:
            if request.kind == "grads":
                reason = adv.validate_grads(request.grads) or \
                    "unmeasurable gradient probe"
            else:
                X = request.materialize_X(self.characters_rows)
                reason = adv.validate_dataset(X) or "unmeasurable dataset"
            return ProbeResponse(
                request_id=request.request_id, status="invalid", tier=None,
                confidence=0.0, confidence_detail={},
                report=adv.invalid_report(request.kind, reason))

        conf = self.tiers.confidence(
            ch, "dataset" if request.kind == "dataset" else "grads")
        if request.kind == "grads":
            report = self.tiers.analytic_grad_report(ch)
        else:
            report = self.tiers.analytic_dataset_report(ch, request.kwargs)

        wants_sweep = (request.escalate is True or
                       (request.escalate is None and
                        conf["confidence"] < self.tiers.threshold))
        if not wants_sweep:
            return ProbeResponse(
                request_id=request.request_id, status="ok", tier="analytic",
                confidence=float(conf["confidence"]),
                confidence_detail=conf, report=report)

        if self.tiers.escalation_spec(request) is None:
            return ProbeResponse(
                request_id=request.request_id, status="ok", tier="analytic",
                confidence=float(conf["confidence"]),
                confidence_detail=conf, report=report,
                note="escalation unavailable: raw in-memory probes carry "
                     "no reproducible dataset identity — pass a "
                     "DatasetSpec or SweepSpec to enable the measured "
                     "tier")
        esc = self.tiers.escalate(request)
        return ProbeResponse(
            request_id=request.request_id, status="ok", tier="measured",
            confidence=1.0 if esc["healthy"] else 0.0,
            confidence_detail={"source": "measured",
                               "analytic": conf,
                               "job_status": esc["status"]},
            report=report, escalation=esc)

    def stats(self) -> Dict:
        return {"queue": self.queue.stats(),
                "batcher": self.batcher.stats(),
                "tiers": self.tiers.stats(),
                "sweep_computes": runner_mod.SWEEP_COMPUTES,
                # registry-backed observability block: service counters /
                # gauges / latency+confidence histograms, JSON-shaped
                # exactly like `python -m repro.telemetry --format json`
                "telemetry": metrics.REGISTRY.to_dict(
                    prefix="repro_service")}
