"""Tiered escalation: analytic early exit, measured sweep on low
confidence.

The FastBERT idiom applied to scalability advice.  Tier 1 is *analytic*:
the `analysis.fit` ``*_from_characters`` predictors answer a probe
immediately from its measured characters, at a confidence derived from
the characters->m_max regression residuals over the sweeps already in
the artifact cache (`fit.analytic_confidence`; `fit.CONFIDENCE_PRIOR`
when no history exists).  Probes whose confidence clears the threshold
exit there — zero sweeps executed.  Below the threshold (or when the
caller forces it), tier 2 runs a *measured* sweep through
`experiments.runner.run_sweep` with single-flight dedup: concurrent
escalations sharing the spec fingerprint execute ONE sweep, and every
waiter is answered from the stored artifact (byte-identical fan-out —
the leader re-reads its own store).  Escalations inherit the runner's
crash journal and retry machinery for free.

Only probes that carry a reproducible dataset identity (a `DatasetSpec`
or a full `SweepSpec`) can escalate: a raw in-memory array has no
fingerprintable spec, so its low-confidence analytic answer is returned
with a structured ``escalation unavailable`` note instead.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, Optional

from repro.analysis import fit as FIT
from repro.core import advisor as advisor_mod
from repro.experiments import cache as artifact_cache
from repro.experiments import runner as runner_mod
from repro.experiments import spec as spec_mod
from repro.experiments.spec import EpsilonSpec, JobSpec, SweepSpec
from repro.telemetry import metrics, trace

_ANALYTIC = metrics.counter("repro_service_analytic_answers_total",
                            help="probes answered by the analytic tier")
_ESCALATIONS = metrics.counter("repro_service_escalations_total",
                               help="probes escalated to a measured sweep")

#: default analytic-tier confidence gate — sits below
#: `fit.CONFIDENCE_PRIOR` (0.75) on purpose: a fresh service with no
#: measured history trusts the theory predictors; history that fits
#: poorly (low R^2 / big residuals) pulls confidence under the gate and
#: starts buying measurements
DEFAULT_CONFIDENCE_THRESHOLD = 0.5

#: default escalation sweep shape: the smallest grid that yields an
#: epsilon readout (probe_m=2 must be on the grid) and a measured m_max
DEFAULT_SWEEP_MS = (1, 2, 4)
DEFAULT_SWEEP_ITERS = 200
DEFAULT_SWEEP_EVAL_EVERY = 20


class TierRouter:
    """Confidence-gated routing between the analytic and measured tiers."""

    def __init__(self, *, confidence_threshold: float =
                 DEFAULT_CONFIDENCE_THRESHOLD,
                 cache_dir: Optional[str] = None,
                 cache_cap: Optional[int] = None,
                 parallel_cost: float = 1e-3,
                 sweep_ms=DEFAULT_SWEEP_MS,
                 sweep_iters: int = DEFAULT_SWEEP_ITERS,
                 sweep_eval_every: int = DEFAULT_SWEEP_EVAL_EVERY):
        self.threshold = float(confidence_threshold)
        self.cache_dir = cache_dir or artifact_cache.DEFAULT_CACHE_DIR
        self.cache_cap = cache_cap
        self.parallel_cost = parallel_cost
        self.sweep_ms = tuple(sweep_ms)
        self.sweep_iters = int(sweep_iters)
        self.sweep_eval_every = int(sweep_eval_every)
        self.advisor = advisor_mod.ScalabilityAdvisor(
            parallel_cost=parallel_cost)
        self._lock = threading.Lock()
        self._model: Optional[Dict] = None
        self._model_stale = True
        self.analytic_answers = 0
        self.escalations = 0

    # -- confidence model (characters->m_max regression over the cache) -----
    def refresh_model(self) -> Optional[Dict]:
        """(Re)fit the characters->m_max regression from every artifact in
        the cache directory; called lazily and after each escalation
        (every measured sweep is new history)."""
        results = []
        for path in artifact_cache.list_artifacts(self.cache_dir):
            try:
                with open(path) as f:
                    results.append(json.load(f))
            except (OSError, json.JSONDecodeError):
                continue
        points = FIT.collect_character_points(results)
        model = FIT.characters_regression(points)
        with self._lock:
            self._model = model
            self._model_stale = False
        return model

    @property
    def model(self) -> Optional[Dict]:
        with self._lock:
            stale = self._model_stale
        if stale:
            self.refresh_model()
        with self._lock:
            return self._model

    def confidence(self, ch: Optional[Dict], kind: str) -> Dict:
        """Confidence of an analytic answer for a probe with characters
        ``ch``.  Dataset probes consult the regression; gradient probes
        have no characters->m_max history and sit at the prior."""
        if kind == "dataset" and ch is not None:
            return FIT.analytic_confidence(self.model, ch)
        return {"confidence": FIT.CONFIDENCE_PRIOR, "source": "prior",
                "detail": "gradient-level probes carry no "
                          "characters->m_max history"}

    # -- tier 1: analytic answers from measured characters ------------------
    def analytic_dataset_report(self, ch: Dict, kwargs: Dict) -> Dict:
        """The `from_dataset` report built from pre-measured (batched)
        characters — identical formulas, so the batched answer matches
        the sequential one."""
        pc = kwargs.get("parallel_cost", self.parallel_cost)
        report = dict(ch)
        report["hogwild"] = FIT.predict_hogwild_from_characters(ch)
        report["sync"] = FIT.predict_sync_from_characters(
            ch, parallel_cost=pc)
        report["dadm"] = FIT.predict_dadm_from_characters(
            ch, parallel_cost=pc)
        report["momentum"] = FIT.predict_momentum_from_characters(
            ch, beta=kwargs.get("beta", 0.9), parallel_cost=pc)
        report["local_sgd"] = FIT.predict_local_sgd_from_characters(
            ch, sync_every=kwargs.get("sync_every", 4), parallel_cost=pc)
        report["svrg"] = FIT.predict_svrg_from_characters(
            ch, anchor_every=kwargs.get("anchor_every", 100))
        report["recommendation"] = self.advisor._recommend_dataset(report)
        report["valid"] = True
        with self._lock:
            self.analytic_answers += 1
        _ANALYTIC.inc()
        return report

    def analytic_grad_report(self, ch: Dict) -> Dict:
        """The `from_grads` report from pre-measured (batched) gradient
        characters — shares `_grad_report` so the answers are identical."""
        report = self.advisor._grad_report(dict(ch))
        with self._lock:
            self.analytic_answers += 1
        _ANALYTIC.inc()
        return report

    # -- tier 2: the measured sweep -----------------------------------------
    def escalation_spec(self, request) -> Optional[SweepSpec]:
        """The SweepSpec an escalated probe executes: the request's own
        sweep when it brought one, else a default probe sweep over its
        DatasetSpec.  None when the probe has no reproducible identity
        (raw arrays can't be fingerprinted into a spec)."""
        if getattr(request, "sweep", None) is not None:
            return request.sweep
        if getattr(request, "dataset", None) is None:
            return None
        return SweepSpec(
            name=f"service-{request.algorithm}",
            ms=self.sweep_ms, iters=self.sweep_iters,
            eval_every=self.sweep_eval_every,
            datasets={"probe": request.dataset},
            jobs=(JobSpec(algorithm=request.algorithm, dataset="probe",
                          kwargs=dict(request.kwargs), predict=True),),
            epsilon=EpsilonSpec(probe_m=2, frac=0.7))

    def escalate(self, request) -> Dict:
        """Run (or join) the measured sweep for an escalated probe.

        ``dedup=True`` collapses concurrent escalations sharing the
        fingerprint into one execution; the answer is then ALWAYS the
        stored artifact's bytes — the leader re-reads its own store — so
        every waiter receives the identical artifact."""
        sp = self.escalation_spec(request)
        assert sp is not None, "escalate() requires an escalatable request"
        fp = spec_mod.fingerprint(sp)
        with trace.span("escalate", spec=sp.name, fingerprint=fp[:12]):
            result = runner_mod.run_sweep(
                sp, cache_dir=self.cache_dir, dedup=True,
                cache_cap=self.cache_cap)
            art = artifact_cache.load(self.cache_dir, sp.name, fp) or result
        with self._lock:
            self.escalations += 1
            self._model_stale = True          # new measured history
        _ESCALATIONS.inc()
        job_key = next(iter(art.get("jobs", {})), None)
        for key in art.get("jobs", {}):
            if key.startswith(f"{request.algorithm}/"):
                job_key = key
                break
        job = art["jobs"].get(job_key, {}) if job_key else {}
        return {
            "sweep": sp.name,
            "fingerprint": fp,
            "artifact_path": artifact_cache.artifact_path(
                self.cache_dir, sp.name, fp),
            "cache_hit": bool(result.get("cache", {}).get("hit")),
            "job_key": job_key,
            "status": job.get("status", "ok"),
            "healthy": runner_mod.job_is_healthy(job) if job else False,
            "measured_m_max": job.get("measured_m_max"),
            "epsilon": job.get("epsilon"),
            "predicted": job.get("predicted"),
            "artifact": art,
        }

    def stats(self) -> Dict:
        with self._lock:
            return {"threshold": self.threshold,
                    "analytic_answers": self.analytic_answers,
                    "escalations": self.escalations,
                    "model": ("none" if self._model is None else
                              {"n_points": self._model["n_points"],
                               "r2": self._model["r2"],
                               "residual_rmse":
                                   self._model["residual_rmse"]})}
