"""CLI for the advisor service — probe a dataset spec through the tiers.

  PYTHONPATH=src python -m repro.service --generator higgs_like \\
      --n 128 --d 16                       # analytic tier (early exit)
  PYTHONPATH=src python -m repro.service --generator realsim_like \\
      --n 128 --d 16 --escalate            # force the measured sweep
  PYTHONPATH=src python -m repro.service --generator higgs_like \\
      --n 128 --d 16 --requests 4 --escalate   # 4 probes, ONE sweep
                                               # (single-flight dedup)
  PYTHONPATH=src python -m repro.service --serve 8787
                                               # HTTP advisor + /metrics

``--requests K`` issues K probes of the SAME dataset spec through
`AdvisorService.probe_batch`: their character measurements coalesce into
one masked-batch call, and — with ``--escalate`` — their sweeps share a
fingerprint, so exactly one executes (the stats line reports
``sweep_computes``).  ``--json`` prints the full response payloads;
default output is a per-probe summary plus the service stats.

``--serve PORT`` skips the one-shot probe and instead serves the advisor
over HTTP until interrupted (`repro.service.http.ServiceServer`):
``POST /probe`` and ``/probe_batch`` take the JSON shapes in
docs/service.md; ``GET /metrics`` / ``/healthz`` / ``/flight`` /
``/trace`` expose the process's telemetry.  Every service knob
(``--queue-depth``, ``--cache-dir``, ``--threshold``, ...) applies to
the served instance.  ``--host`` binds elsewhere than 127.0.0.1;
PORT 0 picks an ephemeral port (printed at startup).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.experiments.spec import DatasetSpec
from repro.service.api import AdvisorService, ProbeRequest
from repro.service.http import ServiceServer


def _summary(resp) -> str:
    line = (f"{resp.request_id}: status={resp.status} tier={resp.tier} "
            f"confidence={resp.confidence:.3f}")
    if resp.tier == "analytic" and resp.report.get("valid"):
        best = {k: resp.report[k]["predicted_m_max"]
                for k in ("hogwild", "sync", "dadm")}
        line += f" predicted_m_max={best}"
    if resp.escalation is not None:
        line += (f" measured_m_max={resp.escalation['measured_m_max']} "
                 f"cache_hit={resp.escalation['cache_hit']}")
    if resp.note:
        line += f"\n    note: {resp.note}"
    return line


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="probe the scalability-advisor service")
    p.add_argument("--generator", default="higgs_like",
                   help="dataset generator (see repro.data.synth)")
    p.add_argument("--n", type=int, default=128, help="dataset rows")
    p.add_argument("--d", type=int, default=16, help="dataset features")
    p.add_argument("--algorithm", default="hogwild",
                   help="algorithm whose sweep an escalation runs")
    p.add_argument("--requests", type=int, default=1,
                   help="number of identical probes to batch")
    p.add_argument("--escalate", action="store_true",
                   help="force the measured tier (tier 2)")
    p.add_argument("--no-escalate", action="store_true",
                   help="never escalate, whatever the confidence")
    p.add_argument("--threshold", type=float, default=None,
                   help="analytic-tier confidence gate override")
    p.add_argument("--cache-dir", default=None,
                   help="artifact cache directory (escalations + history)")
    p.add_argument("--cache-cap", type=int, default=None,
                   help="LRU artifact-count cap for the cache dir")
    p.add_argument("--queue-depth", type=int, default=32)
    p.add_argument("--n-slots", type=int, default=8,
                   help="batcher slot count")
    p.add_argument("--sweep-iters", type=int, default=200,
                   help="iterations of an escalated probe sweep")
    p.add_argument("--json", action="store_true",
                   help="print full response payloads as JSON")
    p.add_argument("--serve", metavar="PORT", type=int, default=None,
                   help="serve the advisor over HTTP on this port until "
                        "interrupted (0 = ephemeral port, printed at "
                        "startup) instead of running a one-shot probe")
    p.add_argument("--host", default="127.0.0.1",
                   help="--serve bind address (default 127.0.0.1)")
    args = p.parse_args(argv)

    kw = {}
    if args.threshold is not None:
        kw["confidence_threshold"] = args.threshold
    service = AdvisorService(
        n_slots=args.n_slots, queue_depth=args.queue_depth,
        cache_dir=args.cache_dir, cache_cap=args.cache_cap,
        sweep_iters=args.sweep_iters, **kw)

    if args.serve is not None:
        server = ServiceServer(service, host=args.host,
                               port=args.serve).start()
        print(f"advisor serving at {server.url} "
              f"(POST /probe /probe_batch; GET /metrics /healthz "
              f"/flight /trace) — ^C to stop", flush=True)
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            server.stop()
        return 0

    escalate = True if args.escalate else (False if args.no_escalate
                                           else None)
    ds = DatasetSpec(args.generator, {"n": args.n, "d": args.d})
    requests = [ProbeRequest(dataset=ds, algorithm=args.algorithm,
                             escalate=escalate)
                for _ in range(max(args.requests, 1))]
    responses = service.probe_batch(requests)

    if args.json:
        payload = {"responses": [r.to_dict() for r in responses],
                   "stats": service.stats()}
        # escalation artifacts are bulky; the path + fingerprint identify
        # them, so keep the JSON output bounded
        for r in payload["responses"]:
            if r.get("escalation"):
                r["escalation"].pop("artifact", None)
        json.dump(payload, sys.stdout, indent=2, default=float)
        print()
    else:
        for r in responses:
            print(_summary(r))
        print(f"stats: {json.dumps(service.stats(), default=float)}")
    return 0 if all(r.status in ("ok", "invalid") for r in responses) else 1


if __name__ == "__main__":
    sys.exit(main())
