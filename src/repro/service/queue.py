"""Bounded admission for the advisor service.

A long-lived service in front of real sweep execution needs back
pressure: an escalated probe holds device time for seconds, and an
unbounded queue just converts overload into unbounded latency.
`AdmissionQueue` is a counting-semaphore admission gate — ``try_admit``
never blocks; a ``False`` means the caller must answer with a structured
``overloaded`` response *now* (see `api.AdvisorService.probe_batch`),
and under-capacity requests are never affected by the shed ones.
"""

from __future__ import annotations

import threading
from typing import Dict

from repro.telemetry import metrics

_ADMITTED = metrics.counter("repro_service_admitted_total",
                            help="probe requests admitted past the gate")
_SHED = metrics.counter("repro_service_shed_total",
                        help="probe requests shed at admission (overload)")
_DEPTH = metrics.gauge("repro_service_queue_depth",
                       help="probes currently holding an admission slot")
_HIGH_WATER = metrics.gauge(
    "repro_service_queue_high_water",
    help="max concurrent in-service probes since process start")


class AdmissionQueue:
    """Non-blocking admission gate with a fixed depth.

    ``try_admit`` takes a slot if one is free (and counts the request);
    ``release`` returns it.  Shed requests are counted but never queued —
    load shedding is the contract, not buffering.  ``high_water`` is the
    deepest concurrent occupancy seen — the capacity-planning number: a
    high-water mark at ``depth`` with nonzero ``shed`` means the gate is
    actually clipping load, not just sized generously."""

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"queue depth {depth} must be >= 1")
        self.depth = int(depth)
        self._sem = threading.BoundedSemaphore(self.depth)
        self._lock = threading.Lock()
        self._in_service = 0
        self.admitted = 0
        self.shed = 0
        self.high_water = 0

    def try_admit(self) -> bool:
        ok = self._sem.acquire(blocking=False)
        with self._lock:
            if ok:
                self.admitted += 1
                self._in_service += 1
                if self._in_service > self.high_water:
                    self.high_water = self._in_service
                _DEPTH.set(self._in_service)
            else:
                self.shed += 1
        if ok:
            _ADMITTED.inc()
            _HIGH_WATER.set_max(self.high_water)
        else:
            _SHED.inc()
        return ok

    def release(self) -> None:
        with self._lock:
            self._in_service -= 1
            _DEPTH.set(self._in_service)
        self._sem.release()

    @property
    def in_service(self) -> int:
        with self._lock:
            return self._in_service

    def stats(self) -> Dict:
        with self._lock:
            return {"depth": self.depth, "in_service": self._in_service,
                    "admitted": self.admitted, "shed": self.shed,
                    "high_water": self.high_water}
