"""Bounded admission for the advisor service.

A long-lived service in front of real sweep execution needs back
pressure: an escalated probe holds device time for seconds, and an
unbounded queue just converts overload into unbounded latency.
`AdmissionQueue` is a counting-semaphore admission gate — ``try_admit``
never blocks; a ``False`` means the caller must answer with a structured
``overloaded`` response *now* (see `api.AdvisorService.probe_batch`),
and under-capacity requests are never affected by the shed ones.
"""

from __future__ import annotations

import threading
from typing import Dict


class AdmissionQueue:
    """Non-blocking admission gate with a fixed depth.

    ``try_admit`` takes a slot if one is free (and counts the request);
    ``release`` returns it.  Shed requests are counted but never queued —
    load shedding is the contract, not buffering."""

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"queue depth {depth} must be >= 1")
        self.depth = int(depth)
        self._sem = threading.BoundedSemaphore(self.depth)
        self._lock = threading.Lock()
        self._in_service = 0
        self.admitted = 0
        self.shed = 0

    def try_admit(self) -> bool:
        ok = self._sem.acquire(blocking=False)
        with self._lock:
            if ok:
                self.admitted += 1
                self._in_service += 1
            else:
                self.shed += 1
        return ok

    def release(self) -> None:
        with self._lock:
            self._in_service -= 1
        self._sem.release()

    @property
    def in_service(self) -> int:
        with self._lock:
            return self._in_service

    def stats(self) -> Dict:
        with self._lock:
            return {"depth": self.depth, "in_service": self._in_service,
                    "admitted": self.admitted, "shed": self.shed}
