"""Bounded admission for the advisor service.

A long-lived service in front of real sweep execution needs back
pressure: an escalated probe holds device time for seconds, and an
unbounded queue just converts overload into unbounded latency.
`AdmissionQueue` is a counting-semaphore admission gate — ``try_admit``
never blocks; a ``False`` means the caller must answer with a structured
``overloaded`` response *now* (see `api.AdvisorService.probe_batch`),
and under-capacity requests are never affected by the shed ones.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional

from repro.telemetry import metrics

_ADMITTED = metrics.counter("repro_service_admitted_total",
                            help="probe requests admitted past the gate")
_SHED = metrics.counter("repro_service_shed_total",
                        help="probe requests shed at admission (overload)")
_DEPTH = metrics.gauge("repro_service_queue_depth",
                       help="probes currently holding an admission slot")
_HIGH_WATER = metrics.gauge(
    "repro_service_queue_high_water",
    help="max concurrent in-service probes since last reset")
#: slot-hold durations: how long each admitted probe kept its admission
#: slot (analytic answers are sub-ms, escalations hold for a whole
#: sweep) — paired with shed_total this is the shedding-pressure story a
#: scrape window sees: long holds + a full gate = clipped load
_WAIT = metrics.histogram(
    "repro_service_queue_wait_seconds",
    help="seconds an admitted probe held its admission slot")


class AdmissionQueue:
    """Non-blocking admission gate with a fixed depth.

    ``try_admit`` takes a slot if one is free (and counts the request);
    ``release`` returns it.  Shed requests are counted but never queued —
    load shedding is the contract, not buffering.  ``high_water`` is the
    deepest concurrent occupancy seen — the capacity-planning number: a
    high-water mark at ``depth`` with nonzero ``shed`` means the gate is
    actually clipping load, not just sized generously."""

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"queue depth {depth} must be >= 1")
        self.depth = int(depth)
        self._sem = threading.BoundedSemaphore(self.depth)
        self._lock = threading.Lock()
        self._in_service = 0
        self.admitted = 0
        self.shed = 0
        self.high_water = 0

    def try_admit(self) -> Optional[float]:
        """Take a slot; returns an admission stamp (monotonic seconds, to
        hand back to :meth:`release` for the wait histogram) or None when
        the gate is full.  Truthiness is unchanged from the old bool
        return — ``if queue.try_admit():`` still reads correctly, since a
        perf_counter stamp is always > 0."""
        ok = self._sem.acquire(blocking=False)
        with self._lock:
            if ok:
                self.admitted += 1
                self._in_service += 1
                if self._in_service > self.high_water:
                    self.high_water = self._in_service
                _DEPTH.set(self._in_service)
            else:
                self.shed += 1
        if ok:
            _ADMITTED.inc()
            _HIGH_WATER.set_max(self.high_water)
            return time.perf_counter()
        _SHED.inc()
        return None

    def release(self, admitted_at: Optional[float] = None) -> None:
        """Return a slot; passing the stamp :meth:`try_admit` returned
        records the slot-hold duration in
        ``repro_service_queue_wait_seconds``."""
        if admitted_at is not None:
            _WAIT.observe(time.perf_counter() - admitted_at)
        with self._lock:
            self._in_service -= 1
            _DEPTH.set(self._in_service)
        self._sem.release()

    @property
    def in_service(self) -> int:
        with self._lock:
            return self._in_service

    def stats(self, reset: bool = False) -> Dict:
        """Queue counters; ``reset=True`` additionally re-arms the
        ``high_water`` mark to the *current* occupancy after reading, so
        a scraper polling ``stats(reset=True)`` per window sees the
        per-window peak instead of the since-start one.  The returned
        dict is always the pre-reset view."""
        with self._lock:
            out = {"depth": self.depth, "in_service": self._in_service,
                   "admitted": self.admitted, "shed": self.shed,
                   "high_water": self.high_water}
            if reset:
                self.high_water = self._in_service
                _HIGH_WATER.set(self._in_service)
        return out
