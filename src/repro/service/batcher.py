"""Probe batching front end: N concurrent dataset-character probes ->
ONE jitted masked-batch call.

Built on `serve.SlotDriver` — the continuous-batching-lite driver of the
serving tier.  The slot state is a fixed ``(n_slots, max_rows,
max_cols)`` envelope plus row/column validity masks; each admitted probe
pads its dataset into a free slot, and one driver step runs
`core.advisor.masked_dataset_characters` over the whole slot batch (one
jitted dispatch regardless of occupancy — padded slots are exact no-ops
because every reduction is mask-weighted).  Character probes finish in a
single step, so the driver's role here is the admission/masking
contract, shared verbatim with the LM serving loop.

Probes larger than the envelope can't ride the fixed-shape slot state;
they fall back to `ScalabilityAdvisor.dataset_characters_batch` (the
group-envelope masked batch — same kernel, per-group shapes) and are
counted in ``stats()["fallback"]``.

The one §IV character that can't be masked-batched is ``diversity``
(exact row dedup — `np.unique` has no fixed-shape analogue); it is
finished host-side per probe, exactly as the scalar path does.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import advisor as advisor_mod
from repro.core import metrics as MX
from repro.serve.engine import SlotDriver

#: the (n_slots,)-shaped characters the masked kernel produces; the
#: batcher turns each slot's slice into the scalar dict the
#: `analysis.fit` ``*_from_characters`` predictors consume
CHARACTER_KEYS = ("n", "d", "mean_feature_variance", "sparsity",
                  "density", "omega", "omega_frac", "delta", "rho")


class ProbeBatcher:
    """Coalesce dataset-character probes into slot-batched jitted calls."""

    def __init__(self, n_slots: int = 8, max_rows: int = 512,
                 max_cols: int = 64):
        self.n_slots = int(n_slots)
        self.max_rows = int(max_rows)
        self.max_cols = int(max_cols)
        self._advisor = advisor_mod.ScalabilityAdvisor()
        self.n_batched = 0
        self.n_fallback = 0
        self.n_steps = 0

        init_state = {
            "X": jnp.zeros((n_slots, max_rows, max_cols), jnp.float32),
            "row_mask": jnp.zeros((n_slots, max_rows), jnp.float32),
            "col_mask": jnp.zeros((n_slots, max_cols), jnp.float32),
            "characters": {k: jnp.zeros((n_slots,), jnp.float32)
                           for k in CHARACTER_KEYS},
        }

        def step_fn(state, active):
            ch = advisor_mod.masked_dataset_characters(
                state["X"], state["row_mask"], state["col_mask"])
            new_state = dict(state, characters=ch)
            # character probes are single-step: every active slot is done
            return new_state, jnp.ones((self.n_slots,), bool)

        self.driver = SlotDriver(step_fn, init_state, n_slots)

    # -- helpers ------------------------------------------------------------
    def _payload(self, X: np.ndarray) -> Dict:
        r, c = X.shape
        Xp = np.zeros((self.max_rows, self.max_cols), np.float32)
        Xp[:r, :c] = np.asarray(X, np.float32)
        rm = np.zeros(self.max_rows, np.float32)
        rm[:r] = 1.0
        cm = np.zeros(self.max_cols, np.float32)
        cm[:c] = 1.0
        return {"X": jnp.asarray(Xp), "row_mask": jnp.asarray(rm),
                "col_mask": jnp.asarray(cm)}

    @staticmethod
    def _finish(ch: Dict, X) -> Dict:
        """Scalar-ize a slot's character slice and add the host-side
        exact-dedup diversity indices."""
        out = {k: (int(ch[k]) if k in ("n", "d") else float(ch[k]))
               for k in CHARACTER_KEYS}
        out["diversity"] = MX.diversity(X)
        out["diversity_ratio"] = out["diversity"] / max(out["n"], 1)
        return out

    # -- the batched measurement --------------------------------------------
    def measure(self, items: List[Tuple[object, np.ndarray]]
                ) -> Dict[object, Optional[Dict]]:
        """Characters for every (request_id, X) item, batched through the
        slot driver; invalid datasets map to None (the caller pairs them
        with `ScalabilityAdvisor.invalid_report`).  Items beyond
        ``n_slots`` recycle freed slots across extra steps — admission
        never blocks, it waits for the next step's free slots."""
        results: Dict[object, Optional[Dict]] = {}
        fallback: List[Tuple[object, np.ndarray]] = []
        pending: List[Tuple[object, np.ndarray]] = []
        for rid, X in items:
            reason = self._advisor.validate_dataset(X)
            if reason is not None:
                results[rid] = None
            elif (X.shape[0] > self.max_rows or X.shape[1] > self.max_cols):
                fallback.append((rid, X))
            else:
                pending.append((rid, np.asarray(X)))

        by_id = {rid: X for rid, X in pending}
        pending = list(pending)
        while pending or self.driver.n_active:
            while pending:
                rid, X = pending[0]
                if self.driver.admit(rid, self._payload(X)) is None:
                    break                     # slots full; step frees them
                pending.pop(0)
                self.n_batched += 1
            for rid, out in self.driver.step():
                ch = {k: out["characters"][k] for k in CHARACTER_KEYS}
                results[rid] = self._finish(ch, by_id[rid])
            self.n_steps += 1

        if fallback:
            # oversized probes: group-envelope masked batch (same kernel)
            self.n_fallback += len(fallback)
            chs = self._advisor.dataset_characters_batch(
                [X for _, X in fallback])
            for (rid, _), ch in zip(fallback, chs):
                results[rid] = ch
        return results

    def stats(self) -> Dict:
        return {"n_slots": self.n_slots,
                "envelope": [self.max_rows, self.max_cols],
                "batched": self.n_batched, "fallback": self.n_fallback,
                "steps": self.n_steps}
