"""HTTP transport for the advisor service + the live observability plane.

ROADMAP item 2's missing piece: `AdvisorService` could only be called
in-process.  This module serves it — and the process's telemetry — over
a stdlib ``http.server`` transport (no new dependencies; the container
pins the environment), with the bounded-admission, batching, and
single-flight semantics *unchanged underneath*: the handler only
decodes JSON into `ProbeRequest` and calls the same `probe` /
`probe_batch` the in-process tests pin, so overload still answers
``status="overloaded"`` and concurrent identical escalations still
execute one sweep.

Endpoints (docs/service.md has request/response shapes and curl
examples; docs/observability.md the scrape side):

  ===========  ======  ==================================================
  path         method  serves
  ===========  ======  ==================================================
  /probe       POST    one JSON ProbeRequest -> one ProbeResponse
  /probe_batch POST    {"requests": [...]} -> {"responses": [...]}
  /metrics     GET     Prometheus text v0.0.4 from the process registry
                       (``?prefix=repro_service`` filters families)
  /healthz     GET     liveness + admission-queue depth/shed state
  /flight      GET     flight-recorder snapshot (``?since=SEQ`` tails)
  /trace       GET     the tracer's Chrome-trace JSON (``?drain=1`` pops
                       the recorded spans so a poller exports
                       incrementally)
  ===========  ======  ==================================================

`ServiceServer` wraps `ThreadingHTTPServer` (thread per request — the
admission queue is the concurrency bound, exactly as for in-process
callers) behind ``start()``/``stop()`` and a context manager; ``port=0``
binds an ephemeral port reported by ``.port`` (tests, and parallel CI
jobs).  Construct it with ``service=None`` for a *metrics-only* plane —
the sweep CLI's ``run.py --serve PORT`` does this so a long sweep can be
watched (``/metrics``, ``/flight``, ``/trace``) without the advisor
front end; probe endpoints then answer 503.

Observational contract: the transport reads registry/recorder/tracer
state beside the sweep's computation — artifact bytes are identical with
the server scraping or absent (tests/test_http.py).
"""

from __future__ import annotations

import json
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

from repro.experiments.spec import DatasetSpec
from repro.telemetry import metrics, recorder, trace

#: refuse request bodies beyond this (a raw-X probe of service envelope
#: scale is ~1 MB of JSON; anything bigger is abuse, not a probe)
MAX_BODY_BYTES = 16 * 1024 * 1024

_REQUESTS = {}


def _request_counter(method: str, path: str):
    key = (method, path)
    if key not in _REQUESTS:
        _REQUESTS[key] = metrics.counter(
            "repro_http_requests_total",
            help="HTTP requests served by the observability transport",
            labels={"method": method, "path": path})
    return _REQUESTS[key]


_LATENCY = metrics.histogram(
    "repro_http_request_seconds",
    help="HTTP request handling latency")


class _BadRequest(ValueError):
    """Client error — rendered as a structured 400 JSON body."""


def decode_probe_request(payload: Dict) -> "ProbeRequest":
    """JSON dict -> ProbeRequest.  Wire shape (docs/service.md):

    ``{"X": [[...]], "dataset": {"generator", "kwargs", "seed",
    "shuffle_split", "variant"}, "algorithm", "escalate", "kwargs",
    "request_id"}`` — exactly one of ``X`` / ``dataset`` (full SweepSpec
    probes remain in-process-only: a JSON SweepSpec codec is not worth
    its ambiguity, and a DatasetSpec already reaches the measured tier).
    """
    from repro.service.api import ProbeRequest    # cycle: api imports queue

    if not isinstance(payload, dict):
        raise _BadRequest("probe payload must be a JSON object")
    unknown = set(payload) - {"X", "dataset", "algorithm", "escalate",
                              "kwargs", "request_id"}
    if unknown:
        raise _BadRequest(f"unknown probe fields {sorted(unknown)}")
    dataset = None
    if payload.get("dataset") is not None:
        d = payload["dataset"]
        if not isinstance(d, dict) or "generator" not in d:
            raise _BadRequest('"dataset" must be {"generator": ..., '
                              '"kwargs": {...}, ...}')
        bad = set(d) - {"generator", "kwargs", "seed", "shuffle_split",
                        "variant"}
        if bad:
            raise _BadRequest(f"unknown dataset fields {sorted(bad)}")
        try:
            dataset = DatasetSpec(
                generator=d["generator"], kwargs=dict(d.get("kwargs", {})),
                seed=int(d.get("seed", 0)),
                shuffle_split=bool(d.get("shuffle_split", True)),
                variant=d.get("variant"))
            dataset.validate()
        except (KeyError, TypeError, ValueError) as e:
            raise _BadRequest(f"invalid dataset spec: {e}") from e
    escalate = payload.get("escalate")
    if escalate is not None and not isinstance(escalate, bool):
        raise _BadRequest('"escalate" must be true, false, or omitted')
    kw = {"X": payload.get("X"), "dataset": dataset,
          "algorithm": payload.get("algorithm", "hogwild"),
          "escalate": escalate,
          "kwargs": dict(payload.get("kwargs", {}))}
    if payload.get("request_id") is not None:
        kw["request_id"] = str(payload["request_id"])
    return ProbeRequest(**kw)


def encode_probe_response(resp, *, full_artifact: bool = False) -> Dict:
    """ProbeResponse -> wire dict; escalation artifacts are bulky and
    fully identified by path + fingerprint, so they stay server-side
    unless explicitly requested."""
    out = resp.to_dict()
    if out.get("escalation") and not full_artifact:
        out["escalation"].pop("artifact", None)
    return out


class _Handler(BaseHTTPRequestHandler):
    # ThreadingHTTPServer default is HTTP/1.0-style close-per-request;
    # keep that (curl and scrapers reconnect) but answer protocol 1.1
    protocol_version = "HTTP/1.1"

    # -- plumbing ------------------------------------------------------------
    def log_message(self, fmt, *args):          # noqa: N802 — stdlib name
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _send(self, code: int, body: bytes, content_type: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, payload) -> None:
        body = json.dumps(payload, indent=1, default=float).encode()
        self._send(code, body, "application/json; charset=utf-8")

    def _send_error_json(self, code: int, message: str) -> None:
        self._send_json(code, {"error": message})

    def _read_json_body(self):
        length = self.headers.get("Content-Length")
        if length is None:
            raise _BadRequest("Content-Length required")
        n = int(length)
        if n > MAX_BODY_BYTES:
            raise _BadRequest(f"body too large ({n} > {MAX_BODY_BYTES})")
        raw = self.rfile.read(n)
        try:
            return json.loads(raw)
        except json.JSONDecodeError as e:
            raise _BadRequest(f"invalid JSON body: {e}") from e

    def _route(self, method: str) -> None:
        url = urllib.parse.urlsplit(self.path)
        query = dict(urllib.parse.parse_qsl(url.query))
        t0 = time.perf_counter()
        try:
            handler = getattr(self, f"_{method}_{url.path.strip('/')}",
                              None)
            if handler is None:
                self._send_error_json(
                    404, f"no {method} route {url.path!r}; serving "
                         f"/probe /probe_batch (POST), /metrics /healthz "
                         f"/flight /trace (GET)")
                return
            _request_counter(method, url.path).inc()
            handler(query)
        except _BadRequest as e:
            self._send_error_json(400, str(e))
        except BrokenPipeError:
            pass                                  # client went away
        except Exception as e:                    # noqa: BLE001 — transport
            # must answer, not die: a handler bug becomes a structured 500
            self._send_error_json(
                500, f"{type(e).__name__}: {e}")
        finally:
            _LATENCY.observe(time.perf_counter() - t0)

    def do_GET(self):                             # noqa: N802 — stdlib name
        self._route("GET")

    def do_POST(self):                            # noqa: N802 — stdlib name
        self._route("POST")

    # -- the advisor front end ----------------------------------------------
    def _POST_probe(self, query):                 # noqa: N802
        svc = self.server.service
        if svc is None:
            self._send_error_json(
                503, "no advisor configured: this is a metrics-only "
                     "observability plane (run.py --serve); POST probes "
                     "to a python -m repro.service --serve instance")
            return
        req = decode_probe_request(self._read_json_body())
        resp = svc.probe(req)
        self._send_json(200, encode_probe_response(
            resp, full_artifact=query.get("full") == "1"))

    def _POST_probe_batch(self, query):           # noqa: N802
        svc = self.server.service
        if svc is None:
            self._send_error_json(
                503, "no advisor configured: this is a metrics-only "
                     "observability plane (run.py --serve)")
            return
        payload = self._read_json_body()
        if not isinstance(payload, dict) or \
                not isinstance(payload.get("requests"), list):
            raise _BadRequest('body must be {"requests": [...]}')
        reqs = [decode_probe_request(p) for p in payload["requests"]]
        resps = svc.probe_batch(reqs)
        self._send_json(200, {"responses": [
            encode_probe_response(r, full_artifact=query.get("full") == "1")
            for r in resps]})

    # -- the observability plane --------------------------------------------
    def _GET_metrics(self, query):                # noqa: N802
        text = metrics.REGISTRY.render_prometheus(
            prefix=query.get("prefix", ""))
        self._send(200, (text or "# (registry empty)\n").encode(),
                   "text/plain; version=0.0.4; charset=utf-8")

    def _GET_healthz(self, query):                # noqa: N802
        svc = self.server.service
        qstats = (svc.queue.stats(reset=query.get("reset") == "1")
                  if svc is not None else None)
        overloaded = bool(qstats) and \
            qstats["in_service"] >= qstats["depth"]
        self._send_json(200, {
            "status": "overloaded" if overloaded else "ok",
            "service": svc is not None,
            "uptime_s": time.time() - self.server.t0,
            "queue": qstats,
            "recorder": recorder.RECORDER.stats(),
            "tracing": trace.enabled(),
        })

    def _GET_flight(self, query):                 # noqa: N802
        try:
            since = int(query.get("since", 0))
            limit = int(query["limit"]) if "limit" in query else None
        except ValueError as e:
            raise _BadRequest(f"since/limit must be integers: {e}") from e
        self._send_json(200, recorder.RECORDER.snapshot(
            since=since, limit=limit))

    def _GET_trace(self, query):                  # noqa: N802
        tracer = trace.active() or trace.last()
        if tracer is None:
            payload = {"traceEvents": [], "displayTimeUnit": "ms",
                       "otherData": {"producer": "repro.telemetry",
                                     "note": "no tracer has run"}}
        elif query.get("drain") == "1":
            payload = {"traceEvents": tracer.drain(),
                       "displayTimeUnit": "ms",
                       "otherData": {"producer": "repro.telemetry",
                                     "clock": "perf_counter",
                                     "drained": True}}
        else:
            payload = tracer.payload()
        self._send_json(200, payload)


class ServiceServer:
    """Owns the ThreadingHTTPServer + its serve thread.

    ``service=None`` serves the observability plane only.  ``port=0``
    binds an ephemeral port (read ``.port`` after construction)."""

    def __init__(self, service=None, *, host: str = "127.0.0.1",
                 port: int = 0, verbose: bool = False):
        self.service = service
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.service = service
        self._httpd.verbose = verbose
        self._httpd.t0 = time.time()
        # request threads must not block interpreter exit mid-sweep
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ServiceServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-service-http",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._thread.join(timeout=5)
        self._httpd.server_close()
        self._thread = None

    def __enter__(self) -> "ServiceServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
