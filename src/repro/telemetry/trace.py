"""Context-var span tracer with Chrome-trace / Perfetto JSON export.

The engine's wall-clock has always been opaque: an `engine_default`
sweep spends its time in some mix of tracing, XLA compilation, device
execution, journal fsyncs, and cache IO, and until now the only way to
attribute it was ad-hoc ``time.perf_counter()`` pairs.  This module
turns the hot paths into *spans* — named, nested, timestamped intervals
— which export directly into the ``traceEvents`` JSON that
``chrome://tracing`` and https://ui.perfetto.dev load natively.

Design constraints (docs/observability.md):

  * **Zero overhead when disabled.**  Tracing is off by default;
    :func:`span` then returns a shared no-op context manager — one
    module-global read and one ``is None`` check on the hot path, no
    allocation, no clock read.  The instrumented code runs the exact
    same statements either way (the observational contract: artifacts
    are byte-identical with tracing on or off).
  * **Thread-safe, nesting-correct.**  The current span stack lives in
    a `contextvars.ContextVar`, so concurrent `repro.service` threads
    (and dedup leader/waiter races) each carry their own stack; the
    recorded events carry the thread id and nesting depth, and children
    are always contained in their parent's interval on the same thread
    (pinned in tests/test_telemetry.py).
  * **One tracer at a time.**  :func:`start` installs the process-wide
    tracer, :func:`stop` uninstalls it but keeps it addressable as the
    *last* tracer so :func:`export` after ``stop()`` writes the
    completed trace.

Usage::

    from repro.telemetry import trace
    trace.start()
    with trace.span("sweep", name="upper_bound"):
        with trace.span("bucket", m_pad=8):
            ...
    trace.stop()
    trace.export("out.json")          # Chrome-trace JSON

Span taxonomy (what the instrumented repo emits) is documented in
docs/observability.md; :func:`phase_breakdown` aggregates a trace's
spans per name for the report's phase table and the
``python -m repro.telemetry --summarize`` CLI.
"""

from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Dict, List, Optional

#: (start_ns, depth) tuples of the enclosing spans for the current
#: execution context — contextvars give each thread (and each asyncio
#: task, should the service ever grow one) its own stack
_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_trace_stack", default=())


#: registered span sinks — callables fed every completed span event while
#: a tracer is installed (the flight recorder mirrors spans this way);
#: sinks must be cheap and never raise
_SPAN_SINKS: List = []


def add_span_sink(fn) -> None:
    """Register a callback receiving every completed span's event dict.
    Idempotent per callable; only fires while a tracer is installed."""
    if fn not in _SPAN_SINKS:
        _SPAN_SINKS.append(fn)


def remove_span_sink(fn) -> None:
    if fn in _SPAN_SINKS:
        _SPAN_SINKS.remove(fn)


class Tracer:
    """Collects completed spans as Chrome-trace ``X`` (complete) events."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: List[Dict] = []
        self.t0_ns = time.perf_counter_ns()

    def record(self, name: str, start_ns: int, dur_ns: int, depth: int,
               args: Dict) -> None:
        ev = {
            "name": name,
            "ph": "X",
            # Chrome-trace timestamps are microseconds (float ok)
            "ts": (start_ns - self.t0_ns) / 1e3,
            "dur": dur_ns / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "cat": "repro",
            "args": dict(args, depth=depth),
        }
        with self._lock:
            self._events.append(ev)
        for sink in _SPAN_SINKS:
            sink(ev)

    @property
    def events(self) -> List[Dict]:
        with self._lock:
            return list(self._events)

    def drain(self) -> List[Dict]:
        """Pop and return every recorded span (the HTTP ``/trace?drain=1``
        path — a poller that exports incrementally without holding the
        whole run in tracer memory)."""
        with self._lock:
            evs, self._events = self._events, []
            return evs

    def payload(self) -> Dict:
        """The exported JSON object (Chrome-trace "JSON Object Format")."""
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.telemetry",
                          "clock": "perf_counter"},
        }

    def export(self, path: str) -> str:
        payload = self.payload()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return path


class _Span:
    """Live span context manager — records itself on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_depth", "_token")

    def __init__(self, tracer: Tracer, name: str, args: Dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        stack = _STACK.get()
        self._depth = len(stack)
        self._t0 = time.perf_counter_ns()
        self._token = _STACK.set(stack + ((self._name, self._t0),))
        return self

    def set(self, **attrs):
        """Attach attributes discovered mid-span (e.g. a result size)."""
        self._args.update(attrs)
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        _STACK.reset(self._token)
        self._tracer.record(self._name, self._t0, dur, self._depth,
                            self._args)
        return False


class _NoopSpan:
    """The disabled-mode span: enter/exit/set are all no-ops.  One shared
    instance — `span()` with tracing off allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _NoopSpan()

#: the installed tracer (None = disabled) and the last one installed —
#: export() after stop() still writes the completed trace
_ACTIVE: Optional[Tracer] = None
_LAST: Optional[Tracer] = None
_INSTALL_LOCK = threading.Lock()


def start() -> Tracer:
    """Install (and return) a fresh process-wide tracer."""
    global _ACTIVE, _LAST
    with _INSTALL_LOCK:
        _ACTIVE = _LAST = Tracer()
        return _ACTIVE


def stop() -> Optional[Tracer]:
    """Uninstall the tracer; it stays addressable via :func:`last` /
    :func:`export`.  Returns the stopped tracer (None if none ran)."""
    global _ACTIVE
    with _INSTALL_LOCK:
        t, _ACTIVE = _ACTIVE, None
        return t


def active() -> Optional[Tracer]:
    """The installed tracer, or None when tracing is disabled."""
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


def last() -> Optional[Tracer]:
    """The most recently installed tracer (running or stopped)."""
    return _LAST


def span(name: str, /, **args) -> "_Span | _NoopSpan":
    """Context manager for one named span.  With tracing disabled this is
    a shared no-op — the caller's code path is identical either way."""
    tracer = _ACTIVE
    if tracer is None:
        return _NOOP
    return _Span(tracer, name, args)


def export(path: str) -> Optional[str]:
    """Write the last tracer's Chrome-trace JSON; None if nothing traced."""
    t = _LAST
    if t is None:
        return None
    return t.export(path)


# ---------------------------------------------------------------------------
# trace analysis (shared by the report section and the --summarize CLI)
# ---------------------------------------------------------------------------

def phase_breakdown(events: List[Dict],
                    root: Optional[str] = None) -> Dict:
    """Aggregate a trace's spans per name; optionally scoped to the last
    top-level span called ``root`` (e.g. ``"sweep"``).

    Returns ``{"root": {...} | None, "wall_us", "coverage",
    "phases": {name: {"total_us", "count", "frac_of_wall"}}}`` where
    ``coverage`` is the fraction of the wall interval covered by the
    union of top-level (depth-0) spans — the acceptance metric for "the
    trace attributes >= 95% of the run".
    """
    evs = [e for e in events if e.get("ph") == "X"]
    if not evs:
        return {"root": None, "wall_us": 0.0, "coverage": 0.0, "phases": {}}
    wall_lo = min(e["ts"] for e in evs)
    wall_hi = max(e["ts"] + e["dur"] for e in evs)
    wall = wall_hi - wall_lo

    root_ev = None
    if root is not None:
        roots = [e for e in evs if e["name"] == root]
        if roots:
            root_ev = max(roots, key=lambda e: e["ts"])
            lo, hi = root_ev["ts"], root_ev["ts"] + root_ev["dur"]
            evs = [e for e in evs
                   if e["tid"] == root_ev["tid"]
                   and e["ts"] >= lo and e["ts"] + e["dur"] <= hi + 1e-6]

    # coverage = union of the attributing spans over the reference wall:
    # with a root, its direct children over the root's own interval
    # (how much of the sweep the child phases attribute); without one,
    # the top-level (depth-0) spans over the whole trace wall (how much
    # of the run the trace attributes at all)
    cov_depth = (root_ev["args"].get("depth", 0) + 1) if root_ev else 0
    tops = sorted(
        ((e["ts"], e["ts"] + e["dur"]) for e in evs
         if e.get("args", {}).get("depth", 0) == cov_depth),
        key=lambda iv: iv[0])
    covered, cur_lo, cur_hi = 0.0, None, None
    for lo, hi in tops:
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                covered += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        covered += cur_hi - cur_lo
    denom = root_ev["dur"] if root_ev else wall

    phases: Dict[str, Dict] = {}
    for e in evs:
        if e is root_ev:
            continue
        p = phases.setdefault(e["name"], {"total_us": 0.0, "count": 0})
        p["total_us"] += e["dur"]
        p["count"] += 1
    for p in phases.values():
        p["frac_of_wall"] = p["total_us"] / denom if denom else 0.0
    return {
        "root": root_ev["name"] if root_ev else None,
        "wall_us": denom,
        "coverage": covered / denom if denom else 0.0,
        "phases": phases,
    }
