"""repro.telemetry — spans, metrics, and profiling for the sweep stack.

Three pieces (docs/observability.md):

  * `repro.telemetry.trace` — a context-var span tracer.  Off by
    default; `trace.start()` installs it, instrumented hot paths then
    emit nested spans (sweep -> job -> bucket -> lower/compile/execute,
    journal/cache IO, service tiers), and `trace.export(path)` writes
    Chrome-trace / Perfetto JSON.  With tracing off every `span()` is a
    shared no-op — the observational contract: the sweep path executes
    the same code and produces byte-identical artifacts either way.
  * `repro.telemetry.metrics` — an always-on, thread-safe registry of
    named counters / gauges / histograms with JSON and Prometheus text
    exposition.  It absorbs the legacy racy module globals:
    ``engine.JIT_CALLS`` and ``runner.SWEEP_COMPUTES`` are now
    registry-backed read aliases (existing reads stay source-
    compatible; increments are locked).
  * `repro.telemetry.instrument` — jax-aware helpers, notably the
    per-bucket compile-vs-execute dispatch split (AOT lower/compile,
    bit-identical results).

CLI: ``python -m repro.telemetry`` dumps the process registry;
``--summarize trace.json`` validates + phase-breaks a saved trace.

This package deliberately has **no repro-internal imports** (and jax
only inside `instrument`), so any module — core, experiments,
distributed, service — can instrument itself without cycles.
"""

from repro.telemetry import recorder, trace
from repro.telemetry.metrics import (REGISTRY, Counter, Gauge, Histogram,
                                     MetricsRegistry, counter, gauge,
                                     histogram)
from repro.telemetry.recorder import RECORDER
from repro.telemetry.trace import span

# the flight recorder mirrors completed spans whenever a tracer runs, so
# recent span history is scrapeable (GET /flight) without draining the
# tracer itself
trace.add_span_sink(RECORDER.record_span)

__all__ = [
    "trace", "span",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "counter", "gauge", "histogram",
    "recorder", "RECORDER",
]
