"""Thread-safe metrics registry: named counters, gauges, histograms.

The repo's only counters used to be racy module globals
(``engine.JIT_CALLS``, ``runner.SWEEP_COMPUTES``) that the PR-8
multi-threaded service mutated without a lock.  This registry replaces
them with first-class metrics:

  * :class:`Counter` — monotone, ``inc(n)`` under a per-metric lock, so
    N threads incrementing concurrently always land exactly N (the
    single-flight tests read exact deltas under 6 threads);
  * :class:`Gauge` — last-write-wins scalar (``set``/``inc``/``dec``),
    with a ``set_max`` helper for high-water marks;
  * :class:`Histogram` — fixed cumulative buckets + count + sum, the
    Prometheus shape (service tier latencies, confidence distribution).

Metrics are identified by ``(name, labels)`` — labels are an optional
frozen dict, Prometheus-style (``repro_service_tier_latency_seconds
{tier="analytic"}``).  Accessors are get-or-create and idempotent:
``counter("x")`` anywhere returns the same object, so instrumented
modules never need registration order.  A kind clash (``counter`` vs an
existing gauge of the same name) raises — silent aliasing would corrupt
both.

Exposition: :meth:`MetricsRegistry.to_dict` (JSON-able snapshot, the
service ``stats`` block and ``--json`` consumers) and
:meth:`MetricsRegistry.render_prometheus` (text format v0.0.4 —
``# HELP`` / ``# TYPE`` / samples — for ``python -m repro.telemetry``
and, later, a real ``/metrics`` endpoint once the service grows an HTTP
transport, see ROADMAP).

Metrics are **always on** (unlike spans): an increment is a lock +
integer add, a few of which happen per *sweep* — never per iteration —
so the registry costs nothing measurable on the hot path (bounded in
`scripts/bench_engine.py`'s telemetry section).

The process-default registry is :data:`REGISTRY`; the module-level
:func:`counter` / :func:`gauge` / :func:`histogram` helpers target it.
"""

from __future__ import annotations

import re
import threading
from typing import Dict, Iterable, List, Optional, Tuple

#: default histogram buckets — latency-flavored seconds, wide enough for
#: both a sub-ms analytic probe and a multi-second escalation sweep
DEFAULT_BUCKETS = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0)

LabelItems = Tuple[Tuple[str, str], ...]

#: Prometheus data-model identifiers (text format v0.0.4): metric names
#: may carry colons (recording rules), label names may not
_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _label_key(labels: Optional[Dict[str, str]]) -> LabelItems:
    if not labels:
        return ()
    items = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
    for k, _ in items:
        if not _LABEL_NAME_RE.match(k):
            raise ValueError(f"invalid Prometheus label name {k!r}")
    return items


def _escape_label_value(v: str) -> str:
    # text-format escaping for quoted label values: backslash, quote, LF
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(text: str) -> str:
    # HELP lines escape backslash and LF only (quotes are legal there)
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _label_str(items: LabelItems) -> str:
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape_label_value(v)}"'
                          for k, v in items) + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, labels: LabelItems, help: str):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid Prometheus metric name {name!r}")
        self.name = name
        self.labels = labels
        self.help = help
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonically increasing integer-ish counter."""

    kind = "counter"

    def __init__(self, name, labels=(), help=""):
        super().__init__(name, labels, help)
        self._value = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value

    def snapshot(self):
        return self.value


class Gauge(_Metric):
    """Last-write-wins scalar."""

    kind = "gauge"

    def __init__(self, name, labels=(), help=""):
        super().__init__(name, labels, help)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def set_max(self, v: float) -> None:
        """High-water-mark update: keep the larger of current and ``v``."""
        with self._lock:
            self._value = max(self._value, float(v))

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self):
        return self.value


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus shape)."""

    kind = "histogram"

    def __init__(self, name, labels=(), help="",
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, labels, help)
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError(f"histogram {name} needs >= 1 bucket bound")
        self._counts = [0] * (len(self.bounds) + 1)   # +inf tail
        self._sum = 0.0
        self._n = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._sum += v
            self._n += 1
            for i, b in enumerate(self.bounds):
                if v <= b:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._n

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self) -> Dict:
        with self._lock:
            cumulative, acc = [], 0
            for c in self._counts:
                acc += c
                cumulative.append(acc)
            return {
                "buckets": {str(b): cumulative[i]
                            for i, b in enumerate(self.bounds)},
                "+inf": cumulative[-1],
                "count": self._n,
                "sum": self._sum,
            }


class MetricsRegistry:
    """Get-or-create store of metrics, keyed by (name, labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelItems], _Metric] = {}

    def _get(self, cls, name: str, labels, help: str, **kw) -> _Metric:
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], help, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict] = None) -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict] = None) -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict] = None,
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, labels, help, buckets=buckets)

    def _items(self) -> List[Tuple[Tuple[str, LabelItems], _Metric]]:
        with self._lock:
            return sorted(self._metrics.items())

    def to_dict(self, prefix: str = "") -> Dict:
        """JSON-able snapshot ``{name{labels}: value-or-histogram}``,
        optionally filtered by name prefix."""
        out: Dict = {}
        for (name, labels), m in self._items():
            if prefix and not name.startswith(prefix):
                continue
            out[name + _label_str(labels)] = m.snapshot()
        return out

    def render_prometheus(self, prefix: str = "") -> str:
        """Prometheus text exposition format v0.0.4.

        Conformance details real scrapers depend on (pinned by the
        strict-parser test in tests/test_telemetry.py): one ``# TYPE``
        (and ``# HELP``, taken from any series that carries one) per
        metric family, emitted before its samples; label values escaped
        (backslash/quote/newline); histograms expose cumulative
        ``_bucket`` series including the ``+Inf`` bucket plus ``_sum``
        and ``_count``; a trailing newline ends the exposition."""
        # HELP can live on any series of a family (get-or-create sites
        # may pass it only once); resolve it family-wide first
        helps: Dict[str, str] = {}
        for (name, _), m in self._items():
            if m.help and name not in helps:
                helps[name] = m.help
        lines: List[str] = []
        seen_header = set()
        for (name, labels), m in self._items():
            if prefix and not name.startswith(prefix):
                continue
            if name not in seen_header:
                seen_header.add(name)
                if helps.get(name):
                    lines.append(
                        f"# HELP {name} {_escape_help(helps[name])}")
                lines.append(f"# TYPE {name} {m.kind}")
            ls = _label_str(labels)
            if isinstance(m, Histogram):
                snap = m.snapshot()
                base = dict(labels)
                for b, c in snap["buckets"].items():
                    lines.append(
                        f"{name}_bucket"
                        f"{_label_str(_label_key(dict(base, le=b)))} {c}")
                lines.append(
                    f"{name}_bucket"
                    f'{_label_str(_label_key(dict(base, le="+Inf")))} '
                    f'{snap["+inf"]}')
                lines.append(f"{name}_sum{ls} {snap['sum']}")
                lines.append(f"{name}_count{ls} {snap['count']}")
            else:
                lines.append(f"{name}{ls} {m.snapshot()}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        """Drop every metric — tests only; live handles held by modules
        keep counting into their (now unregistered) objects, so prefer
        delta assertions over reset in anything but isolated tests."""
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# strict text-format parser (conformance checking; the scrape-side dual
# of render_prometheus, used by the exposition tests and CI smoke)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>NaN|[+-]?Inf|[+-]?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)"
    r"(?: \d+)?$")                      # optional timestamp (ms)
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape_label_value(v: str) -> str:
    return (v.replace("\\n", "\n").replace('\\"', '"')
             .replace("\\\\", "\\"))


def _parse_labels(block: Optional[str]) -> Dict[str, str]:
    if not block:
        return {}
    pairs = _LABEL_PAIR_RE.findall(block)
    # the pairs must tile the whole block (separated by commas) — a
    # malformed remainder means a non-conformant line
    rebuilt = ",".join(f'{k}="{v}"' for k, v in pairs)
    if rebuilt != block.rstrip(","):
        raise ValueError(f"malformed label block {{{block}}}")
    return {k: _unescape_label_value(v) for k, v in pairs}


def _family_of(sample_name: str, types: Dict[str, str]) -> Optional[str]:
    if sample_name in types:
        return sample_name
    for suffix in ("_bucket", "_sum", "_count"):
        if sample_name.endswith(suffix):
            base = sample_name[: -len(suffix)]
            if types.get(base) == "histogram":
                return base
    return None


def parse_prometheus_text(text: str) -> Dict[str, Dict]:
    """Strictly parse Prometheus text format v0.0.4; raises ValueError on
    any non-conformance a real scraper would reject (or silently
    mis-read).  Returns ``{family: {"type", "help", "samples":
    [(sample_name, labels, value), ...]}}``.

    Beyond line syntax, this validates the invariants scrape pipelines
    assume: ``# TYPE`` precedes its family's samples and appears at most
    once; histogram families expose cumulative monotone ``_bucket``
    series whose ``+Inf`` bucket equals ``_count``, plus a ``_sum``;
    counters never carry a negative value."""
    if text and not text.endswith("\n"):
        raise ValueError("exposition must end with a newline")
    families: Dict[str, Dict] = {}
    types: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split(" ", 1)
            name = parts[0]
            fam = families.setdefault(
                name, {"type": None, "help": None, "samples": []})
            fam["help"] = parts[1] if len(parts) > 1 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split(" ")
            if len(parts) != 2:
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            name, kind = parts
            if kind not in ("counter", "gauge", "histogram", "summary",
                            "untyped"):
                raise ValueError(f"line {lineno}: unknown type {kind!r}")
            if name in types:
                raise ValueError(f"line {lineno}: duplicate TYPE for "
                                 f"{name!r}")
            fam = families.setdefault(
                name, {"type": None, "help": None, "samples": []})
            if fam["samples"]:
                raise ValueError(f"line {lineno}: TYPE for {name!r} after "
                                 f"its samples")
            fam["type"] = kind
            types[name] = kind
            continue
        if line.startswith("#"):
            continue                               # free-form comment
        mt = _SAMPLE_RE.match(line)
        if mt is None:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        sample_name = mt.group("name")
        labels = _parse_labels(mt.group("labels"))
        value = float(mt.group("value"))
        family = _family_of(sample_name, types)
        if family is None:
            raise ValueError(f"line {lineno}: sample {sample_name!r} has "
                             f"no preceding # TYPE")
        if types[family] == "counter" and value < 0:
            raise ValueError(f"line {lineno}: counter {sample_name!r} "
                             f"is negative ({value})")
        families[family]["samples"].append((sample_name, labels, value))

    for name, fam in families.items():
        if fam["type"] != "histogram":
            continue
        series: Dict[LabelItems, Dict] = {}
        for sample_name, labels, value in fam["samples"]:
            base = tuple(sorted((k, v) for k, v in labels.items()
                                if k != "le"))
            s = series.setdefault(base, {"buckets": [], "sum": None,
                                         "count": None})
            if sample_name == name + "_bucket":
                if "le" not in labels:
                    raise ValueError(f"{name}_bucket missing le label")
                s["buckets"].append((labels["le"], value))
            elif sample_name == name + "_sum":
                s["sum"] = value
            elif sample_name == name + "_count":
                s["count"] = value
        for base, s in series.items():
            if s["sum"] is None or s["count"] is None:
                raise ValueError(f"histogram {name}{dict(base)} missing "
                                 f"_sum or _count")
            bounds = [float(le) for le, _ in s["buckets"]]
            if not bounds or bounds != sorted(bounds):
                raise ValueError(f"histogram {name}{dict(base)} buckets "
                                 f"out of order: {bounds}")
            counts = [c for _, c in s["buckets"]]
            if any(b > a for b, a in zip(counts, counts[1:])):
                raise ValueError(f"histogram {name}{dict(base)} bucket "
                                 f"counts not cumulative: {counts}")
            if s["buckets"][-1][0] != "+Inf":
                raise ValueError(f"histogram {name}{dict(base)} missing "
                                 f"+Inf bucket")
            if counts[-1] != s["count"]:
                raise ValueError(f"histogram {name}{dict(base)} +Inf "
                                 f"bucket {counts[-1]} != _count "
                                 f"{s['count']}")
    return families


#: the process-default registry every instrumented module targets
REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "",
            labels: Optional[Dict] = None) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: Optional[Dict] = None) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: Optional[Dict] = None,
              buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labels, buckets)
