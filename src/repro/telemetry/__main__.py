"""``python -m repro.telemetry`` — registry dump, trace summarizer, and
live flight-recorder watcher.

  PYTHONPATH=src python -m repro.telemetry                 # registry (prom text)
  PYTHONPATH=src python -m repro.telemetry --format json   # registry (JSON)
  PYTHONPATH=src python -m repro.telemetry \\
      --summarize results/trace.json                       # trace phase report
  PYTHONPATH=src python -m repro.telemetry \\
      --watch http://127.0.0.1:8787                        # tail /flight

``--summarize`` loads a Chrome-trace JSON produced by
``repro.experiments.run --trace`` (or `telemetry.trace.export`),
validates the event schema, and prints the span-coverage + per-phase
breakdown — the same aggregation the analysis report renders
(`trace.phase_breakdown`).  The exit code is non-zero if ``--min-
coverage`` is given and the trace's top-level spans attribute less than
that fraction of its wall-clock (CI's traced-sweep smoke gate).

``--watch URL`` tails a live observability plane (`run.py --serve PORT`
or ``python -m repro.service --serve PORT``): it polls
``URL/flight?since=CURSOR`` and prints each new flight-recorder event
(sweep/job progress, grid pad waste, race psum rounds) as a one-line
record — a text-mode "what is the sweep doing right now".  Stdlib
urllib; ``--interval`` sets the poll period and ``--max-polls`` bounds
the watch (0 = until interrupted).

The bare registry dump shows *this process's* metrics — mostly zeros
from a fresh CLI process; its real consumers are in-process
(`AdvisorService.stats`, the run CLI's ``--metrics`` flag) or the HTTP
``GET /metrics`` endpoint (`repro.service.http`).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.error
import urllib.request

from repro.telemetry import REGISTRY, trace

_REQUIRED_EVENT_KEYS = ("name", "ph", "ts", "dur", "pid", "tid")


def summarize(path: str, root: str = "sweep") -> dict:
    """Load + validate a Chrome-trace JSON; return the phase breakdown."""
    with open(path) as f:
        payload = json.load(f)
    events = payload.get("traceEvents", payload if isinstance(payload, list)
                         else [])
    bad = [e for e in events
           if not all(k in e for k in _REQUIRED_EVENT_KEYS)]
    if bad:
        raise ValueError(
            f"{path}: {len(bad)} event(s) missing required keys "
            f"{_REQUIRED_EVENT_KEYS} (first: {bad[0]!r})")
    overall = trace.phase_breakdown(events)
    scoped = trace.phase_breakdown(events, root=root)
    return {"path": path, "n_events": len(events),
            "overall": overall, "last_" + root: scoped}


def _print_summary(s: dict, root: str) -> None:
    ov = s["overall"]
    print(f"{s['path']}: {s['n_events']} span(s), "
          f"wall {ov['wall_us'] / 1e6:.3f} s, top-level coverage "
          f"{ov['coverage']:.1%}")
    scoped = s["last_" + root]
    if scoped["root"]:
        print(f"last '{root}' span: {scoped['wall_us'] / 1e6:.3f} s, "
              f"child coverage {scoped['coverage']:.1%}")
        phases = scoped["phases"]
    else:
        phases = ov["phases"]
    width = max((len(n) for n in phases), default=4)
    for name, p in sorted(phases.items(),
                          key=lambda kv: -kv[1]["total_us"]):
        print(f"  {name:<{width}}  {p['total_us'] / 1e6:9.3f} s  "
              f"x{p['count']:<5d} {p['frac_of_wall']:6.1%}")


def _format_event(ev: dict) -> str:
    """One flight event -> one log line: time, kind, then the payload
    fields in insertion order."""
    ts = time.strftime("%H:%M:%S", time.localtime(ev.get("t", 0)))
    fields = " ".join(f"{k}={v}" for k, v in ev.items()
                      if k not in ("seq", "t", "kind"))
    return f"{ts} #{ev.get('seq', '?'):<6} {ev.get('kind', '?'):<14} {fields}"


def watch(url: str, interval: float = 1.0, max_polls: int = 0,
          out=None) -> int:
    """Tail ``url``'s ``/flight`` endpoint; returns an exit code."""
    out = out or sys.stdout
    base = url.rstrip("/")
    since, polls = 0, 0
    while True:
        try:
            with urllib.request.urlopen(
                    f"{base}/flight?since={since}", timeout=10) as r:
                snap = json.load(r)
        except (urllib.error.URLError, OSError, json.JSONDecodeError) as e:
            print(f"error: {base}/flight unreachable: {e}", file=sys.stderr)
            return 2
        for ev in snap.get("events", []):
            print(_format_event(ev), file=out)
        for sp in snap.get("spans", []):
            print(f"         #{sp.get('seq', '?'):<6} span:{sp['name']:<9} "
                  f"dur={sp['dur'] / 1e3:.1f}ms", file=out)
        out.flush()
        since = snap.get("seq", since)
        polls += 1
        if max_polls and polls >= max_polls:
            return 0
        try:
            time.sleep(interval)
        except KeyboardInterrupt:
            return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="dump the metrics registry / summarize a trace / "
                    "watch a live flight recorder")
    ap.add_argument("--summarize", metavar="TRACE_JSON",
                    help="validate + phase-break a Chrome-trace JSON")
    ap.add_argument("--root", default="sweep",
                    help="span name to scope the phase breakdown to "
                         "(default: sweep)")
    ap.add_argument("--min-coverage", type=float, default=None,
                    help="exit non-zero if top-level span coverage of the "
                         "trace wall-clock is below this fraction")
    ap.add_argument("--format", choices=("prom", "json"), default="prom",
                    help="registry dump format (default: prom text)")
    ap.add_argument("--prefix", default="",
                    help="only dump metrics whose name starts with this")
    ap.add_argument("--watch", metavar="URL",
                    help="tail URL/flight (a run.py --serve or repro.service "
                         "--serve plane), printing new events per poll")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="--watch poll period in seconds (default 1)")
    ap.add_argument("--max-polls", type=int, default=0,
                    help="--watch: stop after N polls (0 = until ^C)")
    args = ap.parse_args(argv)

    if args.watch:
        return watch(args.watch, interval=args.interval,
                     max_polls=args.max_polls)

    if args.summarize:
        try:
            s = summarize(args.summarize, root=args.root)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        _print_summary(s, args.root)
        if args.min_coverage is not None and \
                s["overall"]["coverage"] < args.min_coverage:
            print(f"FAIL: coverage {s['overall']['coverage']:.1%} < "
                  f"{args.min_coverage:.1%}", file=sys.stderr)
            return 1
        return 0

    if args.format == "json":
        json.dump(REGISTRY.to_dict(prefix=args.prefix), sys.stdout,
                  indent=2, default=float)
        print()
    else:
        out = REGISTRY.render_prometheus(prefix=args.prefix)
        sys.stdout.write(out or "# (registry empty)\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
