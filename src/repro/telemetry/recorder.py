"""Flight recorder: a bounded, always-on ring of sweep progress events.

Spans answer "where did the time go" *after* a run; the metrics registry
answers "how many, how big" at any instant.  Neither answers the
operator's mid-sweep question: *which job is the engine on, and what has
already happened?*  The flight recorder does — it is a pair of bounded
ring buffers:

  * **events** — per-job sweep progress markers published by
    `experiments.runner.run_sweep` (sweep/job started, retried,
    diverged, failed, stored), `experiments.engine` (grid dispatch with
    its pad-waste ratio), and the racing path
    (`distributed.hogwild_shards`, with ``psum_rounds``).  Publishing is
    a lock + ``deque.append`` of a small dict, a handful of times per
    *sweep* — never per iteration — so the recorder is always on, like
    the metrics registry.
  * **spans** — completed spans mirrored from the tracer while one is
    installed (`trace.add_span_sink`); with tracing off this ring simply
    stays empty.  The mirror makes recent span history scrapeable over
    ``GET /flight`` without draining the tracer that CI's coverage gate
    will read.

Ring semantics: each record carries a process-monotonic ``seq``;
:meth:`FlightRecorder.snapshot` returns everything newer than a caller-
supplied ``since`` cursor, so a poller (``GET /flight?since=N`` or
``python -m repro.telemetry --watch URL``) tails the stream without
re-reading history.  Old records fall off the bounded ends — the
recorder is an observability window, not a journal (the crash journal in
`repro.resilience` is the durable one).

Observational contract (docs/observability.md): publishing happens
*beside* the sweep's computation, never in it — artifact bytes are
identical with the recorder ring populated or cleared (pinned in
tests/test_http.py).
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Deque, Dict, List, Optional

#: default ring capacities — sized for "the last few sweeps", not history
DEFAULT_EVENTS = 4096
DEFAULT_SPANS = 2048


class FlightRecorder:
    """Two bounded rings (progress events, mirrored spans) behind one
    monotone sequence counter."""

    def __init__(self, max_events: int = DEFAULT_EVENTS,
                 max_spans: int = DEFAULT_SPANS):
        self._lock = threading.Lock()
        self._seq = 0
        self._events: Deque[Dict] = collections.deque(maxlen=max_events)
        self._spans: Deque[Dict] = collections.deque(maxlen=max_spans)
        self._published = 0
        self._t0 = time.time()

    # -- producers -----------------------------------------------------------
    def publish(self, kind: str, **fields) -> Dict:
        """Append one progress event; returns the recorded dict.  ``kind``
        is the event schema selector (docs/observability.md lists them);
        ``fields`` must be JSON-serializable (the HTTP snapshot dumps
        them verbatim)."""
        with self._lock:
            self._seq += 1
            ev = {"seq": self._seq, "t": time.time(), "kind": kind,
                  **fields}
            self._events.append(ev)
            self._published += 1
        return ev

    def record_span(self, span_event: Dict) -> None:
        """Span-sink callback (`trace.add_span_sink`): mirror a completed
        span into the bounded span ring."""
        with self._lock:
            self._seq += 1
            self._spans.append(dict(span_event, seq=self._seq))

    # -- consumers -----------------------------------------------------------
    def snapshot(self, since: int = 0,
                 limit: Optional[int] = None) -> Dict:
        """Everything newer than the ``since`` cursor, oldest first.

        Returns ``{"seq", "published", "uptime_s", "events", "spans"}``;
        ``seq`` is the cursor to pass back on the next poll.  ``limit``
        caps each list (newest kept) so one scrape stays bounded even
        after a long gap."""
        with self._lock:
            events = [e for e in self._events if e["seq"] > since]
            spans = [s for s in self._spans if s["seq"] > since]
            seq, published = self._seq, self._published
        if limit is not None:
            events, spans = events[-limit:], spans[-limit:]
        return {"seq": seq, "published": published,
                "uptime_s": time.time() - self._t0,
                "events": events, "spans": spans}

    def clear(self) -> None:
        """Drop both rings (tests; the seq cursor keeps advancing so a
        poller never sees a replay)."""
        with self._lock:
            self._events.clear()
            self._spans.clear()

    def stats(self) -> Dict:
        with self._lock:
            return {"seq": self._seq, "published": self._published,
                    "events_held": len(self._events),
                    "spans_held": len(self._spans),
                    "max_events": self._events.maxlen,
                    "max_spans": self._spans.maxlen}


#: the process-default recorder every instrumented module publishes to
RECORDER = FlightRecorder()


def publish(kind: str, **fields) -> Dict:
    """Publish one progress event to the process recorder."""
    return RECORDER.publish(kind, **fields)


def snapshot(since: int = 0, limit: Optional[int] = None) -> Dict:
    return RECORDER.snapshot(since=since, limit=limit)
