"""jax-aware tracing helpers: the per-dispatch compile-vs-execute split.

A jitted grid function called through plain ``jfn(args)`` hides its cost
structure: the first call pays trace + lower + XLA compile + execute in
one opaque interval.  :func:`dispatch` splits that interval when (and
only when) a tracer is installed, using jax's AOT path —
``jfn.lower(*args)`` (trace + StableHLO lowering), ``lowered.compile()``
(XLA), ``compiled(*args)`` (device execution, with a
``block_until_ready`` so the execute span measures compute, not async
dispatch) — which produces the *same executable from the same lowering*
as the plain call, so results are bit-identical (pinned by the
disabled-vs-enabled artifact byte-equality test).

With tracing disabled, :func:`dispatch` is exactly ``jfn(*args)`` — no
AOT, no blocking, no clock reads; the engine's hot path is the
pre-telemetry code.

Only use this for calls that run **once per jit wrapper** (the engine's
per-bucket vmaps, the racing-mode pipeline): ``.lower()`` bypasses the
jit call cache, so wrapping a warm repeated call would re-trace and
re-compile every time.  Repeated-call sites (the sequential reference
path) should use plain `trace.span` around the call instead.

This is the one telemetry module that imports jax; `trace` and
`metrics` stay stdlib-only so the dump CLI works anywhere.
"""

from __future__ import annotations

import jax

from repro.telemetry import trace


def dispatch(jfn, *args, span_name: str = "bucket", **attrs):
    """Call jitted ``jfn(*args)``; under an active tracer, emit a
    ``span_name`` span with ``lower`` / ``compile`` / ``execute``
    children (see module docs for the exactness contract)."""
    if trace.active() is None:
        return jfn(*args)
    with trace.span(span_name, **attrs):
        with trace.span("lower"):
            lowered = jfn.lower(*args)
        with trace.span("compile"):
            compiled = lowered.compile()
        with trace.span("execute"):
            out = compiled(*args)
            jax.block_until_ready(out)
    return out


def timed_call(fn, *args, span_name: str = "execute", **attrs):
    """Plain-span twin of :func:`dispatch` for repeated-call sites: one
    span around the call, blocked until ready so the duration is the
    compute (first call includes its compile — attributed, not split,
    because splitting would defeat the jit call cache)."""
    if trace.active() is None:
        return fn(*args)
    with trace.span(span_name, **attrs):
        out = fn(*args)
        jax.block_until_ready(out)
    return out
