"""Hand-rolled sharded-state-aware optimizers (no external deps).

State layout is a plain dict so the sharding rules can mirror param specs:
  adamw: {"m": tree, "v": tree, "count": scalar}
  sgd/momentum: {"m": tree or (), "count": scalar}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _zeros_like_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


# --- AdamW ------------------------------------------------------------------

def adamw_init(params):
    return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr=1e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        step = mh / (jnp.sqrt(vh) + eps)
        if p.ndim >= 2:                      # decoupled decay on matrices only
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


# --- SGD / momentum ----------------------------------------------------------

def sgd_init(params):
    return {"count": jnp.zeros((), jnp.int32)}


def sgd_update(params, grads, state, *, lr=0.1, weight_decay=0.0):
    def upd(p, g):
        gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * gf).astype(p.dtype)

    return (jax.tree.map(upd, params, grads),
            {"count": state["count"] + 1})


def momentum_init(params):
    return {"m": _zeros_like_f32(params), "count": jnp.zeros((), jnp.int32)}


def momentum_update(params, grads, state, *, lr=0.1, beta=0.9,
                    weight_decay=0.0):
    def upd(p, g, m):
        gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m_new = beta * m + gf
        return (p.astype(jnp.float32) - lr * m_new).astype(p.dtype), m_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    return (treedef.unflatten([o[0] for o in out]),
            {"m": treedef.unflatten([o[1] for o in out]),
             "count": state["count"] + 1})
