from repro.optim.optimizers import (adamw_init, adamw_update, sgd_init,
                                    sgd_update, momentum_init, momentum_update)
