"""repro.optim — optimizers for the production training stack (SGD,
momentum, AdamW as init/update pairs over pytrees).  The paper-side
algorithms in `repro.core.algorithms` carry their own update rules; this
package serves the model-training tier (`repro.train`, `repro.launch`).
"""

from repro.optim.optimizers import (adamw_init, adamw_update, sgd_init,
                                    sgd_update, momentum_init, momentum_update)
