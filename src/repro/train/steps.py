"""Sharded train-step factories — one per paper exchange strategy.

  sync    mini-batch SGD/AdamW (Alg 2): global loss mean => implicit gradient
          all-reduce over ('pod','data'); FSDP param layout.
  stale   Hogwild!'s insight (Alg 1) adapted to SPMD (DESIGN.md §6): the
          update applied at step t uses the gradient computed at step t-1
          (tau=1 staleness), overlapping gradient compute with exchange.
  gossip  ECD-PSGD (Alg 4): per-data-shard model replicas, ring
          collective_permute of *compressed* (stochastically quantized)
          neighbor models + extrapolation variables.  Pure-DP layout
          (replicated per shard) — used for the small/medium archs.
  (DADM, Alg 3, needs a convex conjugate pair; it lives in
   repro.core.algorithms and repro.train.convex for LR-scale models.)
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim import adamw_init, adamw_update
from repro.distributed import (act_constraint, batch_specs, data_axes,
                               head_constraint, inner_act_constraint,
                               layer_constraint, logits_constraint,
                               param_specs)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def init_train_state(key, cfg: ArchConfig, strategy="sync"):
    params = M.init_params(key, cfg)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if strategy == "stale":
        state["prev_grads"] = jax.tree.map(
            lambda x: jnp.zeros(x.shape, x.dtype), params)
    return state


def train_state_specs(state_shapes, mesh):
    pspecs = param_specs(state_shapes["params"], mesh)
    specs = {"params": pspecs,
             "opt": {"m": pspecs, "v": pspecs, "count": P()},
             "step": P()}
    if "prev_grads" in state_shapes:
        specs["prev_grads"] = pspecs
    return specs


# ---------------------------------------------------------------------------
# sync / stale steps (FSDP layout, plain jit)
# ---------------------------------------------------------------------------

def _split_microbatches(batch, m):
    """(B, ...) -> (m, B/m, ...); M-RoPE positions (3,B,S) split on axis 1."""
    def f(path, leaf):
        name = getattr(path[-1], "key", None)
        if name == "positions":
            return leaf.reshape(leaf.shape[0], m, -1, *leaf.shape[2:]
                                ).transpose(1, 0, *range(2, leaf.ndim + 1))
        return leaf.reshape(m, -1, *leaf.shape[1:])
    return jax.tree_util.tree_map_with_path(f, batch)


def make_train_step(cfg: ArchConfig, mesh, *, strategy="sync", lr=3e-4,
                    remat=True, attention_impl="reference", seq_shard=True,
                    grad_shard=True, microbatches=1,
                    grad_accum_dtype=jnp.float32, accum_mode="explicit"):
    constrain = act_constraint(mesh, seq_shard=seq_shard)
    c_inner = inner_act_constraint(mesh, seq_shard=seq_shard, cfg=cfg)
    c_layer = layer_constraint(mesh) if grad_shard else None
    c_logits = logits_constraint(mesh) if grad_shard else None
    c_head = head_constraint(mesh) if grad_shard else None

    def loss(params, batch):
        return M.loss_fn(params, cfg, batch, remat=remat,
                         attention_impl=attention_impl, constrain=constrain,
                         constrain_layer=c_layer, constrain_logits=c_logits,
                         constrain_inner=c_inner, constrain_head=c_head)

    def _constrain_grads(params, grads):
        # pin gradients to the FSDP param layout so XLA lowers the gradient
        # reduction as reduce-scatter instead of all-reduce + slice
        if not grad_shard:
            return grads
        specs = param_specs(params, mesh)
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, s)), grads, specs,
            is_leaf=lambda x: isinstance(x, P))

    def grads_of(params, batch):
        """Gradient of the mean loss, microbatched (grad accumulation).

        accum_mode "in-loss": the microbatch scan lives INSIDE the
        differentiated function, so the parameter cotangent accumulates in
        the backward while-loop instead of re-realizing (and re-reducing)
        a full gradient per microbatch — measured 4x collective-byte saving
        at microbatches=8 on qwen110b (EXPERIMENTS.md §Perf).
        """
        if accum_mode == "in-loss" and microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def total_loss(p):
                def body(acc, one):
                    l, aux = loss(p, one)
                    return acc + l, aux
                tot, auxs = jax.lax.scan(
                    body, jnp.zeros((), jnp.float32), mb)
                return tot / microbatches, jax.tree.map(lambda x: x[-1], auxs)

            (l, aux), grads = jax.value_and_grad(
                total_loss, has_aux=True)(params)
            return l, aux, _constrain_grads(params, grads)
        if microbatches <= 1:
            (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
            return l, aux, _constrain_grads(params, grads)
        mb = _split_microbatches(batch, microbatches)

        def body(acc, one):
            (l, aux), g = jax.value_and_grad(loss, has_aux=True)(params, one)
            g = _constrain_grads(params, g)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, x: a + x.astype(grad_accum_dtype), acc_g, g)
            acc_g = _constrain_grads(params, acc_g)
            return (acc_g, acc_l + l), aux

        zero = jax.tree.map(
            lambda x: jnp.zeros(x.shape, grad_accum_dtype), params)
        zero = _constrain_grads(params, zero)
        (g_sum, l_sum), auxs = jax.lax.scan(body, (zero, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree.map(lambda x: (x / microbatches), g_sum)
        aux = jax.tree.map(lambda x: x[-1], auxs)
        return l_sum / microbatches, aux, grads

    def sync_step(state, batch):
        l, aux, grads = grads_of(state["params"], batch)
        new_params, new_opt = adamw_update(state["params"], grads,
                                           state["opt"], lr=lr)
        metrics = {"loss": l, "ce_loss": aux["ce_loss"],
                   "grad_norm": _global_norm(grads)}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    def stale_step(state, batch):
        # apply last step's gradient while computing this step's
        l, aux, grads = grads_of(state["params"], batch)
        new_params, new_opt = adamw_update(
            state["params"], state["prev_grads"], state["opt"], lr=lr)
        metrics = {"loss": l, "ce_loss": aux["ce_loss"],
                   "grad_norm": _global_norm(grads)}
        prev = jax.tree.map(lambda g, pp: g.astype(pp.dtype), grads,
                            state["params"])
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1, "prev_grads": prev}, metrics

    step = {"sync": sync_step, "stale": stale_step}[strategy]
    return step


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# gossip (ECD-PSGD) step — per-shard replicas via shard_map
# ---------------------------------------------------------------------------

def make_gossip_step(cfg: ArchConfig, mesh, *, lr=3e-4, compress_bits=8,
                     remat=False, attention_impl="reference"):
    """ECD-PSGD on the data axes: per-shard model replicas (leading axis R,
    sharded over 'data'), ring collective_permute of *compressed* neighbor
    extrapolation variables.  Returns (shard_map-wrapped step, state_specs).

    Use via ``init_gossip_state`` + the returned jit-able step:
        step(state, batch) -> (state, metrics)
    """
    from jax.sharding import PartitionSpec
    from jax.experimental.shard_map import shard_map

    from repro.core.compression import dequantize, quantize_stochastic

    fd = data_axes(mesh)
    axis_names = (fd if isinstance(fd, tuple) else (fd,))
    R = 1
    for a in axis_names:
        R *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    def loss(params, batch):
        return M.loss_fn(params, cfg, batch, remat=remat,
                         attention_impl=attention_impl)

    def local_step(state, batch):
        # leading replica axis has local size 1 inside shard_map
        params = jax.tree.map(lambda x: x[0], state["params"])
        y_var = jax.tree.map(lambda x: x[0], state["y"])
        t = state["step"].astype(jnp.float32) + 2.0
        idx = jax.lax.axis_index(axis_names[0])
        for a in axis_names[1:]:
            idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(17), state["step"]), idx)

        (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)

        def ring_avg(leaf):
            total = leaf.astype(jnp.float32)
            n = 1
            for ax in axis_names:
                size = jax.lax.axis_size(ax)
                fwd = [(i, (i + 1) % size) for i in range(size)]
                bwd = [(i, (i - 1) % size) for i in range(size)]
                total = total + jax.lax.ppermute(leaf, ax, fwd).astype(jnp.float32)
                total = total + jax.lax.ppermute(leaf, ax, bwd).astype(jnp.float32)
                n += 2
            return (total / n).astype(leaf.dtype)

        # pull compressed neighbor y (Alg 4 step 3): x_{t+1/2} = sum W_ij y_j
        y_comp = jax.tree.map(
            lambda v, k: dequantize(*quantize_stochastic(
                v, k, bits=compress_bits)).astype(v.dtype),
            y_var, _key_tree(key, y_var))
        x_half = jax.tree.map(ring_avg, y_comp)
        new_params = jax.tree.map(
            lambda xh, g: (xh.astype(jnp.float32)
                           - lr * g.astype(jnp.float32)).astype(xh.dtype),
            x_half, grads)

        # extrapolate + compress (Alg 4 steps 4-5)
        def extrap(x_old, x_new, y_old, k):
            z = (1.0 - t / 2.0) * x_old.astype(jnp.float32) \
                + (t / 2.0) * x_new.astype(jnp.float32)
            cz = dequantize(*quantize_stochastic(z, k, bits=compress_bits))
            return ((1.0 - 2.0 / t) * y_old.astype(jnp.float32)
                    + (2.0 / t) * cz).astype(y_old.dtype)

        new_y = jax.tree.map(extrap, params, new_params, y_var,
                             _key_tree(jax.random.fold_in(key, 1), y_var))
        l_avg = l
        for a in axis_names:
            l_avg = jax.lax.pmean(l_avg, a)
        return ({"params": jax.tree.map(lambda x: x[None], new_params),
                 "y": jax.tree.map(lambda x: x[None], new_y),
                 "step": state["step"] + 1},
                {"loss": l_avg})

    p_stack = PartitionSpec(fd)
    state_specs = {"params": None, "y": None, "step": PartitionSpec()}

    def specs_like(tree):
        return jax.tree.map(lambda _: p_stack, tree)

    def make(state_shapes, batch_shapes):
        st_specs = {"params": specs_like(state_shapes["params"]),
                    "y": specs_like(state_shapes["y"]),
                    "step": PartitionSpec()}
        b_specs = jax.tree.map(
            lambda x: PartitionSpec(fd, *([None] * (x.ndim - 1))),
            batch_shapes)
        step = shard_map(local_step, mesh=mesh,
                         in_specs=(st_specs, b_specs),
                         out_specs=(st_specs, {"loss": PartitionSpec()}),
                         check_rep=False)
        return step, st_specs, b_specs

    return make, R


def init_gossip_state(key, cfg: ArchConfig, n_replicas):
    params = M.init_params(key, cfg)
    stack = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape), params)
    return {"params": stack,
            "y": jax.tree.map(jnp.copy, stack),
            "step": jnp.zeros((), jnp.int32)}


def _key_tree(key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))
