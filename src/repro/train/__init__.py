"""repro.train — the model-training tier: `steps` builds jitted/sharded
train steps (sync data-parallel and gossip strategies) over
`repro.models` + `repro.optim`, and `checkpoint` persists/restores pytree
state.  Scalability advice for choosing a strategy comes from
`repro.core.advisor`.
"""
