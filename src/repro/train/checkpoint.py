"""Pure-JAX checkpointing: pytree -> directory of .npy leaves + a JSON
manifest of the treedef (no external deps; sharded arrays are gathered
per-leaf via jax.device_get — fine at the scales the examples train)."""

from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_key(path):
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path, tree, step=0):
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": int(step), "leaves": []}
    for lp, leaf in leaves:
        key = _leaf_key(lp)
        fname = re.sub(r"[^A-Za-z0-9_/.-]", "_", key).replace("/", "__")
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(path, fname + ".npy"), arr)
        manifest["leaves"].append({"key": key, "file": fname + ".npy",
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def restore_checkpoint(path, tree_like):
    """Restores into the structure of ``tree_like`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e["file"] for e in manifest["leaves"]}
    leaves_p = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for lp, leaf in leaves_p[0]:
        key = _leaf_key(lp)
        arr = np.load(os.path.join(path, by_key[key]))
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(leaves_p[1], out), manifest["step"]
