"""The paper's dataset-character indices (§IV).

  feature_variance   per-feature variance over the dataset (§IV.B)
  sparsity/density   fraction of zero elements (§IV.B)
  diversity          number of distinct sample kinds (§IV.C)
  C_sim_range        Eq. 3: windowed mean L0 distance along the sampling
                     sequence
  LS_A(D, S)         local similarity per algorithm class (§IV.A):
                       async (Hogwild!): C_sim_{tau_max} over the sequence
                       sync  (mini-batch/ECD-PSGD/DADM): the max over batches
                       of the batch-internal similarity

The Pallas kernel in repro.kernels.csim computes the Eq. 3 hot loop
(O(n * range * d)); csim_ref here is its oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def feature_mean(X):
    return jnp.mean(X, axis=0)


def feature_variance(X):
    """Per-feature variance (paper's 'feature variance_k')."""
    return jnp.var(X, axis=0)


def mean_feature_variance(X):
    return float(jnp.mean(feature_variance(X)))


def sparsity(X, tol=0.0):
    """Fraction of zero elements."""
    return float(jnp.mean(jnp.abs(X) <= tol))


def density(X, tol=0.0):
    return 1.0 - sparsity(X, tol)


def diversity(X, *, decimals=6):
    """Number of distinct sample kinds (exact row dedup)."""
    Xr = np.asarray(jax.device_get(X))
    Xr = np.round(Xr, decimals)
    return int(np.unique(Xr, axis=0).shape[0])


def diversity_ratio(X, **kw):
    return diversity(X, **kw) / X.shape[0]


# ---------------------------------------------------------------------------
# C_sim (Eq. 3) and LS_A
# ---------------------------------------------------------------------------

def l0_distance(a, b, tol=0.0):
    """||a - b||_0 — number of differing coordinates."""
    return jnp.sum((jnp.abs(a - b) > tol).astype(jnp.float32), axis=-1)


def csim_ref(X, rng: int, tol=0.0):
    """Eq. 3: C_sim_range = (1/n) sum_i (1/range) sum_{j=1..range}
    ||xi_i - xi_{(i+j) % n}||_0   (pure-jnp oracle for the Pallas kernel)."""
    n = X.shape[0]
    total = jnp.zeros((), jnp.float32)
    for j in range(1, rng + 1):
        total = total + jnp.sum(l0_distance(X, jnp.roll(X, -j, axis=0), tol))
    return float(total / (n * rng))


def csim(X, rng: int, tol=0.0, use_kernel=False):
    if use_kernel:
        from repro.kernels import ops as kops
        return float(kops.csim(X, rng, tol))
    return csim_ref(X, rng, tol)


def batch_internal_similarity(Xb, tol=0.0):
    """Mean pairwise L0 distance within a batch — tractable proxy for the
    paper's 'max C_sim over orderings of the batch' (exact ordering search is
    a TSP; the mean pairwise distance brackets it and preserves ranking)."""
    b = Xb.shape[0]
    diff = (jnp.abs(Xb[:, None, :] - Xb[None, :, :]) > tol)
    d = jnp.sum(diff.astype(jnp.float32), axis=-1)
    off = jnp.sum(d) - jnp.sum(jnp.diag(d))
    return float(off / (b * (b - 1) + 1e-9))


def ls_async(X, tau_max: int, tol=0.0):
    """LS_A for asynchronous algorithms (Hogwild!): C_sim_{tau_max}."""
    return csim(X, tau_max, tol)


def ls_sync(X, batch_size: int, tol=0.0):
    """LS_A for synchronous algorithms: max over batches of the batch's
    internal similarity."""
    n = (X.shape[0] // batch_size) * batch_size
    batches = X[:n].reshape(-1, batch_size, X.shape[1])
    vals = [batch_internal_similarity(batches[i])
            for i in range(batches.shape[0])]
    return float(max(vals))


# ---------------------------------------------------------------------------
# Hogwild! theorem-2 parameters (Omega, delta, rho) from the dataset
# ---------------------------------------------------------------------------

def hogwild_params(X, tol=0.0):
    """Estimate (Omega, delta, rho) of Thm 2 for a *linear* model, where the
    gradient sparsity pattern equals the sample sparsity pattern.

      Omega: max #nonzeros in a sample
      delta: max frequency of any feature being nonzero
      rho:   max probability two random samples share a nonzero feature
    """
    nz = (jnp.abs(X) > tol).astype(jnp.float32)        # (n, d)
    omega = float(jnp.max(jnp.sum(nz, axis=1)))
    freq = jnp.mean(nz, axis=0)                        # (d,)
    delta = float(jnp.max(freq))
    # P(collision) <= sum_k freq_k^2  (union bound over features)
    rho = float(jnp.minimum(jnp.sum(freq * freq), 1.0))
    # omega_frac: support size as a fraction of d — the normalization that
    # makes Thm 2's "Omega delta^{1/2} extremely small" dimensionless
    return {"omega": omega, "omega_frac": omega / X.shape[1],
            "delta": delta, "rho": rho}


def summarize(X, *, tau_max=8, batch_size=8):
    """All paper indices in one report."""
    hw = hogwild_params(X)
    return {
        "n": int(X.shape[0]), "d": int(X.shape[1]),
        "mean_feature_variance": mean_feature_variance(X),
        "sparsity": sparsity(X),
        "density": density(X),
        "diversity": diversity(X),
        "diversity_ratio": diversity_ratio(X),
        "csim_async": ls_async(X, tau_max),
        "csim_sync": ls_sync(X, batch_size),
        **hw,
    }
