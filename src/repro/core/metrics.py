"""The paper's dataset-character indices (§IV).

  feature_variance   per-feature variance over the dataset (§IV.B)
  sparsity/density   fraction of zero elements (§IV.B)
  diversity          number of distinct sample kinds (§IV.C)
  C_sim_range        Eq. 3: windowed mean L0 distance along the sampling
                     sequence
  LS_A(D, S)         local similarity per algorithm class (§IV.A):
                       async (Hogwild!): C_sim_{tau_max} over the sequence
                       sync  (mini-batch/ECD-PSGD/DADM): the max over batches
                       of the batch-internal similarity

The hot paths (`csim`, `ls_sync`, `batch_internal_similarity`) are fused:
a single jitted `lax.scan` over the shift/pair range that routes the
per-row L0 count through the Pallas kernels in `repro.kernels.csim` when
``use_kernel`` is true, or through plain fused jnp otherwise.  The
default (``use_kernel=None``) picks the kernel route on TPU and the jnp
route elsewhere: off-TPU the kernels run in interpret mode, which is
emulation — correct (and test-covered) but slower than the fused jnp
scan.  The pure-jnp `*_ref` oracles — Python-loop `csim_ref`, broadcast
`batch_internal_similarity_ref`, per-batch `ls_sync_ref` — are retained
verbatim as the test references.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def feature_mean(X):
    return jnp.mean(X, axis=0)


def feature_variance(X):
    """Per-feature variance (paper's 'feature variance_k')."""
    return jnp.var(X, axis=0)


def mean_feature_variance(X):
    return float(jnp.mean(feature_variance(X)))


def sparsity(X, tol=0.0):
    """Fraction of zero elements."""
    return float(jnp.mean(jnp.abs(X) <= tol))


def density(X, tol=0.0):
    return 1.0 - sparsity(X, tol)


def diversity(X, *, decimals=6):
    """Number of distinct sample kinds (exact row dedup)."""
    Xr = np.asarray(jax.device_get(X))
    Xr = np.round(Xr, decimals)
    return int(np.unique(Xr, axis=0).shape[0])


def diversity_ratio(X, **kw):
    return diversity(X, **kw) / X.shape[0]


# ---------------------------------------------------------------------------
# C_sim (Eq. 3) and LS_A
# ---------------------------------------------------------------------------

def l0_distance(a, b, tol=0.0):
    """||a - b||_0 — number of differing coordinates."""
    return jnp.sum((jnp.abs(a - b) > tol).astype(jnp.float32), axis=-1)


def csim_ref(X, rng: int, tol=0.0):
    """Eq. 3: C_sim_range = (1/n) sum_i (1/range) sum_{j=1..range}
    ||xi_i - xi_{(i+j) % n}||_0   (Python-unrolled pure-jnp oracle for the
    fused `csim` and the Pallas kernel)."""
    n = X.shape[0]
    total = jnp.zeros((), jnp.float32)
    for j in range(1, rng + 1):
        total = total + jnp.sum(l0_distance(X, jnp.roll(X, -j, axis=0), tol))
    return float(total / (n * rng))


@functools.partial(jax.jit, static_argnames=("rng", "tol"))
def _csim_scan(X, rng: int, tol):
    """Fused jnp Eq. 3: one `lax.scan` over the shift range.  The Pallas
    route is `repro.kernels.csim.csim_kernel` — the same scan with the
    per-shift L0 count done by the `l0_rows` kernel."""
    n = X.shape[0]
    rows = jnp.arange(n)

    def body(total, j):
        Xs = X[(rows + j) % n]               # == jnp.roll(X, -j, axis=0)
        return total + jnp.sum(l0_distance(X, Xs, tol)), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(1, rng + 1))
    return total / (n * rng)


def _default_use_kernel() -> bool:
    # interpret-mode Pallas off-TPU is emulation: correct but slower than
    # the fused jnp scan, so the kernels are the default on TPU only
    return jax.default_backend() == "tpu"


def csim(X, rng: int, tol=0.0, use_kernel=None):
    """Eq. 3, fused: a single jitted scan over the shift range.  With
    ``use_kernel`` (default: TPU only) the per-row L0 count runs through
    the Pallas kernel; otherwise fused jnp.  Oracle: :func:`csim_ref`."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    if use_kernel:
        from repro.kernels import ops as kops
        return float(kops.csim(X, rng, tol))
    return float(_csim_scan(X, rng, tol))


@functools.partial(jax.jit, static_argnames=("tol", "use_kernel"))
def _pairwise_l0_means(batches, *, tol, use_kernel):
    """(nb, b, d) -> (nb,) mean pairwise L0 distance within each batch.

    Scans the b-1 in-batch cyclic shifts (shift s pairs row i with row
    (i+s) % b, covering every ordered pair exactly once) with the rows of
    all batches flattened, so each scan step is ONE (nb*b, d) L0 call —
    Pallas `l0_rows` or jnp — instead of nb separate (b, b, d) broadcasts
    with a host sync each.
    """
    nb, b, d = batches.shape
    flat = batches.reshape(nb * b, d)
    cols = jnp.arange(b)

    def body(tot, s):
        rolled = batches[:, (cols + s) % b, :].reshape(nb * b, d)
        if use_kernel:
            from repro.kernels import ops as kops
            dist = kops.l0_rows(flat, rolled, tol)
        else:
            dist = l0_distance(flat, rolled, tol)
        return tot + dist.reshape(nb, b).sum(axis=1), None

    tot, _ = jax.lax.scan(body, jnp.zeros((nb,), jnp.float32),
                          jnp.arange(1, b))
    return tot / (b * (b - 1) + 1e-9)


def batch_internal_similarity_ref(Xb, tol=0.0):
    """(b, b, d)-broadcast oracle for :func:`batch_internal_similarity`."""
    b = Xb.shape[0]
    diff = (jnp.abs(Xb[:, None, :] - Xb[None, :, :]) > tol)
    d = jnp.sum(diff.astype(jnp.float32), axis=-1)
    off = jnp.sum(d) - jnp.sum(jnp.diag(d))
    return float(off / (b * (b - 1) + 1e-9))


def batch_internal_similarity(Xb, tol=0.0, use_kernel=None):
    """Mean pairwise L0 distance within a batch — tractable proxy for the
    paper's 'max C_sim over orderings of the batch' (exact ordering search is
    a TSP; the mean pairwise distance brackets it and preserves ranking).

    Fused path: O(b d) memory shift-scan instead of the oracle's (b, b, d)
    broadcast.  Oracle: :func:`batch_internal_similarity_ref`.
    """
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    return float(_pairwise_l0_means(Xb[None], tol=tol,
                                    use_kernel=use_kernel)[0])


def ls_async(X, tau_max: int, tol=0.0, use_kernel=None):
    """LS_A for asynchronous algorithms (Hogwild!): C_sim_{tau_max}."""
    return csim(X, tau_max, tol, use_kernel=use_kernel)


def ls_sync_ref(X, batch_size: int, tol=0.0):
    """Per-batch Python-loop oracle for :func:`ls_sync` (one device sync
    per batch)."""
    n = (X.shape[0] // batch_size) * batch_size
    batches = X[:n].reshape(-1, batch_size, X.shape[1])
    vals = [batch_internal_similarity_ref(batches[i])
            for i in range(batches.shape[0])]
    return float(max(vals))


def ls_auto(X, algorithm: str, window: int = 8, tol=0.0, use_kernel=None):
    """LS_A resolved through the Algorithm registry: asynchronous
    algorithms (Hogwild!) read C_sim over the sampling sequence with the
    window as tau_max, synchronous ones the max batch-internal similarity
    with the window as the batch size (§IV.A).  Works for any registered
    algorithm — the async/sync split is the class's `asynchronous` flag."""
    from repro.core.algorithms import base as alg_base
    if alg_base.get_algorithm(algorithm).asynchronous:
        return ls_async(X, window, tol, use_kernel=use_kernel)
    return ls_sync(X, window, tol, use_kernel=use_kernel)


def ls_sync(X, batch_size: int, tol=0.0, use_kernel=None):
    """LS_A for synchronous algorithms: max over batches of the batch's
    internal similarity.  Fused: every batch goes through one jitted
    shift-scan and the max reduces on device — a single host sync total.
    Oracle: :func:`ls_sync_ref`."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    n = (X.shape[0] // batch_size) * batch_size
    batches = X[:n].reshape(-1, batch_size, X.shape[1])
    return float(jnp.max(_pairwise_l0_means(batches, tol=tol,
                                            use_kernel=use_kernel)))


# ---------------------------------------------------------------------------
# Hogwild! theorem-2 parameters (Omega, delta, rho) from the dataset
# ---------------------------------------------------------------------------

def hogwild_params(X, tol=0.0):
    """Estimate (Omega, delta, rho) of Thm 2 for a *linear* model, where the
    gradient sparsity pattern equals the sample sparsity pattern.

      Omega: max #nonzeros in a sample
      delta: max frequency of any feature being nonzero
      rho:   max probability two random samples share a nonzero feature
    """
    nz = (jnp.abs(X) > tol).astype(jnp.float32)        # (n, d)
    omega = float(jnp.max(jnp.sum(nz, axis=1)))
    freq = jnp.mean(nz, axis=0)                        # (d,)
    delta = float(jnp.max(freq))
    # P(collision) <= sum_k freq_k^2  (union bound over features)
    rho = float(jnp.minimum(jnp.sum(freq * freq), 1.0))
    # omega_frac: support size as a fraction of d — the normalization that
    # makes Thm 2's "Omega delta^{1/2} extremely small" dimensionless
    return {"omega": omega, "omega_frac": omega / X.shape[1],
            "delta": delta, "rho": rho}


def summarize(X, *, tau_max=8, batch_size=8):
    """All paper indices in one report."""
    hw = hogwild_params(X)
    return {
        "n": int(X.shape[0]), "d": int(X.shape[1]),
        "mean_feature_variance": mean_feature_variance(X),
        "sparsity": sparsity(X),
        "density": density(X),
        "diversity": diversity(X),
        "diversity_ratio": diversity_ratio(X),
        "csim_async": ls_async(X, tau_max),
        "csim_sync": ls_sync(X, batch_size),
        **hw,
    }
