"""Registered training objectives (the `Problem` protocol).

The paper hardwires one model — L2-regularized logistic regression (Eq. 4,
`core/algorithms/lr.py`) — but its thesis is about *dataset characters*,
not about the log loss: variance, sparsity, diversity and sampling-sequence
similarity should decide parallel scalability for any smooth-ish linear
objective (Stich et al. 2021 make the same critical-parameter claim across
losses).  This module lifts the loss/grad/regularizer into a registered
abstraction so the sweep engine can test that claim beyond Eq. 4 with zero
engine edits.

A :class:`Problem` is a frozen dataclass describing a *linear-model*
objective

    argmin_x (1/n) sum_i phi(x . xi_i, label_i) + (lam/2) ||x||^2

through four primal hooks (``dloss`` — the derivative of phi in its first
argument, which is all a linear model's gradient needs — plus the batch /
point gradient assemblies and the unregularized ``test_loss`` the paper's
figures plot) and three dual hooks (``sdca_stepfactor`` / ``sdca_delta`` /
``dual_init``) that give DADM its per-sample coordinate-ascent update.

Problems register by name via :func:`register_problem`; the engine resolves
``problem="ridge"`` through :func:`get_problem`.  Registered here:

  ``logistic``  the paper's Eq. 4 (delegates to `lr.py`, so every legacy
                curve is bit-identical)
  ``ridge``     L2-regularized least squares on the +-1 labels
  ``hinge``     soft-margin SVM (subgradient primal, exact SDCA dual)

Hyperparameters (``lam``) live on the instance: ``get_problem("ridge")
(lam=0.1)``.  The registry is *live* — a class registered after import is
immediately usable by specs, and the spec fingerprint hashes the registered
source (`experiments.spec.registry_signature`), so editing a Problem
invalidates exactly the cached sweeps that used it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Dict, Type

import jax
import jax.numpy as jnp

from repro.core.algorithms import lr

LAMBDA = lr.LAMBDA

#: name -> Problem subclass.  Live view; latest registration wins (tests
#: re-register on purpose to prove fingerprints track the registry).
PROBLEMS: Dict[str, Type["Problem"]] = {}


def register_problem(cls: Type["Problem"]) -> Type["Problem"]:
    """Class decorator: make a Problem resolvable by its ``name``."""
    if not (isinstance(getattr(cls, "name", None), str) and cls.name):
        raise TypeError(f"{cls!r} needs a non-empty ClassVar 'name'")
    PROBLEMS[cls.name] = cls
    return cls


def get_problem(name: str) -> Type["Problem"]:
    try:
        return PROBLEMS[name]
    except KeyError:
        raise KeyError(f"unknown problem {name!r}; "
                       f"known: {sorted(PROBLEMS)}") from None


def resolve_problem(problem, lam=None) -> "Problem":
    """Coerce a name / class / instance (+ optional lam override) to an
    instance — the engine-facing constructor."""
    if isinstance(problem, str):
        problem = get_problem(problem)
    if isinstance(problem, type):
        problem = problem() if lam is None else problem(lam=lam)
    elif lam is not None and lam != problem.lam:
        problem = dataclasses.replace(problem, lam=lam)
    return problem


@dataclasses.dataclass(frozen=True)
class Problem:
    """Base protocol.  Subclass, set ``name``, implement the hooks."""

    name: ClassVar[str] = ""
    lam: float = LAMBDA

    # -- primal -------------------------------------------------------------
    def dloss(self, z, y):
        """d phi(z, y) / dz at prediction z = x . xi — the only loss-specific
        piece of a linear model's gradient (grad_i = dloss * xi + lam x)."""
        raise NotImplementedError

    def test_loss(self, x, X, y):
        """Mean *unregularized* loss — what the paper's figures plot."""
        raise NotImplementedError

    def train_loss(self, x, X, y):
        return self.test_loss(x, X, y) + 0.5 * self.lam * jnp.sum(x * x)

    def point_grad(self, x, xi, yi):
        """Per-sample regularized (sub)gradient G_xi(x)."""
        return self.dloss(jnp.dot(xi, x), yi) * xi + self.lam * x

    def batch_grad(self, x, Xb, yb):
        """Mean regularized gradient over a batch."""
        c = self.dloss(Xb @ x, yb)
        return (c @ Xb) / Xb.shape[0] + self.lam * x

    def masked_batch_grad(self, x, Xb, yb, active, mf):
        """Batch gradient with padded rows masked out (engine hot path):
        rows where ``active == 0`` contribute nothing, the mean divides by
        the traced live count ``mf``."""
        c = self.dloss(Xb @ x, yb) * active
        return (c @ Xb) / mf + self.lam * x

    # -- dual (DADM / SDCA) -------------------------------------------------
    def dual_init(self) -> float:
        """Initial value for every normalized dual coordinate alpha_i
        (v = (1/(lam n)) sum_i alpha_i y_i xi_i)."""
        return 0.0

    def sdca_stepfactor(self, sq_norms, n):
        """Per-sample step factor, precomputed once from ||xi||^2."""
        raise NotImplementedError

    def sdca_delta(self, z, y, alpha, step):
        """Closed-form(ish) SDCA coordinate update Delta alpha_i given the
        current prediction z = x . xi and the precomputed step factor."""
        raise NotImplementedError

    def sdca_damping(self, k):
        """Scale applied to the k dual increments DADM computes concurrently
        per server iteration (k = m * local_batch, traced).  1.0 keeps the
        paper's additive all-gather — safe for duals whose target is
        bounded (logistic's sigmoid, hinge's box).  Unbounded duals (ridge)
        must *average* concurrent exact-maximizer steps instead (the CoCoA
        safe-combination rule): return 1/k."""
        return 1.0


@register_problem
@dataclasses.dataclass(frozen=True)
class LogisticRegression(Problem):
    """Paper Eq. 4 — delegates to `lr.py` so legacy curves stay
    bit-identical."""

    name: ClassVar[str] = "logistic"

    def dloss(self, z, y):
        return -jax.nn.sigmoid(-(y * z)) * y

    def test_loss(self, x, X, y):
        return lr.test_logloss(x, X, y)

    def point_grad(self, x, xi, yi):
        return lr.lr_grad(x, xi, yi, self.lam)

    def dual_init(self) -> float:
        return 0.5                       # alpha in (0, 1)

    def sdca_stepfactor(self, sq_norms, n):
        # logistic is 1/4-smooth: min(1, lam n / (||xi||^2/4 + lam n))
        return jnp.minimum(1.0, (self.lam * n)
                           / (sq_norms / 4.0 + self.lam * n))

    def sdca_delta(self, z, y, alpha, step):
        return (jax.nn.sigmoid(-(y * z)) - alpha) * step


@register_problem
@dataclasses.dataclass(frozen=True)
class RidgeRegression(Problem):
    """L2-regularized least squares on the +-1 ruler labels:
    phi(z, y) = (z - y)^2 / 2.  The exact SDCA coordinate step is
    Delta alpha = (y - z - alpha) / (1 + ||xi||^2 / (lam n))."""

    name: ClassVar[str] = "ridge"

    def dloss(self, z, y):
        return z - y

    def test_loss(self, x, X, y):
        r = X @ x - y
        return 0.5 * jnp.mean(r * r)

    def sdca_stepfactor(self, sq_norms, n):
        return (self.lam * n) / (self.lam * n + sq_norms)

    def sdca_delta(self, z, y, alpha, step):
        # alpha is the y-normalized dual (v sums alpha_i y_i xi_i), so the
        # optimum is alpha* = y (y - z) = 1 - y z for labels in {-1, +1}
        return (1.0 - y * z - alpha) * step

    def sdca_damping(self, k):
        # the squared-loss dual is unconstrained: adding k concurrent
        # exact-maximizer steps overshoots and diverges; averaging them is
        # always safe (convex combination of safe points)
        return 1.0 / k


@register_problem
@dataclasses.dataclass(frozen=True)
class HingeSVM(Problem):
    """Soft-margin SVM: phi(z, y) = max(0, 1 - y z).  Primal uses the
    subgradient; the dual is the classic box-constrained SDCA update with
    the normalized coordinate alpha_i in [0, 1]."""

    name: ClassVar[str] = "hinge"

    def dloss(self, z, y):
        return -y * (y * z < 1.0).astype(jnp.float32)

    def test_loss(self, x, X, y):
        return jnp.mean(jnp.maximum(0.0, 1.0 - y * (X @ x)))

    def sdca_stepfactor(self, sq_norms, n):
        # exact line search scale 1/q with q = ||xi||^2 / (lam n); the box
        # clip in sdca_delta bounds the update for near-zero rows
        return (self.lam * n) / jnp.maximum(sq_norms, 1e-12)

    def sdca_delta(self, z, y, alpha, step):
        return jnp.clip(alpha + (1.0 - y * z) * step, 0.0, 1.0) - alpha

    def sdca_damping(self, k):
        # the exact hinge step jumps between the box corners, so k additive
        # concurrent updates oscillate (and the jumps amplify 1-ulp
        # execution-order differences into macroscopic divergence);
        # averaging restores monotone-ish progress and determinism
        return 1.0 / k
