"""Stochastic quantization — ECD-PSGD's compression operator C(.).

Unbiased (E[dequantize(quantize(x))] = x) per Tang et al.'s requirement
(Eq. 7: E(C(z) - z) = 0), implemented as stochastic rounding to ``bits``-bit
integers with a per-tensor scale.  The Pallas TPU kernel in
``repro.kernels.quantize`` implements the same operator; this jnp version is
its oracle (ref.py re-exports it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_stochastic(x, key, *, bits=8):
    """x -> (q int8/int16, scale f32).  Stochastic rounding => unbiased."""
    assert bits in (4, 8, 16)
    qmax = 2.0 ** (bits - 1) - 1.0
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / qmax
    u = jax.random.uniform(key, x.shape, jnp.float32)
    q = jnp.floor(xf / scale + u)
    q = jnp.clip(q, -qmax - 1, qmax)
    dt = jnp.int8 if bits <= 8 else jnp.int16
    return q.astype(dt), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_error(x, key, *, bits=8):
    q, s = quantize_stochastic(x, key, bits=bits)
    return dequantize(q, s) - x.astype(jnp.float32)
