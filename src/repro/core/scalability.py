"""Gain / gain-growth / upper-bound machinery (paper §V).

Definitions (§V.B.1):
  cost        = iterations per worker to reach a fixed epsilon
  gain        = goal-function value at a fixed iteration
  gain growth = (1) goal-value difference between m and m+1 workers at a
                    fixed iteration  (synchronous algorithms), or
                (2) cost difference between m and m+1 workers (ASGD/DADM)

Upper bound m_max (§V.B.2):
  synchronous: the m where gain growth falls below the parallel-cost
  threshold; ASGD: the m where gain growth turns negative.

Theory-side predictor (Thm 2): for Hogwild! each worker trains
  t/m = (1/m + 6 rho + 6 m Omega delta^{1/2}) * Omega * h(eps)
so the predicted m_max is argmin_m (1/m + 6 m Omega delta^{1/2}) — computed
directly from the dataset characters.

These are the *scalar, single-curve oracles*: deliberately simple Python
loops over one curve, kept verbatim as the reference the vectorized forms
are parity-tested against.  Production consumers go through
`repro.analysis` — `analysis.stats` broadcasts the measurement helpers
over seed/grid axes and adds bootstrap CIs, `analysis.fit` replaces the
``while m < 4096`` predictor searches with vectorized scans (same answers,
pinned by tests/test_analysis.py) and fits the Thm-2 cost law to measured
curves.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List

import numpy as np

from repro.core import metrics as MX


# ---------------------------------------------------------------------------
# Measurement side
# ---------------------------------------------------------------------------

def iterations_to_epsilon(losses: np.ndarray, eval_every: int,
                          epsilon: float) -> float:
    """Server iterations until test loss <= epsilon (inf if never)."""
    hits = np.nonzero(np.asarray(losses) <= epsilon)[0]
    if len(hits) == 0:
        return math.inf
    return float((hits[0] + 1) * eval_every)


def cost_per_worker(result: Dict, epsilon: float, *, asynchronous: bool):
    """The paper's 'cost': iterations each worker performs to reach eps.
    Async algorithms divide server iterations among workers (PCA §V.A.1)."""
    it = iterations_to_epsilon(result["losses"], result["eval_every"], epsilon)
    return it / result["m"] if asynchronous else it


def gain_growth_from_costs(costs: List[float]) -> List[float]:
    """Second definition: cost_m - cost_{m+1} (positive = still gaining)."""
    return [costs[i] - costs[i + 1] for i in range(len(costs) - 1)]


def gain_growth_from_losses(results: List[Dict], at_iteration: int):
    """First definition: loss(m) - loss(m+1) at a fixed server iteration.

    The eval index clamps to [0, n_evals): iterations below one eval
    period read the *first* eval (``at_iteration=0`` used to wrap to
    index -1, silently reading the last one) and iterations beyond the
    budget read the last."""
    vals = []
    for r in results:
        i = min(at_iteration // r["eval_every"], len(r["losses"])) - 1
        vals.append(float(r["losses"][max(i, 0)]))
    return [vals[i] - vals[i + 1] for i in range(len(vals) - 1)]


def measured_upper_bound(ms: List[int], gain_growths: List[float],
                         threshold: float = 0.0) -> int:
    """First m whose gain growth drops to <= threshold; the paper marks the
    bound 'between two red values' — we return the lower one."""
    for i, g in enumerate(gain_growths):
        if g <= threshold:
            return ms[i]
    return ms[-1]          # bound not reached within the sweep


# ---------------------------------------------------------------------------
# Theory side (dataset characters -> predicted m_max)
# ---------------------------------------------------------------------------

def hogwild_cost_model(m, omega, delta, rho):
    """Thm 2 per-worker cost shape: 1/m + 6 rho + 6 m Omega delta^{1/2}."""
    return 1.0 / m + 6.0 * rho + 6.0 * m * omega * math.sqrt(delta)


def predict_hogwild_mmax(X, *, m_cap=4096) -> Dict:
    """Dataset -> predicted Hogwild! scalability upper bound."""
    hw = MX.hogwild_params(X)
    # normalized support fraction (see metrics.hogwild_params): keeps the
    # Thm 2 cost model dimensionless across feature counts
    omega_term = hw["omega_frac"] * math.sqrt(hw["delta"])
    # analytic argmin of 1/m + 6 m * omega_term
    m_star = 1.0 / math.sqrt(6.0 * omega_term) if omega_term > 0 else m_cap
    # largest m still beating the 1-worker cost
    c1 = hogwild_cost_model(1, hw["omega_frac"], hw["delta"], hw["rho"])
    m_max = 1
    for m in range(2, m_cap + 1):
        if hogwild_cost_model(m, hw["omega_frac"], hw["delta"], hw["rho"]) < c1:
            m_max = m
        else:
            break
    return {**hw, "omega_delta_term": omega_term,
            "m_star": m_star, "predicted_m_max": m_max}


def predict_sync_gain_growth(m, variance_proxy):
    """Thm 3/4: the parallel gain scales like sigma/sqrt(m); gain growth
    between m and m+1 is sigma (1/sqrt(m) - 1/sqrt(m+1))."""
    return variance_proxy * (1.0 / math.sqrt(m) - 1.0 / math.sqrt(m + 1))


def predict_sync_mmax(X, *, parallel_cost=1e-3, m_cap=4096) -> Dict:
    """Mini-batch SGD / ECD-PSGD: m_max where the variance-driven gain growth
    can no longer cover the (configurable) parallel cost."""
    sigma = math.sqrt(max(MX.mean_feature_variance(X), 1e-12))
    m = 1
    while m < m_cap and predict_sync_gain_growth(m, sigma) > parallel_cost:
        m += 1
    return {"sigma_proxy": sigma, "parallel_cost": parallel_cost,
            "predicted_m_max": m}


def predict_dadm_mmax(X, *, parallel_cost=1e-3, m_cap=4096) -> Dict:
    """DADM gain ~ 1/m (diversity-limited): growth 1/m - 1/(m+1); scaled by
    the diversity ratio (duplicated shards solve identical subproblems)."""
    div = MX.diversity_ratio(X)
    m = 1
    while m < m_cap and div * (1.0 / m - 1.0 / (m + 1)) > parallel_cost:
        m += 1
    return {"diversity_ratio": div, "parallel_cost": parallel_cost,
            "predicted_m_max": m}
