"""ScalabilityAdvisor — the paper's contribution as a first-class framework
feature: measure the dataset/gradient characters the trainer actually sees
and report the predicted scalability envelope next to the measured curve.

Production usage (any of the 10 archs):
    advisor = ScalabilityAdvisor()
    report = advisor.from_grads(per_shard_grads)    # gradient-level characters
    report = advisor.from_dataset(X, ...)           # raw-dataset characters
Both return {characters..., predicted m_max per strategy, recommendation}.

The m_max searches go through the vectorized scaling-law predictors in
`repro.analysis.fit` (one array scan over the m grid) rather than the
``while m < 4096`` Python loops of `repro.core.scalability` — those stay
as the scalar oracles, and tests/test_analysis.py pins the two paths to
identical answers.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.analysis import fit as FIT
from repro.core import metrics as MX


def _flatten(tree):
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32)
                            for x in jax.tree.leaves(tree)])


class ScalabilityAdvisor:
    def __init__(self, *, parallel_cost=1e-3, sparsity_tol=1e-8):
        self.parallel_cost = parallel_cost
        self.tol = sparsity_tol

    # -- gradient-level characters (production tier) ------------------------
    def grad_characters(self, per_shard_grads: List) -> Dict:
        """per_shard_grads: list of grad pytrees, one per data shard (or per
        microbatch) — the sample-difference proxies of §IV measured on the
        gradients the optimizer actually consumes."""
        flats = jnp.stack([_flatten(g) for g in per_shard_grads])   # (m, P)
        gvar = float(jnp.mean(jnp.var(flats, axis=0)))
        gmean_sq = float(jnp.mean(jnp.mean(flats, axis=0) ** 2))
        sparsity = float(jnp.mean(jnp.abs(flats) <= self.tol))
        # pairwise cosine similarity across shards = LS proxy
        normed = flats / (jnp.linalg.norm(flats, axis=1, keepdims=True) + 1e-9)
        cos = normed @ normed.T
        m = flats.shape[0]
        off = (jnp.sum(cos) - m) / (m * (m - 1) + 1e-9)
        return {
            "grad_variance": gvar,
            "grad_noise_scale": gvar / (gmean_sq + 1e-12),
            "grad_sparsity": sparsity,
            "shard_cosine_similarity": float(off),
        }

    def from_grads(self, per_shard_grads: List) -> Dict:
        ch = self.grad_characters(per_shard_grads)
        # gradient-noise-scale plays sigma's role in the Thm 3 curve;
        # the m-search is the vectorized grid scan, not a Python loop
        sigma = ch["grad_noise_scale"] ** 0.5
        ch["predicted_m_max_sync"] = FIT.sync_mmax(sigma, self.parallel_cost)
        # Hogwild staleness tolerance needs gradient sparsity
        om = (1.0 - ch["grad_sparsity"])
        ch["predicted_m_max_stale"] = max(
            1, int((1.0 / (6.0 * max(om, 1e-6))) ** 0.5))
        ch["recommendation"] = self._recommend(ch)
        return ch

    # -- dataset-level characters (faithful tier) ---------------------------
    def from_dataset(self, X, *, tau_max=8, batch_size=8, beta=0.9,
                     sync_every=4, anchor_every=100) -> Dict:
        ch = MX.summarize(X, tau_max=tau_max, batch_size=batch_size)
        ch["hogwild"] = FIT.predict_hogwild_mmax(X)
        ch["sync"] = FIT.predict_sync_mmax(X, parallel_cost=self.parallel_cost)
        ch["dadm"] = FIT.predict_dadm_mmax(X, parallel_cost=self.parallel_cost)
        # critical-parameter envelopes: same characters, knob-shifted cliffs
        ch["momentum"] = FIT.predict_momentum_mmax(
            X, beta=beta, parallel_cost=self.parallel_cost)
        ch["local_sgd"] = FIT.predict_local_sgd_mmax(
            X, sync_every=sync_every, parallel_cost=self.parallel_cost)
        ch["svrg"] = FIT.predict_svrg_mmax(X, anchor_every=anchor_every)
        ch["recommendation"] = self._recommend_dataset(ch)
        return ch

    def _recommend(self, ch: Dict) -> str:
        if ch["grad_sparsity"] > 0.5:
            return ("sparse gradients: async/stale exchange scales "
                    f"(predicted m_max ~{ch['predicted_m_max_stale']}); "
                    "sync batch scaling limited")
        if ch["grad_noise_scale"] > 1.0:
            return ("high gradient noise: sync batch scaling pays off up to "
                    f"m~{ch['predicted_m_max_sync']}")
        return ("low gradient noise: batch scaling saturates early "
                f"(m_max~{ch['predicted_m_max_sync']}); consider gossip to "
                "cut exchange cost instead of adding workers")

    def _recommend_dataset(self, ch: Dict) -> str:
        if ch["sparsity"] > 0.9:
            return ("sparse + low-variance dataset: Hogwild!-class (predicted "
                    f"m_max {ch['hogwild']['predicted_m_max']}, "
                    f"{ch['svrg']['predicted_m_max']} with semi-stochastic "
                    "gradients); mini-batch gains will be minor (paper "
                    "Fig 3b)")
        if ch["mean_feature_variance"] > 1.0:
            return ("dense high-variance dataset: mini-batch SGD/ECD-PSGD "
                    f"class, m_max ~{ch['sync']['predicted_m_max']} "
                    "(paper Fig 3a)")
        if ch["diversity_ratio"] < 0.5:
            return ("low diversity: DADM and all model-average methods "
                    "saturate early (paper Fig 6); deduplicate or reshuffle")
        return ("balanced characters: any strategy; bound set by parallel "
                "cost — a local-SGD sync window amortizes it (predicted "
                f"m_max {ch['local_sgd']['predicted_m_max']} vs sync "
                f"{ch['sync']['predicted_m_max']})")
