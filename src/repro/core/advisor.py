"""ScalabilityAdvisor — the paper's contribution as a first-class framework
feature: measure the dataset/gradient characters the trainer actually sees
and report the predicted scalability envelope next to the measured curve.

Production usage (any of the 10 archs):
    advisor = ScalabilityAdvisor()
    report = advisor.from_grads(per_shard_grads)    # gradient-level characters
    report = advisor.from_dataset(X, ...)           # raw-dataset characters
Both return {characters..., predicted m_max per strategy, recommendation}.
Invalid probes (empty/single-element shard lists, non-finite values,
too-small datasets) return a structured low-confidence report
(``valid: False`` + ``reason``) instead of NaN characters or a raise —
`repro.service` turns those into graceful API responses.

The m_max searches go through the vectorized scaling-law predictors in
`repro.analysis.fit` (one array scan over the m grid) rather than the
``while m < 4096`` Python loops of `repro.core.scalability` — those stay
as the scalar oracles, and tests/test_analysis.py pins the two paths to
identical answers.

Batched probes: :func:`masked_dataset_characters` and
:func:`masked_grad_characters` are the slots-batched twins of the scalar
character measurements — pure jnp over a padded ``(n_slots, ...)`` batch
with row/column validity masks, so `repro.service.batcher` can answer N
concurrent probes with ONE vmapped-style jitted call (pad-to-slot, the
same masked-batch idiom the sweep engine and `serve.SlotDriver` use)
instead of N sequential `from_dataset`/`from_grads` calls.  Padded rows/
columns/slots are exact no-ops: every reduction is mask-weighted, so the
batched characters match the sequential ones (pinned <= 1e-6 in
tests/test_service.py).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.analysis import fit as FIT
from repro.core import metrics as MX


def _flatten(tree):
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32)
                            for x in jax.tree.leaves(tree)])


# ---------------------------------------------------------------------------
# masked (slots-batched) character kernels — the service's batched path
# ---------------------------------------------------------------------------

def masked_dataset_characters(Xs, row_mask, col_mask) -> Dict:
    """Slots-batched §IV dataset characters under validity masks.

    ``Xs``: ``(n_slots, R, D)`` zero-padded datasets; ``row_mask``
    ``(n_slots, R)`` and ``col_mask`` ``(n_slots, D)`` are 1.0 on real
    rows/columns.  Returns ``(n_slots,)`` arrays for every maskable
    character (variance, sparsity, density, the Thm-2 Hogwild! params);
    `diversity` needs an exact row dedup and stays a host-side per-slot
    pass (see `ScalabilityAdvisor.dataset_characters_batch`).  All-padding
    slots (inactive batch slots) produce zeros, never NaN."""
    rm = row_mask[:, :, None]                        # (s, R, 1)
    cm = col_mask[:, None, :]                        # (s, 1, D)
    cell = rm * cm                                   # (s, R, D)
    n = jnp.sum(row_mask, axis=1)                    # (s,)
    d = jnp.sum(col_mask, axis=1)                    # (s,)
    n_safe = jnp.maximum(n, 1.0)
    d_safe = jnp.maximum(d, 1.0)

    mean = jnp.sum(Xs * cell, axis=1) / n_safe[:, None]          # (s, D)
    var_k = jnp.sum(((Xs - mean[:, None, :]) * rm) ** 2 * cm,
                    axis=1) / n_safe[:, None]                    # (s, D)
    mean_feature_variance = jnp.sum(var_k * col_mask,
                                    axis=1) / d_safe
    zeros = (jnp.abs(Xs) <= 0.0).astype(jnp.float32) * cell
    sparsity = jnp.sum(zeros, axis=(1, 2)) / (n_safe * d_safe)

    nz = (jnp.abs(Xs) > 0.0).astype(jnp.float32) * cell          # (s, R, D)
    omega = jnp.max(jnp.sum(nz, axis=2), axis=1)                 # (s,)
    freq = jnp.sum(nz, axis=1) / n_safe[:, None]                 # (s, D)
    delta = jnp.max(freq, axis=1)
    rho = jnp.minimum(jnp.sum(freq * freq, axis=1), 1.0)
    return {
        "n": n, "d": d,
        "mean_feature_variance": mean_feature_variance,
        "sparsity": sparsity,
        "density": 1.0 - sparsity,
        "omega": omega,
        "omega_frac": omega / d_safe,
        "delta": delta,
        "rho": rho,
    }


def masked_grad_characters(flats, shard_mask, param_mask) -> Dict:
    """Slots-batched gradient-level characters under validity masks.

    ``flats``: ``(n_slots, M, P)`` zero-padded flattened per-shard grads;
    ``shard_mask`` ``(n_slots, M)`` / ``param_mask`` ``(n_slots, P)`` mark
    real shards/parameters.  Same proxies as
    `ScalabilityAdvisor.grad_characters`, mask-weighted so padding is an
    exact no-op."""
    sm = shard_mask[:, :, None]                      # (s, M, 1)
    pm = param_mask[:, None, :]                      # (s, 1, P)
    cell = sm * pm
    m = jnp.sum(shard_mask, axis=1)                  # (s,)
    p = jnp.sum(param_mask, axis=1)
    m_safe = jnp.maximum(m, 1.0)
    p_safe = jnp.maximum(p, 1.0)

    mean = jnp.sum(flats * cell, axis=1) / m_safe[:, None]       # (s, P)
    var = jnp.sum(((flats - mean[:, None, :]) * sm) ** 2 * pm,
                  axis=1) / m_safe[:, None]
    gvar = jnp.sum(var * param_mask, axis=1) / p_safe
    gmean_sq = jnp.sum((mean ** 2) * param_mask, axis=1) / p_safe
    sparsity = jnp.sum((jnp.abs(flats) <= SPARSITY_TOL) * cell,
                       axis=(1, 2)) / (m_safe * p_safe)

    normed = flats * cell / (
        jnp.linalg.norm(flats * cell, axis=2, keepdims=True) + 1e-9)
    cos = jnp.einsum("smp,snp->smn", normed, normed)
    pair = sm * shard_mask[:, None, :]               # (s, M, M)
    off = (jnp.sum(cos * pair, axis=(1, 2)) - m) / (m * (m - 1.0) + 1e-9)
    return {
        "grad_variance": gvar,
        "grad_noise_scale": gvar / (gmean_sq + 1e-12),
        "grad_sparsity": sparsity,
        "shard_cosine_similarity": off,
    }


#: default |g| <= tol sparsity threshold shared by the scalar and masked
#: gradient paths (ScalabilityAdvisor(sparsity_tol=) overrides per
#: instance for the scalar path)
SPARSITY_TOL = 1e-8


class ScalabilityAdvisor:
    def __init__(self, *, parallel_cost=1e-3, sparsity_tol=SPARSITY_TOL):
        self.parallel_cost = parallel_cost
        self.tol = sparsity_tol

    # -- input validation (the service front door hits these) ---------------
    @staticmethod
    def validate_grads(per_shard_grads) -> Optional[str]:
        """None when the shard list supports character measurement, else a
        human-readable reason (empty list, a single shard — no cross-shard
        signal — or non-finite gradient values)."""
        if per_shard_grads is None or len(per_shard_grads) == 0:
            return "empty shard list — no gradients to measure"
        if len(per_shard_grads) == 1:
            return ("single gradient shard — cross-shard variance and "
                    "similarity need >= 2 shards")
        for i, g in enumerate(per_shard_grads):
            leaves = jax.tree.leaves(g)
            if not leaves or all(x.size == 0 for x in map(jnp.asarray,
                                                          leaves)):
                return f"shard {i} carries no gradient values"
            if not all(bool(jnp.isfinite(jnp.asarray(x)).all())
                       for x in leaves):
                return f"shard {i} contains non-finite gradient values"
        return None

    @staticmethod
    def validate_dataset(X) -> Optional[str]:
        """None when X supports character measurement, else the reason
        (empty, not a matrix, < 2 rows, or non-finite values)."""
        if X is None:
            return "no dataset provided"
        X = jnp.asarray(X)
        if X.ndim != 2:
            return f"dataset must be a (rows, features) matrix, got " \
                   f"shape {tuple(X.shape)}"
        if X.shape[0] < 2 or X.shape[1] < 1:
            return (f"dataset of shape {tuple(X.shape)} is too small — "
                    f"character measurement needs >= 2 rows and >= 1 "
                    f"feature")
        if not bool(jnp.isfinite(X).all()):
            return "dataset contains non-finite values"
        return None

    @staticmethod
    def invalid_report(kind: str, reason: str) -> Dict:
        """Structured low-confidence report for an unmeasurable probe: the
        conservative m_max is 1 worker, confidence is 0, and the caller is
        told to fix the probe — never NaN characters, never a raise."""
        return {
            "valid": False, "kind": kind, "reason": reason,
            "confidence": 0.0,
            "predicted_m_max_conservative": 1,
            "recommendation": (f"invalid {kind} probe: {reason}; fix the "
                               f"probe input — no scalability estimate is "
                               f"trustworthy for it"),
        }

    # -- gradient-level characters (production tier) ------------------------
    def grad_characters(self, per_shard_grads: List) -> Dict:
        """per_shard_grads: list of grad pytrees, one per data shard (or per
        microbatch) — the sample-difference proxies of §IV measured on the
        gradients the optimizer actually consumes."""
        flats = jnp.stack([_flatten(g) for g in per_shard_grads])   # (m, P)
        gvar = float(jnp.mean(jnp.var(flats, axis=0)))
        gmean_sq = float(jnp.mean(jnp.mean(flats, axis=0) ** 2))
        sparsity = float(jnp.mean(jnp.abs(flats) <= self.tol))
        # pairwise cosine similarity across shards = LS proxy
        normed = flats / (jnp.linalg.norm(flats, axis=1, keepdims=True) + 1e-9)
        cos = normed @ normed.T
        m = flats.shape[0]
        off = (jnp.sum(cos) - m) / (m * (m - 1) + 1e-9)
        return {
            "grad_variance": gvar,
            "grad_noise_scale": gvar / (gmean_sq + 1e-12),
            "grad_sparsity": sparsity,
            "shard_cosine_similarity": float(off),
        }

    def _grad_report(self, ch: Dict) -> Dict:
        """Predictions + recommendation from measured gradient characters
        (shared by the scalar `from_grads` and the service's batched path,
        so the two produce identical answers for identical characters)."""
        # gradient-noise-scale plays sigma's role in the Thm 3 curve;
        # the m-search is the vectorized grid scan, not a Python loop
        sigma = ch["grad_noise_scale"] ** 0.5
        ch["predicted_m_max_sync"] = FIT.sync_mmax(sigma, self.parallel_cost)
        # Hogwild staleness tolerance needs gradient sparsity
        om = (1.0 - ch["grad_sparsity"])
        ch["predicted_m_max_stale"] = max(
            1, int((1.0 / (6.0 * max(om, 1e-6))) ** 0.5))
        ch["recommendation"] = self._recommend(ch)
        ch["valid"] = True
        return ch

    def from_grads(self, per_shard_grads: List) -> Dict:
        reason = self.validate_grads(per_shard_grads)
        if reason is not None:
            return self.invalid_report("grads", reason)
        return self._grad_report(self.grad_characters(per_shard_grads))

    # -- dataset-level characters (faithful tier) ---------------------------
    def from_dataset(self, X, *, tau_max=8, batch_size=8, beta=0.9,
                     sync_every=4, anchor_every=100) -> Dict:
        reason = self.validate_dataset(X)
        if reason is not None:
            return self.invalid_report("dataset", reason)
        ch = MX.summarize(X, tau_max=tau_max, batch_size=batch_size)
        ch["hogwild"] = FIT.predict_hogwild_mmax(X)
        ch["sync"] = FIT.predict_sync_mmax(X, parallel_cost=self.parallel_cost)
        ch["dadm"] = FIT.predict_dadm_mmax(X, parallel_cost=self.parallel_cost)
        # critical-parameter envelopes: same characters, knob-shifted cliffs
        ch["momentum"] = FIT.predict_momentum_mmax(
            X, beta=beta, parallel_cost=self.parallel_cost)
        ch["local_sgd"] = FIT.predict_local_sgd_mmax(
            X, sync_every=sync_every, parallel_cost=self.parallel_cost)
        ch["svrg"] = FIT.predict_svrg_mmax(X, anchor_every=anchor_every)
        ch["recommendation"] = self._recommend_dataset(ch)
        ch["valid"] = True
        return ch

    # -- batched probes (one jitted masked-batch call for N requests) -------
    def dataset_characters_batch(self, Xs: List, n_slots: int = 0
                                 ) -> List[Optional[Dict]]:
        """Characters for N raw datasets in ONE masked-batch computation.

        Pads every dataset to the group's (rows, features) envelope and a
        slot count of ``max(n_slots, len(Xs))``, runs
        :func:`masked_dataset_characters` once, then finishes the one
        non-vmappable index (exact-dedup `diversity`) per slot on host.
        Invalid entries come back as None (callers pair them with
        :meth:`invalid_report`); the returned dicts carry exactly the
        characters the `repro.analysis.fit` ``*_from_characters``
        predictors consume."""
        reasons = [self.validate_dataset(X) for X in Xs]
        valid = [i for i, r in enumerate(reasons) if r is None]
        out: List[Optional[Dict]] = [None] * len(Xs)
        if not valid:
            return out
        slots = max(int(n_slots), len(Xs))
        arrs = [jnp.asarray(Xs[i], jnp.float32) for i in valid]
        R = max(a.shape[0] for a in arrs)
        D = max(a.shape[1] for a in arrs)
        Xp = jnp.zeros((slots, R, D), jnp.float32)
        row_m = jnp.zeros((slots, R), jnp.float32)
        col_m = jnp.zeros((slots, D), jnp.float32)
        for s, a in enumerate(arrs):
            Xp = Xp.at[s, :a.shape[0], :a.shape[1]].set(a)
            row_m = row_m.at[s, :a.shape[0]].set(1.0)
            col_m = col_m.at[s, :a.shape[1]].set(1.0)
        batched = _masked_dataset_characters_jit(Xp, row_m, col_m)
        batched = jax.device_get(batched)
        for s, i in enumerate(valid):
            ch = {k: (int(v[s]) if k in ("n", "d") else float(v[s]))
                  for k, v in batched.items()}
            # exact row dedup stays on host: np.unique has no masked
            # fixed-shape analogue worth jitting
            ch["diversity"] = MX.diversity(Xs[i])
            ch["diversity_ratio"] = ch["diversity"] / max(ch["n"], 1)
            out[i] = ch
        return out

    def grad_characters_batch(self, grads_list: List, n_slots: int = 0
                              ) -> List[Optional[Dict]]:
        """Gradient characters for N per-shard-grad probes in ONE masked
        batch (the `from_grads` twin of :meth:`dataset_characters_batch`);
        invalid entries come back as None."""
        reasons = [self.validate_grads(g) for g in grads_list]
        valid = [i for i, r in enumerate(reasons) if r is None]
        out: List[Optional[Dict]] = [None] * len(grads_list)
        if not valid:
            return out
        slots = max(int(n_slots), len(grads_list))
        flats = [[_flatten(g) for g in grads_list[i]] for i in valid]
        M_ = max(len(f) for f in flats)
        P = max(f[0].shape[0] for f in flats)
        Fp = jnp.zeros((slots, M_, P), jnp.float32)
        shard_m = jnp.zeros((slots, M_), jnp.float32)
        param_m = jnp.zeros((slots, P), jnp.float32)
        for s, shards in enumerate(flats):
            for j, f in enumerate(shards):
                Fp = Fp.at[s, j, :f.shape[0]].set(f)
            shard_m = shard_m.at[s, :len(shards)].set(1.0)
            param_m = param_m.at[s, :shards[0].shape[0]].set(1.0)
        batched = jax.device_get(
            _masked_grad_characters_jit(Fp, shard_m, param_m))
        for s, i in enumerate(valid):
            out[i] = {k: float(v[s]) for k, v in batched.items()}
        return out

    def _recommend(self, ch: Dict) -> str:
        if ch["grad_sparsity"] > 0.5:
            return ("sparse gradients: async/stale exchange scales "
                    f"(predicted m_max ~{ch['predicted_m_max_stale']}); "
                    "sync batch scaling limited")
        if ch["grad_noise_scale"] > 1.0:
            return ("high gradient noise: sync batch scaling pays off up to "
                    f"m~{ch['predicted_m_max_sync']}")
        return ("low gradient noise: batch scaling saturates early "
                f"(m_max~{ch['predicted_m_max_sync']}); consider gossip to "
                "cut exchange cost instead of adding workers")

    def _recommend_dataset(self, ch: Dict) -> str:
        if ch["sparsity"] > 0.9:
            return ("sparse + low-variance dataset: Hogwild!-class (predicted "
                    f"m_max {ch['hogwild']['predicted_m_max']}, "
                    f"{ch['svrg']['predicted_m_max']} with semi-stochastic "
                    "gradients); mini-batch gains will be minor (paper "
                    "Fig 3b)")
        if ch["mean_feature_variance"] > 1.0:
            return ("dense high-variance dataset: mini-batch SGD/ECD-PSGD "
                    f"class, m_max ~{ch['sync']['predicted_m_max']} "
                    "(paper Fig 3a)")
        if ch["diversity_ratio"] < 0.5:
            return ("low diversity: DADM and all model-average methods "
                    "saturate early (paper Fig 6); deduplicate or reshuffle")
        return ("balanced characters: any strategy; bound set by parallel "
                "cost — a local-SGD sync window amortizes it (predicted "
                f"m_max {ch['local_sgd']['predicted_m_max']} vs sync "
                f"{ch['sync']['predicted_m_max']})")


_masked_dataset_characters_jit = jax.jit(masked_dataset_characters)
_masked_grad_characters_jit = jax.jit(masked_grad_characters)
