"""Mini-batch SGD (Alg 2) under the PCA.

One worker computes one sample's gradient per server iteration; the server
averages batch_size of them (all-gather in Alg 2 => the degree of parallelism
IS the batch size, Fact 1).  Iteration count on the x-axis is *server*
iterations, so larger batch = more parallel workers at the same x.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.algorithms.lr import lr_grad_batch, test_logloss, LAMBDA


@functools.partial(jax.jit,
                   static_argnames=("batch_size", "iters", "eval_every"))
def _run(X, y, Xte, yte, key, batch_size, iters, gamma, lam, eval_every):
    n, d = X.shape
    order = jax.random.randint(key, (iters, batch_size), 0, n)

    def step(x, idx):
        g = lr_grad_batch(x, X[idx], y[idx], lam)
        return x - gamma * g, None

    n_evals = iters // eval_every

    def outer(x, e):
        x, _ = jax.lax.scan(step, x, order[e * eval_every:(e + 1) * eval_every]
                            if False else jax.lax.dynamic_slice_in_dim(
                                order, e * eval_every, eval_every, axis=0))
        return x, test_logloss(x, Xte, yte)

    x, losses = jax.lax.scan(outer, jnp.zeros((d,)), jnp.arange(n_evals))
    return x, losses


def run_minibatch(train, test, *, batch_size=4, iters=4000, gamma=0.1,
                  lam=LAMBDA, eval_every=100, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key,
                     batch_size, iters, gamma, lam, eval_every)
    return {
        "algorithm": "minibatch",
        "m": batch_size,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters,   # synchronous: every worker runs them all
    }
