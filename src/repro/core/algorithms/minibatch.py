"""Mini-batch SGD (Alg 2) under the PCA.

One worker computes one sample's gradient per server iteration; the server
averages m of them (all-gather in Alg 2 => the degree of parallelism IS the
batch size, Fact 1).  Iteration count on the x-axis is *server* iterations,
so larger batch = more parallel workers at the same x.

:class:`Minibatch` is the engine-facing protocol implementation
(`base.Algorithm`); :func:`run_minibatch` is the legacy per-m runner, kept
as a thin deprecated adapter and as the independent oracle the engine
equivalence tests compare against.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)
from repro.core.algorithms.lr import lr_grad_batch, test_logloss, LAMBDA


@register_algorithm
@dataclasses.dataclass(frozen=True)
class Minibatch(Algorithm):
    """m parallel one-sample gradients averaged by the server each step."""

    name: ClassVar[str] = "minibatch"
    bucketed_default: ClassVar[bool] = True      # work is O(m_pad * d)/step

    gamma: float = 0.1

    def make_draws(self, key, n, iters, m_top):
        return jax.random.randint(key, (iters, m_top), 0, n)

    def init_state(self, problem, data, ctx: SimContext):
        return jnp.zeros((data.X.shape[1],))

    def step(self, problem, data, ctx: SimContext, x, idx, t):
        g = problem.masked_batch_grad(x, data.X[idx], data.y[idx],
                                      ctx.active, ctx.mf)
        return x - self.gamma * g

    def readout(self, ctx: SimContext, x):
        return x


@functools.partial(jax.jit,
                   static_argnames=("batch_size", "iters", "eval_every"))
def _run(X, y, Xte, yte, key, batch_size, iters, gamma, lam, eval_every):
    n, d = X.shape
    order = jax.random.randint(key, (iters, batch_size), 0, n)

    def step(x, idx):
        g = lr_grad_batch(x, X[idx], y[idx], lam)
        return x - gamma * g, None

    n_evals = iters // eval_every

    def outer(x, e):
        x, _ = jax.lax.scan(step, x, jax.lax.dynamic_slice_in_dim(
            order, e * eval_every, eval_every, axis=0))
        return x, test_logloss(x, Xte, yte)

    x, losses = jax.lax.scan(outer, jnp.zeros((d,)), jnp.arange(n_evals))
    return x, losses


def run_minibatch(train, test, *, m=None, iters=4000, gamma=0.1,
                  lam=LAMBDA, eval_every=100, key=None, batch_size=None):
    """Legacy per-m logistic runner (deprecated: sweeps should go through
    `repro.experiments.engine`).  The worker count is ``m`` like every other
    entry point; ``batch_size`` is the old name for the same quantity
    (Fact 1) and is kept as a warning shim."""
    if batch_size is not None:
        warnings.warn(
            "run_minibatch(batch_size=...) is deprecated; the degree of "
            "parallelism is named m=... like the other algorithms (Fact 1: "
            "batch size IS the worker count)", DeprecationWarning,
            stacklevel=2)
        if m is not None and m != batch_size:
            raise TypeError(f"conflicting worker counts: m={m} "
                            f"batch_size={batch_size}")
        m = batch_size
    m = 4 if m is None else m
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key,
                     m, iters, gamma, lam, eval_every)
    return {
        "algorithm": "minibatch",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters,   # synchronous: every worker runs them all
    }
