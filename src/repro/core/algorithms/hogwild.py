"""Hogwild! (Alg 1) under the Perfect Computer Assumption.

TPU/SPMD adaptation (DESIGN.md §6): the x86 lock-free shared-memory race is
simulated *deterministically* — the gradient applied at server iteration j
was computed against the model at iteration j - tau, with tau cycling over
[1, m] (Thm 1: with m equal workers the lag is exactly the worker count).
Convergence behaviour depends only on tau_max (Thm 2), so the insight
survives the mechanism swap.

The staleness recurrence is *padding-safe*: :func:`masked_sim` allocates the
model history at a static pad width ``m_pad`` and takes every history index
modulo a **traced** worker count m, so shapes never depend on m — only
indices do, and those stay in ``[0, m)``.  Rows ``>= m`` of the history are
never read or written, which makes the padded run numerically the m-worker
run.  That is what lets `repro.experiments.engine` sweep the whole m-grid
as one ``jax.vmap`` (one trace, one compile) instead of re-jitting per m.

Under the PCA, wall-time for m workers = t_single / m * n_iterations, so the
figures report iterations (server) and iterations-per-worker (= cost).

Since ENGINE_VERSION 5 this sequential recurrence is also the **parity
oracle** for the true multi-device racing mode
(`repro.distributed.hogwild_shards`): there the worker set is split into
per-device shards under ``shard_map`` and the shards genuinely race on a
donated shared parameter, reconciling their deltas every sync round.
The oracle stays the cached, mesh-invariant default the engine sweeps;
the race is the hardware-validation mode (:func:`run_hogwild_sharded`
delegates; divergence regimes are documented in docs/distributed.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar, Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)
from repro.core.algorithms.lr import lr_grad, test_logloss, LAMBDA
from repro.resilience import faults


@register_algorithm
@dataclasses.dataclass(frozen=True)
class Hogwild(Algorithm):
    """Protocol port of the traced-m staleness recurrence below: the model
    history lives at the static pad width, every history index is taken
    modulo the traced m, and the sample sequence is m-independent — so the
    engine sweeps the whole grid as ONE flat vmap (``force_flat``: the
    recurrence updates a single model, work is O(iters * d) regardless of
    the pad width, so bucketing would only add compiles).

    ``fault`` (a `repro.resilience.faults.FaultSpec` or its dict form)
    injects update-delivery faults into the recurrence: a straggle event
    deepens the staleness (``tau + straggle_rounds``, clamped to the
    m-deep history), drop/duplicate scale the landing gradient by 0 / 2,
    corruption rewrites it — all as traced transforms on an ``(iters,)``
    event stream drawn once from the fault seed, so faulted sweeps vmap
    and bucket exactly like unfaulted ones.  Zero-rate specs are
    bit-exact with ``fault=None``.
    """

    name: ClassVar[str] = "hogwild"
    asynchronous: ClassVar[bool] = True      # cost divides iters by m
    bucketed_default: ClassVar[bool] = False
    force_flat: ClassVar[bool] = True
    predictor: ClassVar[str] = "hogwild"

    gamma: float = 0.1
    fault: Optional[faults.FaultSpec] = None

    def __post_init__(self):
        object.__setattr__(self, "fault", faults.resolve(self.fault))

    def make_draws(self, key, n, iters, m_top):
        # identical draw to run_hogwild's: the sequence is m-independent.
        # The fault stream is keyed from the FAULT seed, not the sweep
        # key: faults are environment — every seed replicate faces the
        # same schedule, so the seed axis keeps measuring sampling noise.
        order = jax.random.randint(key, (iters,), 0, n)
        if self.fault is None:
            return order
        return {"i": order,
                "fault": faults.make_stream(self.fault, (iters,))}

    def init_state(self, problem, data, ctx: SimContext):
        d = data.X.shape[1]
        return (jnp.zeros((d,)), jnp.zeros((ctx.m_pad, d)))

    def step(self, problem, data, ctx: SimContext, state, batch, j):
        x, hist = state
        i = batch if self.fault is None else batch["i"]
        # stale model: the one from j - tau, tau = (j % m) + 1 (Thm 1)
        tau = (j % ctx.m) + 1
        if self.fault is not None:
            # straggler: the read is extra rounds stale, clamped to the
            # m-deep history (identity when the event did not fire)
            tau = jnp.minimum(
                tau + faults.extra_staleness(self.fault, batch["fault"]),
                ctx.m)
        x_stale = hist[(j - tau) % ctx.m]
        g = problem.point_grad(x_stale, data.X[i], data.y[i])
        if self.fault is not None:
            g = faults.corrupt(self.fault, g, batch["fault"]["corrupt"])
            g = faults.delivery_scale(batch["fault"]) * g
        x_new = x - self.gamma * g
        return (x_new, hist.at[j % ctx.m].set(x_new))

    def readout(self, ctx: SimContext, state):
        return state[0]


def masked_sim(X, y, Xte, yte, order, *, m_pad, gamma, lam, eval_every,
               n_evals):
    """Build ``sim(m) -> (x, losses)`` with the worker count m as traced data.

    ``m_pad`` is the only shape parameter (history rows); any ``m <= m_pad``
    runs bit-identically to a ``m_pad == m`` allocation because the
    recurrence indexes ``hist`` modulo m.  ``order`` is the shared
    ``(iters,)`` server sample sequence — it is m-independent, so every
    sweep member consumes the same draws.
    """
    d = X.shape[1]

    def sim(m):
        m = jnp.asarray(m, jnp.int32)

        def step(carry, j):
            x, hist = carry                   # hist: (m_pad, d) past models
            # stale model: the one from j - tau, tau = (j % m) + 1
            tau = (j % m) + 1
            x_stale = hist[(j - tau) % m]
            i = order[j]
            g = lr_grad(x_stale, X[i], y[i], lam)
            x_new = x - gamma * g
            hist = hist.at[j % m].set(x_new)
            return (x_new, hist), None

        def outer(carry, e):
            carry, _ = jax.lax.scan(
                step, carry, e * eval_every + jnp.arange(eval_every))
            return carry, test_logloss(carry[0], Xte, yte)

        carry0 = (jnp.zeros((d,)), jnp.zeros((m_pad, d)))
        (x, _), losses = jax.lax.scan(outer, carry0, jnp.arange(n_evals))
        return x, losses

    return sim


@functools.partial(jax.jit, static_argnames=("m_pad", "iters", "eval_every"))
def _run(X, y, Xte, yte, key, m, gamma, lam, *, m_pad, iters, eval_every):
    n = X.shape[0]
    order = jax.random.randint(key, (iters,), 0, n)
    sim = masked_sim(X, y, Xte, yte, order, m_pad=m_pad, gamma=gamma,
                     lam=lam, eval_every=eval_every,
                     n_evals=iters // eval_every)
    return sim(m)


def run_hogwild(train, test, *, m=4, iters=4000, gamma=0.1, lam=LAMBDA,
                eval_every=100, key=None):
    """Returns dict with the convergence curve (server-iteration indexed).

    Thin single-m wrapper over :func:`masked_sim` (padded exactly to m);
    sweeps over many m should go through `engine.sweep_hogwild`, which
    vmaps the same recurrence over the whole grid in one compile.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key, m, gamma, lam,
                     m_pad=m, iters=iters, eval_every=eval_every)
    return {
        "algorithm": "hogwild",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters / m,
    }


def run_hogwild_sharded(train, test, **kwargs):
    """The real race: worker shards on a device mesh updating a donated
    shared parameter (lazy delegate to `repro.distributed.hogwild_shards`
    — `repro.core` stays importable without the distributed package's
    mesh machinery; this recurrence here remains its parity oracle)."""
    from repro.distributed.hogwild_shards import run_hogwild_sharded as fn
    return fn(train, test, **kwargs)
