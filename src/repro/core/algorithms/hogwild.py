"""Hogwild! (Alg 1) under the Perfect Computer Assumption.

TPU/SPMD adaptation (DESIGN.md §6): the x86 lock-free shared-memory race is
simulated *deterministically* — the gradient applied at server iteration j
was computed against the model at iteration j - tau, with tau cycling over
[1, m] (Thm 1: with m equal workers the lag is exactly the worker count).
Convergence behaviour depends only on tau_max (Thm 2), so the insight
survives the mechanism swap.

Under the PCA, wall-time for m workers = t_single / m * n_iterations, so the
figures report iterations (server) and iterations-per-worker (= cost).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.algorithms.lr import lr_grad, test_logloss, LAMBDA


@functools.partial(jax.jit, static_argnames=("m", "iters", "eval_every"))
def _run(X, y, Xte, yte, key, m, iters, gamma, lam, eval_every):
    n, d = X.shape
    order = jax.random.randint(key, (iters,), 0, n)

    def step(carry, j):
        x, hist = carry                       # hist: (m, d) past models
        # stale model: the one from j - tau, tau = (j % m) + 1
        tau = (j % m) + 1
        x_stale = hist[(j - tau) % m]
        i = order[j]
        g = lr_grad(x_stale, X[i], y[i], lam)
        x_new = x - gamma * g
        hist = hist.at[j % m].set(x_new)
        return (x_new, hist), None

    x0 = jnp.zeros((d,))
    hist0 = jnp.zeros((m, d))
    n_evals = iters // eval_every

    def outer(carry, e):
        carry, _ = jax.lax.scan(
            step, carry, e * eval_every + jnp.arange(eval_every))
        return carry, test_logloss(carry[0], Xte, yte)

    (x, _), losses = jax.lax.scan(outer, (x0, hist0), jnp.arange(n_evals))
    return x, losses


def run_hogwild(train, test, *, m=4, iters=4000, gamma=0.1, lam=LAMBDA,
                eval_every=100, key=None):
    """Returns dict with the convergence curve (server-iteration indexed)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key,
                     m, iters, gamma, lam, eval_every)
    return {
        "algorithm": "hogwild",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters / m,
    }
