"""The `Algorithm` protocol — the engine-facing shape of a parallel trainer.

`repro.experiments.engine` runs every algorithm the same way (one masked,
padded simulation vmapped over the worker grid, see docs/architecture.md);
what varies per algorithm is captured by this protocol:

  ``make_draws(key, n, iters, m_top)``   every random draw of the whole run,
        made once at the *global* top of the worker grid so that sweep
        member m consumes identical randomness in any bucket / mode
  ``slice_draws(draws, m_pad)``          restrict those draws to a bucket's
        pad width (default: first ``m_pad`` columns of any worker axis)
  ``init_state(problem, data, ctx)``     the per-run state pytree; derived
        constants (ring matrices, SDCA step tables) are attached to ``ctx``
        so they are traced once per sim, not once per step
  ``step(problem, data, ctx, state, batch, t)``  one server iteration;
        ``batch`` is the per-iteration slice of the draws, ``t`` the traced
        global iteration index
  ``readout(ctx, state)``                the model the loss curve evaluates

Hyperparameters are dataclass fields (``Minibatch(gamma=0.05)``); loss,
gradient, and the DADM dual update come from the `Problem` argument
(`repro.core.problems`), never from the algorithm itself — that is what
makes the sweep generic over objectives.

Class-level policy flags steer the engine without special cases:

  ``asynchronous``     cost readout divides server iterations by m (§V.A.1)
  ``bucketed_default`` whether bucketed m-padding pays for this algorithm
  ``force_flat``       always one flat vmap (work independent of pad width)
  ``predictor``        which theory-side m_max predictor applies
        (one of ``PREDICTOR_KINDS`` — see `experiments.runner`)
  ``gamma_scale``      how much the algorithm amplifies its nominal step
        size (momentum's 1/(1-beta)); generic harnesses multiply a step
        size tuned for plain SGD by this before instantiating

Register with :func:`register_algorithm`; the registry is *live* (latest
registration wins) and spec fingerprints hash the registered source, so
editing an Algorithm invalidates exactly the cached sweeps that used it.

The masked-simulation contract every implementation must keep: for any
``m <= m_pad``, padded workers (index >= m) are excluded from every
reduction and every stateful write, so the padded run is numerically the
m-worker run — `tests/test_protocols.py` enforces this for every
registered Algorithm x Problem pair.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Type

import jax
import jax.numpy as jnp

#: name -> Algorithm subclass.  Live view; latest registration wins.
ALGORITHMS: Dict[str, Type["Algorithm"]] = {}

#: predictor kinds an Algorithm may declare (resolved in experiments.runner)
PREDICTOR_KINDS = ("sync", "hogwild", "dadm", "momentum", "local_sgd", "svrg")


def register_algorithm(cls: Type["Algorithm"]) -> Type["Algorithm"]:
    """Class decorator: make an Algorithm resolvable by its ``name``."""
    if not (isinstance(getattr(cls, "name", None), str) and cls.name):
        raise TypeError(f"{cls!r} needs a non-empty ClassVar 'name'")
    if cls.predictor not in PREDICTOR_KINDS:
        raise ValueError(f"{cls.name}: predictor {cls.predictor!r} "
                         f"not in {PREDICTOR_KINDS}")
    ALGORITHMS[cls.name] = cls
    return cls


def get_algorithm(name: str) -> Type["Algorithm"]:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise KeyError(f"unknown algorithm {name!r}; "
                       f"known: {sorted(ALGORITHMS)}") from None


def registered_algorithms():
    return tuple(sorted(ALGORITHMS))


class SimContext:
    """Per-``sim(m)`` context: the static pad width and the *traced* live
    worker count, plus the derived views every masked kernel needs.
    ``init_state`` may attach algorithm-specific constants (e.g. ``ctx.W``);
    they are closure-captured by ``step``, i.e. traced once per sim and
    hoisted out of the iteration scan."""

    def __init__(self, m, m_pad: int):
        self.m = jnp.asarray(m, jnp.int32)      # traced live worker count
        self.m_pad = int(m_pad)                 # static worker-axis width
        self.mf = self.m.astype(jnp.float32)
        #: (m_pad,) float mask — 1 for live workers, 0 for padding
        self.active = (jnp.arange(m_pad) < self.m).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """Base protocol.  Subclass, set ``name``, implement the five hooks."""

    name: ClassVar[str] = ""
    asynchronous: ClassVar[bool] = False
    bucketed_default: ClassVar[bool] = True
    force_flat: ClassVar[bool] = False
    predictor: ClassVar[str] = "sync"
    #: effective-step amplification a generic harness should divide out
    gamma_scale: ClassVar[float] = 1.0

    # -- randomness ---------------------------------------------------------
    def make_draws(self, key, n: int, iters: int, m_top: int):
        """All random draws for ``iters`` steps at the global grid top
        ``m_top`` — a pytree of arrays with leading dim ``iters``."""
        raise NotImplementedError

    def slice_draws(self, draws, m_pad: int):
        """Default: worker axes are axis 1 — take their first ``m_pad``
        columns; per-iteration scalars pass through."""
        return jax.tree.map(
            lambda a: a[:, :m_pad] if a.ndim >= 2 else a, draws)

    # -- simulation ---------------------------------------------------------
    def init_state(self, problem, data, ctx: SimContext):
        raise NotImplementedError

    def step(self, problem, data, ctx: SimContext, state, batch, t):
        raise NotImplementedError

    def readout(self, ctx: SimContext, state):
        raise NotImplementedError
