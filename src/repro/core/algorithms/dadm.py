"""DADM (Alg 3) — Distributed Alternating Dual Maximization, i.e.
mini-batched distributed SDCA for L2-regularized logistic regression.

Each of m workers owns a shard of the dual variables alpha_i; per iteration
every worker approximately maximizes the local dual increment (Eq. 5) for a
local mini-batch (one SDCA closed-form-ish step per sample), then the server
all-gathers Delta v = (1/(lambda n)) sum xi_i Delta alpha_i and broadcasts.
Primal: x = v (psi = 0.5 ||x||^2 => grad psi* = identity).

For logistic loss the dual is
  D(alpha) = -(1/n) sum_i [a log a + (1-a) log(1-a)]|_{a=alpha_i}
             - (lambda/2)||v||^2,  alpha_i in (0,1),
  v = (1/(lambda n)) sum_i alpha_i y_i xi_i.
The per-sample update uses the Shalev-Shwartz & Zhang step
  dalpha = (sigma(-y x.xi) - alpha) * min(1, 4 lambda n / ||xi||^2 / 4).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)
from repro.core.algorithms.lr import test_logloss, LAMBDA


@register_algorithm
@dataclasses.dataclass(frozen=True)
class Dadm(Algorithm):
    """Protocol port: the dual all-gather is a masked sum over the padded
    worker axis; padded workers' dual increments are zeroed so they neither
    move ``alpha`` nor contribute to ``v``.  The loss-specific pieces — the
    per-sample SDCA step table and the coordinate update — come from the
    Problem's dual hooks (``sdca_stepfactor`` / ``sdca_delta``), so DADM
    runs unchanged on logistic, ridge, and hinge objectives.

    ``bucketed_default`` is False: the dual state is ``(n,)``-sized and
    m-independent, so replaying the alpha/v updates once per bucket costs
    more than the padded per-worker FLOPs it saves (the flag is honored
    when explicitly requested; the equivalence tests exercise it)."""

    name: ClassVar[str] = "dadm"
    bucketed_default: ClassVar[bool] = False
    predictor: ClassVar[str] = "dadm"

    local_batch: int = 8

    def make_draws(self, key, n, iters, m_top):
        return jax.random.randint(key, (iters, m_top, self.local_batch),
                                  0, n)

    def init_state(self, problem, data, ctx: SimContext):
        X, y = data.X, data.y
        n = X.shape[0]
        ctx.sdca_step = problem.sdca_stepfactor(jnp.sum(X * X, axis=1), n)
        alpha0 = jnp.full((n,), problem.dual_init())
        v0 = (y * alpha0) @ X / (problem.lam * n)
        return (alpha0, v0)

    def step(self, problem, data, ctx: SimContext, state, idx, t):
        X, y = data.X, data.y
        n = X.shape[0]
        alpha, v = state                     # (n,), (d,)
        x = v                                # primal

        def worker(idx_w):
            Xi, yi, ai = X[idx_w], y[idx_w], alpha[idx_w]
            da = problem.sdca_delta(Xi @ x, yi, ai, ctx.sdca_step[idx_w])
            dv = (yi * da) @ Xi / (problem.lam * n)
            return da, dv

        das, dvs = jax.vmap(worker)(idx)     # (m_pad, lb), (m_pad, d)
        # padded workers sit out; problems with unbounded duals damp the
        # concurrent increments (sdca_damping == 1.0 for the paper's
        # logistic dual, keeping those curves bit-identical)
        damp = problem.sdca_damping(ctx.mf * self.local_batch)
        das = das * (ctx.active[:, None] * damp)
        dvs = dvs * damp
        alpha = alpha.at[idx.reshape(-1)].add(das.reshape(-1))
        v = v + ctx.active @ dvs             # masked all-gather sum
        return (alpha, v)

    def readout(self, ctx: SimContext, state):
        return state[1]


@functools.partial(jax.jit, static_argnames=("m", "local_batch", "iters",
                                             "eval_every"))
def _run(X, y, Xte, yte, key, m, local_batch, iters, lam, eval_every):
    n, d = X.shape
    order = jax.random.randint(key, (iters, m, local_batch), 0, n)
    sq_norms = jnp.sum(X * X, axis=1)
    # SDCA step size factor per sample: min(1, lambda n / (||xi||^2/4 + l n))
    step = jnp.minimum(1.0, (lam * n) / (sq_norms / 4.0 + lam * n))

    def one_iter(carry, idx):
        alpha, v = carry                     # (n,), (d,)
        x = v                                # primal

        def worker(idx_w):
            Xi = X[idx_w]                    # (lb, d)
            yi = y[idx_w]
            ai = alpha[idx_w]
            p = jax.nn.sigmoid(-(yi * (Xi @ x)))      # target dual value
            da = (p - ai) * step[idx_w]
            dv = (yi * da) @ Xi / (lam * n)
            return da, dv

        das, dvs = jax.vmap(worker)(idx)     # (m, lb), (m, d)
        alpha = alpha.at[idx.reshape(-1)].add(das.reshape(-1))
        v = v + jnp.sum(dvs, axis=0)         # server all-gather + sum
        return (alpha, v), None

    alpha0 = jnp.full((n,), 0.5)
    v0 = (y * alpha0) @ X / (lam * n)
    n_evals = iters // eval_every

    def outer(carry, e):
        idxs = jax.lax.dynamic_slice_in_dim(order, e * eval_every,
                                            eval_every, axis=0)
        carry, _ = jax.lax.scan(one_iter, carry, idxs)
        return carry, test_logloss(carry[1], Xte, yte)

    carry, losses = jax.lax.scan(outer, (alpha0, v0), jnp.arange(n_evals))
    return carry[1], losses


def run_dadm(train, test, *, m=4, local_batch=8, iters=2000, lam=LAMBDA,
             eval_every=100, key=None):
    """Legacy per-m logistic runner (deprecated: sweeps should go through
    `repro.experiments.engine`; kept as the independent equivalence
    oracle)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key, m, local_batch,
                     iters, lam, eval_every)
    return {
        "algorithm": "dadm",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters,
    }
