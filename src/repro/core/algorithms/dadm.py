"""DADM (Alg 3) — Distributed Alternating Dual Maximization, i.e.
mini-batched distributed SDCA for L2-regularized logistic regression.

Each of m workers owns a shard of the dual variables alpha_i; per iteration
every worker approximately maximizes the local dual increment (Eq. 5) for a
local mini-batch (one SDCA closed-form-ish step per sample), then the server
all-gathers Delta v = (1/(lambda n)) sum xi_i Delta alpha_i and broadcasts.
Primal: x = v (psi = 0.5 ||x||^2 => grad psi* = identity).

For logistic loss the dual is
  D(alpha) = -(1/n) sum_i [a log a + (1-a) log(1-a)]|_{a=alpha_i}
             - (lambda/2)||v||^2,  alpha_i in (0,1),
  v = (1/(lambda n)) sum_i alpha_i y_i xi_i.
The per-sample update uses the Shalev-Shwartz & Zhang step
  dalpha = (sigma(-y x.xi) - alpha) * min(1, 4 lambda n / ||xi||^2 / 4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.algorithms.lr import test_logloss, LAMBDA


@functools.partial(jax.jit, static_argnames=("m", "local_batch", "iters",
                                             "eval_every"))
def _run(X, y, Xte, yte, key, m, local_batch, iters, lam, eval_every):
    n, d = X.shape
    order = jax.random.randint(key, (iters, m, local_batch), 0, n)
    sq_norms = jnp.sum(X * X, axis=1)
    # SDCA step size factor per sample: min(1, lambda n / (||xi||^2/4 + l n))
    step = jnp.minimum(1.0, (lam * n) / (sq_norms / 4.0 + lam * n))

    def one_iter(carry, idx):
        alpha, v = carry                     # (n,), (d,)
        x = v                                # primal

        def worker(idx_w):
            Xi = X[idx_w]                    # (lb, d)
            yi = y[idx_w]
            ai = alpha[idx_w]
            p = jax.nn.sigmoid(-(yi * (Xi @ x)))      # target dual value
            da = (p - ai) * step[idx_w]
            dv = (yi * da) @ Xi / (lam * n)
            return da, dv

        das, dvs = jax.vmap(worker)(idx)     # (m, lb), (m, d)
        alpha = alpha.at[idx.reshape(-1)].add(das.reshape(-1))
        v = v + jnp.sum(dvs, axis=0)         # server all-gather + sum
        return (alpha, v), None

    alpha0 = jnp.full((n,), 0.5)
    v0 = (y * alpha0) @ X / (lam * n)
    n_evals = iters // eval_every

    def outer(carry, e):
        idxs = jax.lax.dynamic_slice_in_dim(order, e * eval_every,
                                            eval_every, axis=0)
        carry, _ = jax.lax.scan(one_iter, carry, idxs)
        return carry, test_logloss(carry[1], Xte, yte)

    carry, losses = jax.lax.scan(outer, (alpha0, v0), jnp.arange(n_evals))
    return carry[1], losses


def run_dadm(train, test, *, m=4, local_batch=8, iters=2000, lam=LAMBDA,
             eval_every=100, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key, m, local_batch,
                     iters, lam, eval_every)
    return {
        "algorithm": "dadm",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters,
    }
