"""The paper's four parallel training algorithms on its Eq. 4 model
(L2-regularized logistic regression, `lr.py`): Hogwild! (Alg 1, async,
deterministic staleness simulation), mini-batch SGD (Alg 2, batch size =
degree of parallelism), DADM (Alg 3, distributed dual coordinate ascent)
and ECD-PSGD (Alg 4, decentralized ring gossip with compression).  Each
`run_*` returns the shared result contract ({"losses", "m", "iters",
"eval_every", ...}) the scalability machinery consumes; the m-grid batched
versions live in `repro.experiments.engine`.
"""

from repro.core.algorithms.lr import logloss, lr_grad, test_logloss
from repro.core.algorithms.hogwild import run_hogwild
from repro.core.algorithms.minibatch import run_minibatch
from repro.core.algorithms.ecd_psgd import run_ecd_psgd
from repro.core.algorithms.dadm import run_dadm
