from repro.core.algorithms.lr import logloss, lr_grad, test_logloss
from repro.core.algorithms.hogwild import run_hogwild
from repro.core.algorithms.minibatch import run_minibatch
from repro.core.algorithms.ecd_psgd import run_ecd_psgd
from repro.core.algorithms.dadm import run_dadm
