"""The paper's four parallel training algorithms, ported onto the
registered `Algorithm` protocol (`base.py`): Hogwild! (Alg 1, async,
deterministic staleness simulation), mini-batch SGD (Alg 2, batch size =
degree of parallelism), DADM (Alg 3, distributed dual coordinate ascent)
and ECD-PSGD (Alg 4, decentralized ring gossip with compression) — plus
the critical-parameter extensions (ROADMAP item 4): momentum mini-batch
SGD (`Momentum`), local SGD / EASGD (`LocalSgd`) and asynchronous SVRG
(`AsyncSvrg`), protocol-only dataclasses with no legacy runner face.

Each module carries two faces:

  * a registered protocol dataclass (`Minibatch`, `Hogwild`, `EcdPsgd`,
    `Dadm`) — what `repro.experiments.engine` dispatches through, generic
    over the `repro.core.problems` objective;
  * the legacy per-m ``run_*`` runner — a thin deprecated adapter with the
    original `{"losses", "m", "iters", "eval_every", ...}` contract, kept
    as the independent oracle the engine equivalence tests pin against.

Importing this package populates the registry; resolve entries with
`base.get_algorithm` / enumerate with `base.registered_algorithms`.
"""

from repro.core.algorithms.base import (ALGORITHMS, Algorithm, SimContext,
                                        get_algorithm, register_algorithm,
                                        registered_algorithms)
from repro.core.algorithms.lr import logloss, lr_grad, test_logloss
from repro.core.algorithms.hogwild import Hogwild, run_hogwild
from repro.core.algorithms.minibatch import Minibatch, run_minibatch
from repro.core.algorithms.ecd_psgd import EcdPsgd, run_ecd_psgd
from repro.core.algorithms.dadm import Dadm, run_dadm
from repro.core.algorithms.momentum import Momentum
from repro.core.algorithms.local_sgd import LocalSgd
from repro.core.algorithms.async_svrg import AsyncSvrg
