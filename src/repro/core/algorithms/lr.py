"""The paper's model: L2-regularized logistic regression (Eq. 4).

  argmin_x (1/n) sum_i Phi(label_i * xi_i . x) + (lambda/2) ||x||^2,
  Phi(t) = log(1 + exp(-t)),  lambda = 0.01.

These are the raw numeric kernels; the engine-facing abstraction is
`repro.core.problems.LogisticRegression`, which delegates here (so the
sweep engine stays bit-identical to the paper's curves) and registers
Eq. 4 alongside the other objectives (ridge, hinge).  New code should go
through the `Problem` protocol; these functions remain for the legacy
per-m runners and as the test oracles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LAMBDA = 0.01


def logloss_point(x, xi, yi):
    t = yi * jnp.dot(xi, x)
    return jnp.logaddexp(0.0, -t)


def logloss(x, X, y, lam=LAMBDA):
    t = y * (X @ x)
    return jnp.mean(jnp.logaddexp(0.0, -t)) + 0.5 * lam * jnp.sum(x * x)


def test_logloss(x, X, y):
    """Paper figures plot *test* log loss (no regularizer)."""
    t = y * (X @ x)
    return jnp.mean(jnp.logaddexp(0.0, -t))


def lr_grad(x, xi, yi, lam=LAMBDA):
    """Per-sample (sub)gradient G_xi(x).  For sparse xi the data term is
    supported on xi's nonzeros — the paper's Omega/delta/rho story."""
    t = yi * jnp.dot(xi, x)
    sig = jax.nn.sigmoid(-t)           # = 1 - 1/(1+e^-t)
    return -sig * yi * xi + lam * x


def lr_grad_batch(x, Xb, yb, lam=LAMBDA):
    t = yb * (Xb @ x)
    sig = jax.nn.sigmoid(-t)
    return -(sig * yb) @ Xb / Xb.shape[0] + lam * x
