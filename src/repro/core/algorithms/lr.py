"""The paper's model: L2-regularized logistic regression (Eq. 4).

  argmin_x (1/n) sum_i Phi(label_i * xi_i . x) + (lambda/2) ||x||^2,
  Phi(t) = log(1 + exp(-t)),  lambda = 0.01.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LAMBDA = 0.01


def logloss_point(x, xi, yi):
    t = yi * jnp.dot(xi, x)
    return jnp.logaddexp(0.0, -t)


def logloss(x, X, y, lam=LAMBDA):
    t = y * (X @ x)
    return jnp.mean(jnp.logaddexp(0.0, -t)) + 0.5 * lam * jnp.sum(x * x)


def test_logloss(x, X, y):
    """Paper figures plot *test* log loss (no regularizer)."""
    t = y * (X @ x)
    return jnp.mean(jnp.logaddexp(0.0, -t))


def lr_grad(x, xi, yi, lam=LAMBDA):
    """Per-sample (sub)gradient G_xi(x).  For sparse xi the data term is
    supported on xi's nonzeros — the paper's Omega/delta/rho story."""
    t = yi * jnp.dot(xi, x)
    sig = jax.nn.sigmoid(-t)           # = 1 - 1/(1+e^-t)
    return -sig * yi * xi + lam * x


def lr_grad_batch(x, Xb, yb, lam=LAMBDA):
    t = yb * (Xb @ x)
    sig = jax.nn.sigmoid(-t)
    return -(sig * yb) @ Xb / Xb.shape[0] + lam * x
