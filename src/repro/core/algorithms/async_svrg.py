"""Asynchronous SVRG: Hogwild!-style staleness over semi-stochastic grads.

Same deterministic staleness recurrence as `hogwild.py` (the gradient
applied at server iteration j was computed at iteration j - tau, tau
cycling over [1, m] — Thm 1's "lag equals the worker count"), but the
worker evaluates the SVRG semi-stochastic gradient instead of the raw
point gradient (Zhang et al., arXiv 1508.01633):

    v_j = grad f_i(x_stale) - grad f_i(x_anchor) + mu,
    mu  = full gradient at x_anchor,

with the anchor (and mu) refreshed from the current model every
``anchor_every`` server iterations.  Near the anchor the two point terms
cancel, so both the gradient *variance* and the staleness error the
recurrence injects shrink with ||x_stale - x_anchor|| — which is why
semi-stochastic gradients tolerate staleness (here: worker count m,
since tau_max = m) far better than Hogwild!'s raw gradients, and why the
anchor period is the third knob of the critical-parameter surface.
Theory-side bound: `repro.analysis.fit.svrg_mmax` (predictor kind
``"svrg"`` — Thm 2's Hogwild! recipe with the coordination term damped
by the variance-reduction factor theta = H / (H + n)).

Padding-safe like Hogwild!: the model history is allocated at the static
pad width, indexed modulo the *traced* m; the anchor refresh is a
``lax.cond`` on the (unbatched) iteration index, so the full-gradient
pass runs once per ``anchor_every`` steps, not per step, even under the
engine's grid vmap.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)


@register_algorithm
@dataclasses.dataclass(frozen=True)
class AsyncSvrg(Algorithm):
    """Traced-m staleness recurrence over SVRG semi-stochastic gradients
    with a periodic full-gradient anchor."""

    name: ClassVar[str] = "async_svrg"
    asynchronous: ClassVar[bool] = True      # cost divides iters by m
    bucketed_default: ClassVar[bool] = False
    force_flat: ClassVar[bool] = True        # single-model recurrence
    predictor: ClassVar[str] = "svrg"

    gamma: float = 0.1
    anchor_every: int = 100

    def make_draws(self, key, n, iters, m_top):
        # one shared server sample sequence, m-independent (as hogwild)
        return jax.random.randint(key, (iters,), 0, n)

    def init_state(self, problem, data, ctx: SimContext):
        d = data.X.shape[1]
        x0 = jnp.zeros((d,))
        mu0 = problem.batch_grad(x0, data.X, data.y)
        # (model, stale-model history, anchor, full gradient at anchor)
        return (x0, jnp.zeros((ctx.m_pad, d)), x0, mu0)

    def step(self, problem, data, ctx: SimContext, state, i, j):
        x, hist, anchor, mu = state
        tau = (j % ctx.m) + 1
        x_stale = hist[(j - tau) % ctx.m]
        v = (problem.point_grad(x_stale, data.X[i], data.y[i])
             - problem.point_grad(anchor, data.X[i], data.y[i]) + mu)
        x_new = x - self.gamma * v
        hist = hist.at[j % ctx.m].set(x_new)
        anchor, mu = jax.lax.cond(
            (j + 1) % self.anchor_every == 0,
            lambda _: (x_new, problem.batch_grad(x_new, data.X, data.y)),
            lambda _: (anchor, mu),
            operand=None)
        return (x_new, hist, anchor, mu)

    def readout(self, ctx: SimContext, state):
        return state[0]
