"""ECD-PSGD (Alg 4) — decentralized SGD with extrapolation-compression,
faithful single-host simulation: m workers on a ring (W = I/3 + ring
neighbors /3), each holding its own model x^(i), exchanging *compressed*
intermediate variables y^(i) (stochastic quantization, unbiased per Eq. 7).

Vectorized over workers with vmap; iteration-indexed per the PCA.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)
from repro.core.algorithms.lr import lr_grad, test_logloss, LAMBDA
from repro.core.compression import dequantize, quantize_stochastic


def ring_matrix(m, m_pad: int):
    """W with W[i] = (e_i + e_{i-1 mod m} + e_{i+1 mod m})/3 for i < m and
    identity rows for padded workers — the roll-based ring below expressed
    so that the live worker count m can be traced data."""
    ids = jnp.arange(m_pad)
    eye = jnp.eye(m_pad)
    W = (eye + eye[(ids - 1) % m] + eye[(ids + 1) % m]) / 3.0
    return jnp.where((ids < m)[:, None], W, eye)


@register_algorithm
@dataclasses.dataclass(frozen=True)
class EcdPsgd(Algorithm):
    """Protocol port: the ring of m workers becomes a masked
    ``(m_pad, m_pad)`` mixing matrix (identity rows for padding), built once
    per sim in ``init_state`` and closure-captured by ``step``.
    Quantization keys are drawn per (iteration, worker) at the global grid
    top and sliced per bucket, so worker i's key is identical in every
    bucket and execution mode."""

    name: ClassVar[str] = "ecd_psgd"
    bucketed_default: ClassVar[bool] = True  # quantization work is O(m_pad)

    gamma: float = 0.1
    compress_bits: int = 8

    def make_draws(self, key, n, iters, m_top):
        k_order, k_q = jax.random.split(key)
        order = jax.random.randint(k_order, (iters, m_top), 0, n)
        # per-(iteration, worker) quantization keys, hoisted out of the
        # scan: one vectorized fold_in+split replaces two chained RNG ops
        # per step, with the same draws as the in-scan version
        wkeys = jax.vmap(lambda t: jax.random.split(
            jax.random.fold_in(k_q, t), m_top))(jnp.arange(iters))
        return {"order": order, "keys": wkeys}

    def init_state(self, problem, data, ctx: SimContext):
        ctx.W = ring_matrix(ctx.m, ctx.m_pad)
        d = data.X.shape[1]
        return (jnp.zeros((ctx.m_pad, d)), jnp.zeros((ctx.m_pad, d)))

    def step(self, problem, data, ctx: SimContext, state, batch, t):
        xs, ys = state                       # (m_pad, d) models / y-vars
        idx, kqs = batch["order"], batch["keys"]
        tf = t.astype(jnp.float32) + 1.0
        x_half = ctx.W @ ys                  # neighbors pull compressed y

        grads = jax.vmap(lambda xi, i: problem.point_grad(
            xi, data.X[i], data.y[i]))(xs, idx)
        x_new = x_half - self.gamma * grads
        # z = (1 - t/2) x_t + (t/2) x_{t+1};  y = (1-2/t) y + (2/t) C(z)
        z = (1.0 - tf / 2.0) * xs + (tf / 2.0) * x_new
        cz = jax.vmap(lambda zz, kk: dequantize(*quantize_stochastic(
            zz, kk, bits=self.compress_bits)))(z, kqs)
        y_new = (1.0 - 2.0 / tf) * ys + (2.0 / tf) * cz
        return (x_new, y_new)

    def readout(self, ctx: SimContext, state):
        return (ctx.active @ state[0]) / ctx.mf   # mean over live workers


@functools.partial(jax.jit, static_argnames=("m", "iters", "eval_every",
                                             "compress_bits"))
def _run(X, y, Xte, yte, key, m, iters, gamma, lam, eval_every,
         compress_bits):
    n, d = X.shape
    k_order, k_q = jax.random.split(key)
    order = jax.random.randint(k_order, (iters, m), 0, n)

    def one_iter(carry, inp):
        xs, ys = carry                       # (m, d) models, (m, d) y-vars
        idx, kq, t = inp                     # t: 1-based iteration index
        tf = t.astype(jnp.float32) + 1.0

        # neighbors pull compressed y from the ring: x_{t+1/2} = sum W_ij y_j
        y_hat = ys                            # y already holds C(z) updates
        x_half = (y_hat + jnp.roll(y_hat, 1, axis=0)
                  + jnp.roll(y_hat, -1, axis=0)) / 3.0

        grads = jax.vmap(lambda xi, i: lr_grad(xi, X[i], y[i], lam))(xs, idx)
        x_new = x_half - gamma * grads

        # z = (1 - t/2) x_t + (t/2) x_{t+1};  y = (1-2/t) y + (2/t) C(z)
        z = (1.0 - tf / 2.0) * xs + (tf / 2.0) * x_new
        kqs = jax.random.split(kq, m)
        cz = jax.vmap(lambda zz, kk: dequantize(
            *quantize_stochastic(zz, kk, bits=compress_bits)))(z, kqs)
        y_new = (1.0 - 2.0 / tf) * ys + (2.0 / tf) * cz
        return (x_new, y_new), None

    xs0 = jnp.zeros((m, d))
    ys0 = jnp.zeros((m, d))
    n_evals = iters // eval_every

    def outer(carry, e):
        base = e * eval_every
        ts = base + jnp.arange(eval_every)
        keys = jax.vmap(lambda t: jax.random.fold_in(k_q, t))(ts)
        idxs = jax.lax.dynamic_slice_in_dim(order, base, eval_every, axis=0)
        carry, _ = jax.lax.scan(one_iter, carry, (idxs, keys, ts))
        x_avg = jnp.mean(carry[0], axis=0)   # output: worker average
        return carry, test_logloss(x_avg, Xte, yte)

    carry, losses = jax.lax.scan(outer, (xs0, ys0), jnp.arange(n_evals))
    return jnp.mean(carry[0], axis=0), losses


def run_ecd_psgd(train, test, *, m=4, iters=4000, gamma=0.1, lam=LAMBDA,
                 eval_every=100, compress_bits=8, key=None):
    """Legacy per-m logistic runner (deprecated: sweeps should go through
    `repro.experiments.engine`; kept as the independent equivalence
    oracle)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key, m, iters,
                     gamma, lam, eval_every, compress_bits)
    return {
        "algorithm": "ecd_psgd",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters,
    }
