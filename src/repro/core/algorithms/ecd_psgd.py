"""ECD-PSGD (Alg 4) — decentralized SGD with extrapolation-compression,
faithful single-host simulation: m workers on a ring (W = I/3 + ring
neighbors /3), each holding its own model x^(i), exchanging *compressed*
intermediate variables y^(i) (stochastic quantization, unbiased per Eq. 7).

Vectorized over workers with vmap; iteration-indexed per the PCA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.algorithms.lr import lr_grad, test_logloss, LAMBDA
from repro.core.compression import dequantize, quantize_stochastic


@functools.partial(jax.jit, static_argnames=("m", "iters", "eval_every",
                                             "compress_bits"))
def _run(X, y, Xte, yte, key, m, iters, gamma, lam, eval_every,
         compress_bits):
    n, d = X.shape
    k_order, k_q = jax.random.split(key)
    order = jax.random.randint(k_order, (iters, m), 0, n)

    def one_iter(carry, inp):
        xs, ys = carry                       # (m, d) models, (m, d) y-vars
        idx, kq, t = inp                     # t: 1-based iteration index
        tf = t.astype(jnp.float32) + 1.0

        # neighbors pull compressed y from the ring: x_{t+1/2} = sum W_ij y_j
        y_hat = ys                            # y already holds C(z) updates
        x_half = (y_hat + jnp.roll(y_hat, 1, axis=0)
                  + jnp.roll(y_hat, -1, axis=0)) / 3.0

        grads = jax.vmap(lambda xi, i: lr_grad(xi, X[i], y[i], lam))(xs, idx)
        x_new = x_half - gamma * grads

        # z = (1 - t/2) x_t + (t/2) x_{t+1};  y = (1-2/t) y + (2/t) C(z)
        z = (1.0 - tf / 2.0) * xs + (tf / 2.0) * x_new
        kqs = jax.random.split(kq, m)
        cz = jax.vmap(lambda zz, kk: dequantize(
            *quantize_stochastic(zz, kk, bits=compress_bits)))(z, kqs)
        y_new = (1.0 - 2.0 / tf) * ys + (2.0 / tf) * cz
        return (x_new, y_new), None

    xs0 = jnp.zeros((m, d))
    ys0 = jnp.zeros((m, d))
    n_evals = iters // eval_every

    def outer(carry, e):
        base = e * eval_every
        ts = base + jnp.arange(eval_every)
        keys = jax.vmap(lambda t: jax.random.fold_in(k_q, t))(ts)
        idxs = jax.lax.dynamic_slice_in_dim(order, base, eval_every, axis=0)
        carry, _ = jax.lax.scan(one_iter, carry, (idxs, keys, ts))
        x_avg = jnp.mean(carry[0], axis=0)   # output: worker average
        return carry, test_logloss(x_avg, Xte, yte)

    carry, losses = jax.lax.scan(outer, (xs0, ys0), jnp.arange(n_evals))
    return jnp.mean(carry[0], axis=0), losses


def run_ecd_psgd(train, test, *, m=4, iters=4000, gamma=0.1, lam=LAMBDA,
                 eval_every=100, compress_bits=8, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    x, losses = _run(train.X, train.y, test.X, test.y, key, m, iters,
                     gamma, lam, eval_every, compress_bits)
    return {
        "algorithm": "ecd_psgd",
        "m": m,
        "iters": iters,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters,
    }
