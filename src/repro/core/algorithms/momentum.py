"""Momentum mini-batch SGD (heavy-ball / Nesterov) under the PCA.

Same parallelization as Alg 2 (m one-sample worker gradients averaged by
the server per iteration, Fact 1: batch size IS the worker count), but the
server applies the averaged gradient through a momentum buffer:

    heavy-ball:  v_{t+1} = beta v_t - gamma g(x_t);        x_{t+1} = x_t + v_{t+1}
    Nesterov:    v_{t+1} = beta v_t - gamma g(x_t + beta v_t)

Momentum is the first knob of the critical-parameter surface (Stich et
al., arXiv 2103.02351): the buffer geometrically averages ~1/(1-beta)
past gradients, so part of the gradient-noise budget that batch
parallelism would otherwise spend is already consumed — the variance-
driven sqrt(m) gain saturates earlier, and the critical batch size moves
*down* with beta.  The theory-side bound is
`repro.analysis.fit.momentum_mmax` (predictor kind ``"momentum"``);
sweeping ``gamma`` at fixed beta maps the lr axis of the surface
(`critical_params` spec).

Note the effective step size is gamma / (1 - beta): a gamma tuned for
plain SGD is ~10x too large at beta=0.9.  ``gamma_scale`` declares that
amplification to generic harnesses (the conformance suite scales its
per-problem step sizes by it).
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)


@register_algorithm
@dataclasses.dataclass(frozen=True)
class Momentum(Algorithm):
    """m parallel one-sample gradients averaged by the server, applied
    through a heavy-ball (or Nesterov) momentum buffer each step."""

    name: ClassVar[str] = "momentum"
    bucketed_default: ClassVar[bool] = True      # work is O(m_pad * d)/step
    predictor: ClassVar[str] = "momentum"
    #: effective step is gamma/(1-beta) — generic drivers scale gamma by this
    gamma_scale: ClassVar[float] = 0.1

    gamma: float = 0.01
    beta: float = 0.9
    nesterov: bool = False

    def make_draws(self, key, n, iters, m_top):
        # identical layout to Minibatch: sweep member m reads the first m
        # worker columns in any bucket / execution mode
        return jax.random.randint(key, (iters, m_top), 0, n)

    def init_state(self, problem, data, ctx: SimContext):
        d = data.X.shape[1]
        return (jnp.zeros((d,)), jnp.zeros((d,)))    # (model, velocity)

    def step(self, problem, data, ctx: SimContext, state, idx, t):
        x, v = state
        x_eval = x + self.beta * v if self.nesterov else x
        g = problem.masked_batch_grad(x_eval, data.X[idx], data.y[idx],
                                      ctx.active, ctx.mf)
        v_new = self.beta * v - self.gamma * g
        return (x + v_new, v_new)

    def readout(self, ctx: SimContext, state):
        return state[0]
