"""Local SGD / EASGD under the PCA: per-worker models, periodic averaging.

Each of the m workers keeps its own model replica and takes one local SGD
step per server iteration on its own sample; every ``sync_every``-th
iteration the replicas are pulled toward their (live-worker) average:

    x_i <- x_i - gamma g_i(x_i)                      every iteration
    x_i <- x_i + averaging (x_bar - x_i)             when (t+1) % H == 0

``averaging=1.0`` is plain local SGD (replicas collapse onto the mean);
``averaging < 1`` is the EASGD elastic pull.  At ``sync_every=1`` every
step starts from a shared average of equal replicas, so the update is
exactly mini-batch SGD (Alg 2) up to reduction order — the conformance
suite pins that equivalence.

The sync window H is the second knob of the critical-parameter surface
(Stich, arXiv 1805.09767): communication is paid once per H local steps,
so the per-iteration parallel cost divides by H and the m_max cliff moves
*up* with the window — until replica drift over the window erases the
variance gain.  Theory-side bound: `repro.analysis.fit.local_sgd_mmax`
(predictor kind ``"local_sgd"``).

Masking contract: the replica bank lives at the static pad width
``(m_pad, d)``; padded rows step on their own (valid) draws but the sync
average reduces through ``ctx.active`` and the readout does the same, so
no padded value ever reaches a live row — the padded run is numerically
the m-worker run.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)
from repro.resilience import faults


@register_algorithm
@dataclasses.dataclass(frozen=True)
class LocalSgd(Algorithm):
    """m model replicas, one local point-gradient step each per server
    iteration, masked-mean synchronization every ``sync_every`` steps.

    ``fault`` (`repro.resilience.faults.FaultSpec` / dict) injects
    update-delivery faults: corruption rewrites a worker's local gradient
    (the update stream), while drop / straggle / duplicate act on the sync
    **messages** — a dropped or straggling worker's replica is excluded
    from the average (weight 0) and is not pulled toward it (it missed
    the sync), a duplicated one is counted twice.  The event stream is
    ``(iters, m_top)`` — per (iteration, worker), sliced per bucket like
    the sample draws — and zero-rate specs are bit-exact with
    ``fault=None``.
    """

    name: ClassVar[str] = "local_sgd"
    bucketed_default: ClassVar[bool] = True      # replica bank is O(m_pad * d)
    predictor: ClassVar[str] = "local_sgd"

    gamma: float = 0.1
    sync_every: int = 4
    averaging: float = 1.0      # 1.0 = local SGD, <1 = EASGD elastic pull
    fault: Optional[faults.FaultSpec] = None

    def __post_init__(self):
        object.__setattr__(self, "fault", faults.resolve(self.fault))

    def make_draws(self, key, n, iters, m_top):
        # one sample per worker per iteration, same layout as Minibatch;
        # the fault stream is keyed from the FAULT seed (environment, not
        # experiment randomness) — identical across seed replicates
        idx = jax.random.randint(key, (iters, m_top), 0, n)
        if self.fault is None:
            return idx
        return {"i": idx,
                "fault": faults.make_stream(self.fault, (iters, m_top))}

    def init_state(self, problem, data, ctx: SimContext):
        return jnp.zeros((ctx.m_pad, data.X.shape[1]))

    def step(self, problem, data, ctx: SimContext, xs, batch, t):
        idx = batch if self.fault is None else batch["i"]
        gs = jax.vmap(
            lambda xi, i: problem.point_grad(xi, data.X[i], data.y[i]))(xs, idx)
        if self.fault is not None:
            gs = faults.corrupt(self.fault, gs, batch["fault"]["corrupt"])
        xs = xs - self.gamma * gs
        if self.fault is None:
            # sync boundary: pull every replica toward the live-worker mean
            avg = (ctx.active @ xs) / ctx.mf
            pulled = xs + self.averaging * (avg[None, :] - xs)
        else:
            f = batch["fault"]
            # a straggler's message is as lost as a dropped one: both
            # miss the sync window entirely
            absent = jnp.maximum(f["drop"], f["straggle"])
            # delivery-weighted mean: absent replicas weigh 0, duplicated
            # ones 2; all-absent degrades to weight 1 (exact identity
            # otherwise — the live-worker count is integer-valued)
            wt = ctx.active * (1.0 - absent) * (1.0 + f["dup"])
            avg = (wt @ xs) / jnp.maximum(wt.sum(), 1.0)
            # absent workers are not pulled: they never saw the average
            pulled = xs + self.averaging * (
                (1.0 - absent)[:, None] * (avg[None, :] - xs))
        return jnp.where((t + 1) % self.sync_every == 0, pulled, xs)

    def readout(self, ctx: SimContext, xs):
        return (ctx.active @ xs) / ctx.mf
