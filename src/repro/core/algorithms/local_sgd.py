"""Local SGD / EASGD under the PCA: per-worker models, periodic averaging.

Each of the m workers keeps its own model replica and takes one local SGD
step per server iteration on its own sample; every ``sync_every``-th
iteration the replicas are pulled toward their (live-worker) average:

    x_i <- x_i - gamma g_i(x_i)                      every iteration
    x_i <- x_i + averaging (x_bar - x_i)             when (t+1) % H == 0

``averaging=1.0`` is plain local SGD (replicas collapse onto the mean);
``averaging < 1`` is the EASGD elastic pull.  At ``sync_every=1`` every
step starts from a shared average of equal replicas, so the update is
exactly mini-batch SGD (Alg 2) up to reduction order — the conformance
suite pins that equivalence.

The sync window H is the second knob of the critical-parameter surface
(Stich, arXiv 1805.09767): communication is paid once per H local steps,
so the per-iteration parallel cost divides by H and the m_max cliff moves
*up* with the window — until replica drift over the window erases the
variance gain.  Theory-side bound: `repro.analysis.fit.local_sgd_mmax`
(predictor kind ``"local_sgd"``).

Masking contract: the replica bank lives at the static pad width
``(m_pad, d)``; padded rows step on their own (valid) draws but the sync
average reduces through ``ctx.active`` and the readout does the same, so
no padded value ever reaches a live row — the padded run is numerically
the m-worker run.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.algorithms.base import (Algorithm, SimContext,
                                        register_algorithm)


@register_algorithm
@dataclasses.dataclass(frozen=True)
class LocalSgd(Algorithm):
    """m model replicas, one local point-gradient step each per server
    iteration, masked-mean synchronization every ``sync_every`` steps."""

    name: ClassVar[str] = "local_sgd"
    bucketed_default: ClassVar[bool] = True      # replica bank is O(m_pad * d)
    predictor: ClassVar[str] = "local_sgd"

    gamma: float = 0.1
    sync_every: int = 4
    averaging: float = 1.0      # 1.0 = local SGD, <1 = EASGD elastic pull

    def make_draws(self, key, n, iters, m_top):
        # one sample per worker per iteration, same layout as Minibatch
        return jax.random.randint(key, (iters, m_top), 0, n)

    def init_state(self, problem, data, ctx: SimContext):
        return jnp.zeros((ctx.m_pad, data.X.shape[1]))

    def step(self, problem, data, ctx: SimContext, xs, idx, t):
        gs = jax.vmap(
            lambda xi, i: problem.point_grad(xi, data.X[i], data.y[i]))(xs, idx)
        xs = xs - self.gamma * gs
        # sync boundary: pull every replica toward the live-worker mean
        avg = (ctx.active @ xs) / ctx.mf
        pulled = xs + self.averaging * (avg[None, :] - xs)
        return jnp.where((t + 1) % self.sync_every == 0, pulled, xs)

    def readout(self, ctx: SimContext, xs):
        return (ctx.active @ xs) / ctx.mf
