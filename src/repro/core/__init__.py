"""repro.core — the paper's substance.  `metrics` computes the §IV dataset
characters (feature variance, sparsity, diversity, C_sim/LS_A);
`algorithms` implements the four parallel training algorithms under the
Perfect Computer Assumption; `scalability` turns convergence curves into
gain/gain-growth/upper-bound readouts and predicts m_max from the
characters (§V); `advisor` packages those predictions as a framework
feature for the production training stack; `compression` holds the
stochastic quantizer ECD-PSGD gossips with.  The sweep engine in
`repro.experiments` drives all of it.
"""
