"""Flash attention Pallas TPU kernel (pl.pallas_call + explicit BlockSpec
VMEM tiling).

TPU adaptation of the GPU flash algorithm (DESIGN.md §6): the k-loop is the
*grid's* trailing "arbitrary" dimension so the MXU sees (BQ, D) x (D, BK)
matmuls with BQ = BK = 512 (multiples of 128 — systolic-array aligned);
running max / denominator / accumulator live in VMEM scratch across k-steps.
GQA is handled in the index map (q head h reads kv head h * KV // H) — no
materialized KV repeat.  Causal and sliding-window masks are applied from
the global block offsets.

Validated in interpret mode against repro.kernels.ref.attention_ref (see
tests/test_kernels.py shape/dtype sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, bq, bk, nk):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (cols <= rows)
    if window:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=0,
                         bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=True):
    """q: (B, H, S, D); k, v: (B, KV, T, D) -> (B, H, S, D).

    S % bq == 0 and T % bk == 0 (the ops.py wrapper pads).
    """
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    bq = min(bq, S)
    bk = min(bk, T)
    nq, nk = S // bq, T // bk
    scale = 1.0 / (D ** 0.5)

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, KV=KV, H=H: (b, h * KV // H, ik, 0)),
            pl.BlockSpec((1, 1, bk, v.shape[-1]),
                         lambda b, h, iq, ik, KV=KV, H=H: (b, h * KV // H, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, v.shape[-1]),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, v.shape[-1]), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, v.shape[-1]), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),             # running max
            pltpu.VMEM((bq, 1), jnp.float32),             # running denom
        ],
        interpret=interpret,
    )(q, k, v)
