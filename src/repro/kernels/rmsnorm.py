"""Fused RMSNorm Pallas kernel: one VMEM pass computes the row second moment
and applies the normalization + gain (vs. the unfused mean-square / rsqrt /
mul chain).  Rows are (tokens), tiled (BR x d) with d kept whole so the
reduction is a single in-tile pass.

Oracle: repro.models.layers.apply_rmsnorm (re-exported in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 256


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)               # (br, d)
    var = jnp.mean(x * x, axis=1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm_2d(x, gain, *, eps=1e-6, br=DEFAULT_BR, interpret=True):
    """x: (n, d), gain: (d,) -> (n, d)."""
    n, d = x.shape
    br = min(br, n)
    pad = (-n) % br
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (xp.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, gain.reshape(1, d))
    return out[:n]
