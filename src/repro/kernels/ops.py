"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels are written for TPU BlockSpec tiling and validated in interpret
mode per the project contract).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import csim as _csim
from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q
from repro.kernels import rmsnorm as _rn


def _interpret_default():
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, bq=None, bk=None):
    """Model-layout wrapper: q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = bq or min(_fa.DEFAULT_BQ, S)
    bk = bk or min(_fa.DEFAULT_BK, k.shape[1])
    # pad S/T to block multiples; padded q rows attend only to themselves
    pad_q = (-S) % bq
    pad_k = (-k.shape[1]) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   bq=bq, bk=bk,
                                   interpret=_interpret_default())
    return out[:, :, :S].transpose(0, 2, 1, 3)


def csim(X, rng: int, tol=0.0):
    return _csim.csim_kernel(X, rng, tol, interpret=_interpret_default())


def l0_rows(x, y, tol=0.0):
    return _csim.l0_rows(x, y, tol=tol, interpret=_interpret_default())


def quantize_stochastic(x, key, *, bits=8):
    """Any-shape wrapper (kernel is 2D-tiled)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim >= 2 else x.reshape(1, -1)
    q, scale = _q.quantize_stochastic_2d(x2, key, bits=bits,
                                         interpret=_interpret_default())
    return q.reshape(shape), scale


def dequantize(q, scale):
    shape = q.shape
    q2 = q.reshape(-1, shape[-1]) if q.ndim >= 2 else q.reshape(1, -1)
    x = _q.dequantize_2d(q2, scale, interpret=_interpret_default())
    return x.reshape(shape)


def rmsnorm(x, gain, eps=1e-6):
    """Any-rank wrapper: normalizes the last dim."""
    shape = x.shape
    out = _rn.rmsnorm_2d(x.reshape(-1, shape[-1]), gain, eps=eps,
                         interpret=_interpret_default())
    return out.reshape(shape)
