"""C_sim (paper Eq. 3) Pallas kernel — the paper-specific compute hot spot.

The O(n * range * d) windowed-L0 sweep is tiled as: for each shift j the
wrapper rolls X by j (cheap row permutation), and the kernel counts
differing coordinates block-by-block with explicit VMEM tiles of
(BN rows x BD features), accumulating per-row-block partial counts across
the feature-tile grid dimension.

Oracle: repro.core.metrics.csim_ref (re-exported in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256
DEFAULT_BD = 512


def _l0_kernel(x_ref, y_ref, o_ref, *, tol, nd):
    jd = pl.program_id(1)

    @pl.when(jd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    diff = (jnp.abs(x_ref[...].astype(jnp.float32)
                    - y_ref[...].astype(jnp.float32)) > tol)
    o_ref[...] += jnp.sum(diff.astype(jnp.float32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret", "tol"))
def l0_rows(x, y, *, tol=0.0, bn=DEFAULT_BN, bd=DEFAULT_BD, interpret=True):
    """Per-row L0 distance between x and y: (n, d) x (n, d) -> (n,)."""
    n, d = x.shape
    bn = min(bn, n)
    bd = min(bd, d)
    pad_n = (-n) % bn
    pad_d = (-d) % bd
    if pad_n or pad_d:
        x = jnp.pad(x, ((0, pad_n), (0, pad_d)))
        y = jnp.pad(y, ((0, pad_n), (0, pad_d)))
    np_, dp = x.shape
    grid = (np_ // bn, dp // bd)
    out = pl.pallas_call(
        functools.partial(_l0_kernel, tol=tol, nd=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(x, y)
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("rng", "tol", "interpret"))
def csim_kernel(X, rng: int, tol=0.0, *, interpret=True):
    """Eq. 3 via the Pallas L0 kernel, fused as one `lax.scan` over the
    shift range — one trace and one compiled pipeline regardless of rng
    (the old wrapper unrolled rng separate pallas calls)."""
    n = X.shape[0]
    rows = jnp.arange(n)

    def body(total, j):
        Xs = X[(rows + j) % n]               # == jnp.roll(X, -j, axis=0)
        total = total + jnp.sum(
            l0_rows(X, Xs, tol=tol, interpret=interpret))
        return total, None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(1, rng + 1))
    return total / (n * rng)
