"""repro.kernels — Pallas kernels for the repo's compute hot spots, each
with a pure-jnp oracle in `ref.py` and a dispatch wrapper in `ops.py`:
`csim` (the paper's Eq. 3 windowed L0-distance loop, O(n·range·d)),
`flash_attention`, `rmsnorm`, and stochastic `quantize` (the compression
ECD-PSGD gossips with).  Tests compare kernel vs oracle in interpret mode;
`benchmarks/kernel_bench.py` times them.
"""
