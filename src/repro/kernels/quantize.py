"""Stochastic-quantization Pallas kernel — ECD-PSGD's compression operator
C(.) as a tiled TPU kernel (bf16/f32 -> int8 with per-tensor scale and
stochastic rounding; unbiased per the paper's Eq. 7 requirement).

The uniform noise is supplied by the wrapper (jax.random) so the kernel is
deterministic given its inputs; the scale (a global max) is a cheap XLA
reduce in the wrapper — the kernel does the bandwidth-bound elementwise pass
with explicit (BN x BD) VMEM tiles.

Oracle: repro.core.compression.quantize_stochastic (re-exported in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256
DEFAULT_BD = 512


def _quant_kernel(x_ref, u_ref, scale_ref, q_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...]
    scale = scale_ref[0, 0]
    q = jnp.floor(x / scale + u)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    q_ref[...] = q.astype(q_ref.dtype)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("bits", "bn", "bd", "interpret"))
def quantize_stochastic_2d(x, key, *, bits=8, bn=DEFAULT_BN, bd=DEFAULT_BD,
                           interpret=True):
    """x: (n, d) -> (q int8/int16, scale)."""
    assert bits in (4, 8, 16)
    qmax = 2.0 ** (bits - 1) - 1.0
    n, d = x.shape
    bn = min(bn, n)
    bd = min(bd, d)
    pad_n, pad_d = (-n) % bn, (-d) % bd
    xp = jnp.pad(x, ((0, pad_n), (0, pad_d)))
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / qmax
    u = jax.random.uniform(key, xp.shape, jnp.float32)
    dt = jnp.int8 if bits <= 8 else jnp.int16
    grid = (xp.shape[0] // bn, xp.shape[1] // bd)
    q = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, dt),
        interpret=interpret,
    )(xp, u, scale.reshape(1, 1))
    return q[:n, :d], scale


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def dequantize_2d(q, scale, *, bn=DEFAULT_BN, bd=DEFAULT_BD, interpret=True):
    n, d = q.shape
    bn = min(bn, n)
    bd = min(bd, d)
    pad_n, pad_d = (-n) % bn, (-d) % bd
    qp = jnp.pad(q, ((0, pad_n), (0, pad_d)))
    grid = (qp.shape[0] // bn, qp.shape[1] // bd)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=interpret,
    )(qp, scale.reshape(1, 1))
    return x[:n, :d]
