"""Pure-jnp oracles for every Pallas kernel (the per-kernel allclose tests
sweep shapes/dtypes against these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compression import dequantize as dequantize_ref           # noqa: F401
from repro.core.compression import quantize_stochastic as quantize_ref   # noqa: F401
from repro.core.metrics import csim_ref                                  # noqa: F401
from repro.core.metrics import l0_distance


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,S,D); k,v: (B,KV,T,D) -> (B,H,S,Dv).  Unchunked, f32."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=1)
        v = jnp.repeat(v, H // KV, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (cols <= rows)
    if window:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def l0_rows_ref(x, y, tol=0.0):
    return l0_distance(x, y, tol)


def rmsnorm_ref(x, gain, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * gain.astype(jnp.float32)).astype(x.dtype)
