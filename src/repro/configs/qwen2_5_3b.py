"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, GQA + QKV bias.  long_500k is served through the sliding-window
variant flag (window 4096) — see DESIGN.md.  [hf:Qwen/Qwen2.5-0.5B]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    norm="rmsnorm",
    max_seq_len=32768,
    source="hf:Qwen/Qwen2.5-0.5B",
)

import dataclasses as _dc

# long_500k opt-in: same arch with a sliding window (block-sparse variant)
SLIDING_VARIANT = _dc.replace(
    CONFIG, name="qwen2.5-3b-swa", sliding_window=4096, global_every=0)
