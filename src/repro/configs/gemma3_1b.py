"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding-window attention, 128k ctx (local window 512).
[hf:google/gemma-3-1b-pt]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    attention="gqa",
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,               # 5 local : 1 global
    mlp_kind="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    max_seq_len=131072,
    source="hf:google/gemma-3-1b-pt",
)
