"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE, dynamic resolution (vision frontend stubbed; the
backbone consumes precomputed patch embeddings).  [arXiv:2409.12191]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    mlp_kind="swiglu",
    norm="rmsnorm",
    vision_tokens=1024,           # stub frontend supplies this many patch embeds
    max_seq_len=32768,
    source="arXiv:2409.12191",
)
