"""repro.configs — the 10 assigned model architectures as `ArchConfig`
dataclasses (one module each) plus `registry.get_arch` / `ARCH_IDS` lookup
and the (arch x input-shape) applicability matrix.  `base.py` defines the
config schema and the canonical input shapes.  `repro.experiments` mirrors
this registry pattern for sweep specs.
"""
