"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064, RoPE + SwiGLU.  [arXiv:2404.14219]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    attention="gqa",
    rope_theta=10000.0,
    mlp_kind="swiglu",
    norm="rmsnorm",
    max_seq_len=131072,
    source="arXiv:2404.14219",
)
