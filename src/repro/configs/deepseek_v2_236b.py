"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA (kv_lora=512),
expert d_ff=1536, 2 shared + 160 routed experts top-6, vocab=102400.
[arXiv:2405.04434]"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,             # MLA: per-head KV decompressed from latent
    head_dim=128,
    d_ff=12288,                   # the dense first layer's MLP width
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_theta=10000.0,
    mlp_kind="swiglu",
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        expert_d_ff=1536,
        num_shared_experts=2,
        shared_d_ff=3072,         # 2 shared experts x 1536
        capacity_factor=1.25,
    ),
    moe_skip_first=1,             # first layer dense (deepseek recipe)
    norm="rmsnorm",
    max_seq_len=131072,
    source="arXiv:2405.04434",
)
