"""Registry of assigned architectures (public pool) + the paper's own configs."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape

_ARCH_MODULES = [
    "qwen1_5_110b",
    "gemma3_1b",
    "arctic_480b",
    "qwen2_vl_72b",
    "qwen2_5_3b",
    "xlstm_350m",
    "deepseek_v2_236b",
    "zamba2_1_2b",
    "whisper_small",
    "phi3_mini_3_8b",
]

_CACHE: Dict[str, ArchConfig] = {}


# canonical ids as assigned
ARCH_IDS = [
    "qwen1.5-110b",
    "gemma3-1b",
    "arctic-480b",
    "qwen2-vl-72b",
    "qwen2.5-3b",
    "xlstm-350m",
    "deepseek-v2-236b",
    "zamba2-1.2b",
    "whisper-small",
    "phi3-mini-3.8b",
]

_ID_TO_MODULE = dict(zip(ARCH_IDS, _ARCH_MODULES))


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _CACHE:
        if arch_id not in _ID_TO_MODULE:
            raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
        mod = importlib.import_module(f"repro.configs.{_ID_TO_MODULE[arch_id]}")
        _CACHE[arch_id] = mod.CONFIG
    return _CACHE[arch_id]


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


# ---------------------------------------------------------------------------
# Applicability matrix: which (arch, shape) pairs run.  decode shapes lower
# serve_step; long_500k needs sub-quadratic attention (see DESIGN.md).
# ---------------------------------------------------------------------------

_LONG_OK = {"xlstm-350m", "zamba2-1.2b", "gemma3-1b", "qwen2.5-3b"}
# qwen2.5-3b runs long_500k through its sliding-window variant flag.


def pair_supported(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-skipped)."""
    if shape_name == "long_500k" and arch_id not in _LONG_OK:
        return False, ("pure full-attention arch: 500k decode would be a "
                       "quadratic-attention port; skipped per DESIGN.md")
    return True, ""


def supported_pairs():
    out = []
    for a in ARCH_IDS:
        for s in INPUT_SHAPES:
            ok, _ = pair_supported(a, s)
            if ok:
                out.append((a, s))
    return out
