"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP.  [hf:Snowflake/snowflake-arctic-base]"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,                    # dense-residual MLP width
    vocab_size=32000,
    attention="gqa",
    rope_theta=10000.0,
    mlp_kind="swiglu",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_residual_d_ff=4864,   # arctic's dense + MoE parallel structure
        capacity_factor=1.25,
    ),
    norm="rmsnorm",
    max_seq_len=4096,
    source="hf:Snowflake/snowflake-arctic-base",
)
