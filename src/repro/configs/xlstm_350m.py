"""xlstm-350m [ssm] — 24L d_model=1024 4 heads, sLSTM + mLSTM blocks
(xLSTM[7:1] mix), vocab=50304.  [arXiv:2405.04517]"""

from repro.configs.base import ArchConfig, SSMConfig

# 7 mLSTM : 1 sLSTM per the xLSTM[7:1] recipe
_PATTERN = ("mlstm",) * 7 + ("slstm",)

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                       # blocks carry their own projections
    vocab_size=50304,
    ssm=SSMConfig(kind="mlstm", state_dim=64, expand=2, conv_width=4,
                  num_heads=4, chunk_size=128),
    layer_pattern=_PATTERN,
    norm="layernorm",
    max_seq_len=1_048_576,        # recurrent: unbounded in principle
    source="arXiv:2405.04517",
)
