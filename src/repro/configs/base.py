"""Architecture + input-shape config system.

Every assigned architecture is expressed as an ``ArchConfig`` — a frozen
dataclass rich enough to describe dense, MoE, SSM, hybrid, VLM-backbone and
audio enc-dec families.  Full-size configs are exercised only through the
dry-run (``ShapeDtypeStruct``, no allocation); smoke tests call
``reduced()`` to get a CPU-runnable variant of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for a block."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0       # deepseek-style always-on experts
    shared_d_ff: int = 0
    dense_residual_d_ff: int = 0      # arctic-style parallel dense MLP
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention settings."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / xLSTM settings."""

    kind: str = "mamba2"              # "mamba2" | "mlstm" | "slstm"
    state_dim: int = 64               # N (mamba2) / head memory (mlstm)
    expand: int = 2                   # inner = expand * d_model
    conv_width: int = 4
    num_heads: int = 0                # 0 -> derive from inner/64 (mamba2)
    chunk_size: int = 128             # chunked parallel scan block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # --- attention flavor ---
    attention: str = "gqa"            # gqa | mla | mha
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_kind: str = "standard"       # standard | mrope
    mrope_sections: Sequence[int] = (16, 24, 24)   # t/h/w split of head_dim/2
    sliding_window: int = 0           # 0 -> full attention everywhere
    global_every: int = 0             # gemma3: 1 global layer per N (N=6 -> 5:1)
    # --- ffn ---
    mlp_kind: str = "swiglu"          # swiglu | gelu
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                # apply MoE to every Nth layer
    moe_skip_first: int = 0           # deepseek: first layer dense
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    # layer_pattern: per-layer block kind; empty -> homogeneous family default.
    # entries: "attn" | "mamba2" | "mlstm" | "slstm" | "shared_attn"
    layer_pattern: Sequence[str] = ()
    shared_attn_every: int = 0        # zamba2: shared attn block period
    # --- enc-dec (audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 0              # frames after the (stubbed) conv frontend
    cross_attention: bool = False
    # --- vlm ---
    vision_tokens: int = 0            # patches provided by stubbed frontend
    # --- norms / embeddings ---
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    source: str = ""                  # citation bracket from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def block_kind(self, layer_idx: int) -> str:
        """Which block occupies layer ``layer_idx``."""
        if self.layer_pattern:
            return self.layer_pattern[layer_idx % len(self.layer_pattern)]
        if self.family == "ssm" and self.ssm is not None:
            return self.ssm.kind
        return "attn"

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if layer_idx < self.moe_skip_first:
            return False
        return (layer_idx - self.moe_skip_first) % self.moe_every == 0

    def is_global_attn_layer(self, layer_idx: int) -> bool:
        """For local:global interleave (gemma3): True -> full attention."""
        if self.sliding_window == 0:
            return True
        if self.global_every == 0:
            return False
        return (layer_idx + 1) % self.global_every == 0

    def reduced(self) -> "ArchConfig":
        """CPU-runnable smoke variant of the same family (prompt rules:
        ≤2 layers, d_model ≤ 512, ≤4 experts)."""
        d_model = min(self.d_model, 256)
        num_heads = max(2, min(self.num_heads, 4))
        ratio = max(1, self.num_heads // max(1, self.num_kv_heads))
        num_kv = max(1, num_heads // min(ratio, num_heads))
        head_dim = max(32, d_model // num_heads)
        changes = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=512,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            global_every=min(self.global_every, 2) if self.global_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64),
            vision_tokens=min(self.vision_tokens, 16),
            dtype="float32",
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=min(self.moe.expert_d_ff, 256),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                shared_d_ff=min(self.moe.shared_d_ff, 256),
                dense_residual_d_ff=min(self.moe.dense_residual_d_ff, 256),
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm,
                state_dim=min(self.ssm.state_dim, 16),
                chunk_size=32,
            )
        if self.rope_kind == "mrope":
            # rescale t/h/w frequency-slot split to the reduced head_dim
            tot = sum(self.mrope_sections)
            half = head_dim // 2
            secs = [max(1, s * half // tot) for s in self.mrope_sections]
            secs[0] += half - sum(secs)
            changes["mrope_sections"] = tuple(secs)
        if self.attention == "mla":
            changes["mla"] = MLAConfig(
                kv_lora_rank=64, q_lora_rank=96,
                qk_nope_head_dim=head_dim, qk_rope_head_dim=32,
                v_head_dim=head_dim)
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
        if self.layer_pattern:
            # keep the family mix visible in 2 layers
            changes["layer_pattern"] = tuple(self.layer_pattern[:2]) \
                if len(set(self.layer_pattern[:2])) > 1 \
                else (self.layer_pattern[0], self.layer_pattern[-1])
        return dataclasses.replace(self, **changes)

    mla: Optional[MLAConfig] = None


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                         # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
