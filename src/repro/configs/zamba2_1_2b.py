"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba2 backbone + shared
attention blocks (32H kv=32), d_ff=8192, ssm_state=64, vocab=32000.
[arXiv:2411.15242]"""

from repro.configs.base import ArchConfig, SSMConfig

# Mamba2 backbone with a (shared-weight) attention block every 6 layers.
_PATTERN = ("mamba2",) * 5 + ("shared_attn",)

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,                    # shared block's MLP width
    vocab_size=32000,
    attention="gqa",
    ssm=SSMConfig(kind="mamba2", state_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
    layer_pattern=_PATTERN,
    shared_attn_every=6,
    norm="rmsnorm",
    max_seq_len=1_048_576,
    source="arXiv:2411.15242",
)
