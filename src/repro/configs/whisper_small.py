"""whisper-small [audio] — enc-dec, 12L each, d_model=768 12H d_ff=3072
vocab=51865.  Conv/mel frontend is the stated stub: input_specs() provides
precomputed frame embeddings (batch, 1500, 768).  [arXiv:2212.04356]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,                # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    attention="gqa",              # MHA (kv == heads)
    rope_theta=0.0,               # whisper uses learned absolute positions
    mlp_kind="gelu",
    norm="layernorm",
    encoder_layers=12,
    encoder_seq=1500,
    cross_attention=True,
    # real whisper caps at 448; the positional table is extended so the
    # assigned train_4k/decode_32k shapes lower (shape exercise — DESIGN.md §4)
    max_seq_len=4096,
    source="arXiv:2212.04356",
)
