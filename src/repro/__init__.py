"""repro — reproduction of "The Scalability for Parallel Machine Learning
Training Algorithm: Dataset Matters" (arXiv:1910.11510), grown into a
JAX/Pallas system.  `core` holds the paper's substance (dataset-character
metrics, the four parallel training algorithms, scalability theory, the
advisor); `experiments` is the unified sweep engine that reproduces every
figure/table; `analysis` turns seed-replicated sweeps into statistics
(bootstrap CIs, scaling-law fits, the paper report CLI); `distributed`
shards sweep execution over a device mesh with mesh-invariant results
and carries the model stack's FSDP/TP partition rules; `data`
generates the Table-I synthetic datasets; `kernels`
carries the Pallas hot loops with jnp oracles; `configs`/`models`/`optim`/
`train`/`serve`/`launch` form the production-flavored model
stack the scalability analysis plugs into.  Start at README.md.
"""
