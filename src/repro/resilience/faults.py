"""Deterministic fault injection for parallel-training update streams.

A :class:`FaultSpec` names four fault processes on the stream of worker
updates — the delivery effects Keuper & Pfreundt (arXiv 1505.04956) show
bound async scalability:

  * **drop**      the update is lost: its gradient never lands
                  (``drop_rate``);
  * **duplicate** the update lands twice — a retransmission the dedup
                  layer missed (``duplicate_rate``);
  * **straggle**  the worker read an *extra-stale* model: its gradient
                  was computed ``straggle_rounds`` rounds further in the
                  past than the algorithm's own staleness already implies
                  (``straggle_rate``);
  * **corrupt**   the gradient payload is corrupted — ``sign_flip``
                  (adversarial bit-flip of the direction) or ``quantize``
                  (deterministic ``corrupt_bits``-bit rounding, the lossy
                  compression model) (``corrupt_rate``).

Faults are **environment, not randomness of the experiment**: every mask
is drawn from ``PRNGKey(FaultSpec.seed)`` (one ``fold_in`` tag per fault
kind), never from the engine's per-seed draw keys — so seed replicates of
a sweep face the *same* fault schedule, and the seed axis keeps measuring
sampling noise only.

Determinism / parity contract (pinned in tests/test_resilience.py):

  * a stream is a pure function of ``(spec.seed, shape)``; re-running a
    faulted sweep is bit-reproducible;
  * threefry draws depend only on the element *count*, so an ``(iters,)``
    stream and an ``(E, R, D, w)`` stream with the same total count carry
    identical events — the racing multi-device mode and the sequential
    staleness oracle therefore see the SAME fault schedule, which is what
    makes faulted results mesh-invariant;
  * every application helper is IEEE-exact at zero rates: delivery scales
    are a computed ``1.0`` and corruption is a ``where`` over a computed
    all-False mask, so ``FaultSpec()`` (all rates 0) runs bit-identical
    to the unfaulted code path even though it takes the faulted trace.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

#: corruption models a FaultSpec may name
CORRUPT_KINDS = ("sign_flip", "quantize")

#: fold_in tags, one independent threefry stream per fault process
_TAGS = {"drop": 0, "dup": 1, "straggle": 2, "corrupt": 3}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault environment: four event rates plus their parameters.

    Rates are per-update probabilities in ``[0, 1]``.  The spec is a
    frozen dataclass so it can live (as its :func:`to_dict` form) inside
    ``JobSpec.kwargs`` — faulted jobs fingerprint-split the artifact
    cache exactly like any other hyperparameter change.
    """

    drop_rate: float = 0.0
    duplicate_rate: float = 0.0
    straggle_rate: float = 0.0
    straggle_rounds: int = 1          # extra staleness per straggle event
    corrupt_rate: float = 0.0
    corrupt_kind: str = "sign_flip"   # one of CORRUPT_KINDS
    corrupt_bits: int = 8             # quantize: signed levels = 2^(bits-1)
    seed: int = 0                     # the fault environment's own key

    def validate(self) -> "FaultSpec":
        for f in ("drop_rate", "duplicate_rate", "straggle_rate",
                  "corrupt_rate"):
            v = getattr(self, f)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"FaultSpec.{f}={v!r} must be in [0, 1]")
        if self.corrupt_kind not in CORRUPT_KINDS:
            raise ValueError(f"FaultSpec.corrupt_kind={self.corrupt_kind!r} "
                             f"not in {CORRUPT_KINDS}")
        if self.straggle_rounds < 1:
            raise ValueError(
                f"FaultSpec.straggle_rounds={self.straggle_rounds} "
                f"must be >= 1")
        if self.corrupt_bits < 1:
            raise ValueError(f"FaultSpec.corrupt_bits={self.corrupt_bits} "
                             f"must be >= 1")
        return self

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @property
    def any_rate(self) -> float:
        """Max event rate — 0.0 means the spec is a (bit-exact) no-op."""
        return max(self.drop_rate, self.duplicate_rate,
                   self.straggle_rate, self.corrupt_rate)


FaultLike = Union[None, Dict, FaultSpec]


def resolve(fault: FaultLike) -> Optional[FaultSpec]:
    """``None`` passes through (no fault path at all); a dict — the
    JSON-round-tripped ``JobSpec.kwargs`` form — becomes a validated
    :class:`FaultSpec`; a spec validates and passes through."""
    if fault is None:
        return None
    if isinstance(fault, FaultSpec):
        return fault.validate()
    if isinstance(fault, dict):
        try:
            return FaultSpec(**fault).validate()
        except TypeError as e:
            raise ValueError(f"bad fault dict {fault!r}: {e}") from None
    raise TypeError(f"fault must be None, a dict, or a FaultSpec; "
                    f"got {type(fault).__name__}")


def make_stream(spec: FaultSpec, shape: Tuple[int, ...]) -> Dict:
    """Draw the per-update event indicators for a whole run.

    Returns ``{"drop", "dup", "straggle", "corrupt"}`` — float32 0/1
    arrays of ``shape``, each from its own ``fold_in(PRNGKey(seed), tag)``
    stream.  ``uniform() < rate`` makes a zero rate an all-zeros array by
    construction (uniform draws live in ``[0, 1)``), which the apply
    helpers below turn into bit-exact identity.
    """
    key = jax.random.PRNGKey(spec.seed)
    rates = {"drop": spec.drop_rate, "dup": spec.duplicate_rate,
             "straggle": spec.straggle_rate, "corrupt": spec.corrupt_rate}
    return {name: (jax.random.uniform(jax.random.fold_in(key, tag), shape)
                   < rates[name]).astype(jnp.float32)
            for name, tag in _TAGS.items()}


def delivery_scale(stream_slice: Dict):
    """Multiplier a delivered update lands with: ``(1 - drop)(1 + dup)``
    — 0 for a lost message, 2 for a duplicated one, and a computed
    exact 1.0 when neither event fired (the zero-rate identity)."""
    return (1.0 - stream_slice["drop"]) * (1.0 + stream_slice["dup"])


def extra_staleness(spec: FaultSpec, stream_slice: Dict):
    """int32 extra rounds of staleness a straggle event adds (0 when the
    event did not fire)."""
    return (stream_slice["straggle"] * spec.straggle_rounds).astype(jnp.int32)


def corrupt(spec: FaultSpec, g, flag):
    """Apply the spec's corruption model where ``flag`` fired.

    ``flag`` broadcasts against ``g`` from the left (a per-worker flag
    corrupts that worker's whole gradient row).  The un-fired branch is
    ``g`` itself through ``jnp.where``, so a computed all-False mask is
    bit-exact identity.
    """
    flag = jnp.asarray(flag)
    while flag.ndim < jnp.ndim(g):
        flag = flag[..., None]
    if spec.corrupt_kind == "sign_flip":
        bad = -g
    else:   # quantize: deterministic symmetric rounding to 2^(bits-1) levels
        levels = float(2 ** (spec.corrupt_bits - 1))
        s = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
        bad = jnp.round(g / s * levels) * (s / levels)
    return jnp.where(flag > 0, bad, g)
