"""repro.resilience — faults as science, and an engine that survives them.

Two halves (docs/robustness.md):

  `faults`    :class:`FaultSpec` — a seeded, fingerprint-hashed description
              of update-delivery faults (dropped / duplicated / straggling
              updates, gradient corruption) realized as *pure traced
              transforms* on the engine's pre-drawn update streams.  The
              same spec drives the Hogwild! staleness oracle, local SGD's
              sync average, and the true racing multi-device reconcile —
              faulted sweeps vmap, bucket, and cache like any other job.
  `journal`   per-job JSONL journaling for `runner.run_sweep`: every
              completed job is appended atomically, so a sweep killed
              mid-run resumes from the journal and still produces a
              byte-identical final artifact.

The determinism contract both halves build on: a fault stream is a
function of ``FaultSpec.seed`` and the stream shape's element count only
— never of the worker grid, the seed replicate, the mesh, or wall time —
and every fault application is written so that zero-rate streams are
**bit-exact** with the unfaulted code path (multiplies by a computed 1.0,
``where`` on a computed all-False mask).
"""

from repro.resilience.faults import (FaultSpec, corrupt, delivery_scale,
                                     make_stream, resolve)
from repro.resilience.journal import (append_entry, consume, journal_path,
                                      read_entries)

__all__ = [
    "FaultSpec", "resolve", "make_stream", "delivery_scale", "corrupt",
    "journal_path", "append_entry", "read_entries", "consume",
]
