"""Per-job crash journal for `repro.experiments.runner.run_sweep`.

Layout: ``<cache_dir>/<spec-name>-<fingerprint16>.journal.jsonl`` — one
JSON object per line, appended (and fsync'd) the moment a job finishes:

    {"fingerprint": "<full sha256>", "key": "<job.key>", "job": {...}}

``job`` is the job's *finished* result dict — readouts, predictions, and
``status`` already attached — exactly the object the final artifact will
carry.  Because JSON float serialization round-trips exactly (shortest
repr), a re-run that replays journal entries instead of recomputing them
produces a byte-identical artifact (pinned in tests/test_resilience.py).

Robustness: a crash mid-append leaves at most one partial trailing line;
:func:`read_entries` skips unparsable lines and entries whose
``fingerprint`` does not match, so a stale or torn journal can only cause
recomputation, never a wrong resume.  The runner deletes the journal once
the final artifact is stored.
"""

from __future__ import annotations

import json
import os
from typing import Dict


def journal_path(cache_dir: str, name: str, fp: str) -> str:
    """Sibling of the artifact the journal is protecting (mirrors
    `repro.experiments.cache.artifact_path`'s ``<name>-<fp16>`` naming;
    not imported from there — `repro.resilience` must stay importable
    from `repro.core.algorithms` without pulling in the experiments
    package)."""
    return os.path.join(cache_dir, f"{name}-{fp[:16]}.journal.jsonl")


def append_entry(path: str, fp: str, key: str, job: Dict) -> None:
    """Durably append one completed job (flush + fsync: a SIGKILL right
    after this call must still find the entry on disk)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    line = json.dumps({"fingerprint": fp, "key": key, "job": job},
                      default=float)
    with open(path, "a") as f:
        f.write(line + "\n")
        f.flush()
        os.fsync(f.fileno())


def read_entries(path: str, fp: str) -> Dict[str, Dict]:
    """``{job key: job result}`` for every intact entry matching ``fp``.
    Missing file, torn trailing lines, and foreign fingerprints all
    degrade to "not journaled" (the job just recomputes)."""
    out: Dict[str, Dict] = {}
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError:
        return out
    for line in lines:
        try:
            entry = json.loads(line)
        except json.JSONDecodeError:
            continue                      # torn write — skip
        if (isinstance(entry, dict) and entry.get("fingerprint") == fp
                and isinstance(entry.get("job"), dict)
                and isinstance(entry.get("key"), str)):
            out[entry["key"]] = entry["job"]
    return out


def consume(path: str) -> None:
    """Remove the journal (called after the final artifact is stored —
    the artifact now supersedes it)."""
    try:
        os.unlink(path)
    except OSError:
        pass
