"""SSM blocks: Mamba2 (chunked SSD), xLSTM's mLSTM (chunkwise matrix-memory)
and sLSTM (stabilized scalar-memory recurrence).

TPU adaptation (DESIGN.md §6): the CUDA selective-scan becomes a *chunked*
formulation — within-chunk work is MXU-friendly (chunk x chunk matmuls,
chunk=128 aligns with the systolic array), and only chunk-boundary states are
materialized (HBM footprint O(T/chunk), not O(T)).  Inter-chunk recurrence is
a short ``lax.scan``.

All blocks expose:
  init_*            -> param subtree
  *_forward(p, x)   -> (B, T, d)          full-sequence (train / prefill)
  *_step(p, x, st)  -> ((B, 1, d), state) single-token decode
  init_*_state      -> decode state (constant-size: the long_500k enabler)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import _dense_init

HEAD_DIM = 64


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    heads = s.num_heads or inner // HEAD_DIM
    return s, inner, heads, inner // heads, s.state_dim


def init_mamba2(key, cfg: ArchConfig, dtype):
    s, inner, H, hd, N = _mamba_dims(cfg)
    d = cfg.d_model
    conv_ch = inner + 2 * N          # x, B, C all pass through the conv
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z(inner), xBC(conv_ch), dt(H)]
        "in_proj": _dense_init(ks[0], (d, 2 * inner + 2 * N + H), dtype),
        "conv_w": _dense_init(ks[1], (s.conv_width, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),        # A = -exp(a_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_proj": _dense_init(ks[2], (inner, d), dtype),
        "norm_scale": jnp.ones((inner,), dtype),      # gated RMSNorm
    }


def _causal_conv(x, w, b):
    """x: (B, T, C); w: (W, C) depthwise."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _gated_rmsnorm(x, z, scale, eps=1e-6):
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _ssd_chunked(xh, dt, B_, C_, a_log, chunk):
    """Chunked SSD core.

    xh: (B,T,H,hd)  dt: (B,T,H)  B_,C_: (B,T,N)  ->  y: (B,T,H,hd),
    final state (B,H,hd,N).
    """
    Bsz, T, H, hd = xh.shape
    N = B_.shape[-1]
    nc = T // chunk
    A = -jnp.exp(a_log)                                   # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32))          # (B,T,H)
    glog = (dt * A).reshape(Bsz, nc, chunk, H)            # log-decay per step
    xin = (xh.astype(jnp.float32)
           * dt[..., None]).reshape(Bsz, nc, chunk, H, hd)
    Bc = B_.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Cc = C_.astype(jnp.float32).reshape(Bsz, nc, chunk, N)

    cs = jnp.cumsum(glog, axis=2)                         # (B,nc,L,H)
    total = cs[:, :, -1]                                  # (B,nc,H)

    # within-chunk (attention-like, causal)
    rel = cs[:, :, :, None, :] - cs[:, :, None, :, :]     # (B,nc,L,L,H) t,s
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    qk = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)            # (B,nc,L,L)
    y_intra = jnp.einsum("bcts,bctsh,bcshd->bcthd", qk, M, xin)

    # chunk summary state: decay inputs to chunk end
    decay_to_end = jnp.exp(total[:, :, None, :] - cs)     # (B,nc,L,H)
    S_chunk = jnp.einsum("bclh,bclhd,bcln->bchdn",
                         decay_to_end, xin, Bc)           # (B,nc,H,hd,N)

    # inter-chunk scan
    def step(S_prev, inp):
        tot, Sc = inp                                     # (B,H), (B,H,hd,N)
        S_new = jnp.exp(tot)[..., None, None] * S_prev + Sc
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, H, hd, N), jnp.float32)
    S_last, S_befores = jax.lax.scan(
        step, S0,
        (total.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4)))
    S_befores = S_befores.transpose(1, 0, 2, 3, 4)        # (B,nc,H,hd,N)

    y_inter = jnp.einsum("bcln,bclh,bchdn->bclhd",
                         Cc, jnp.exp(cs), S_befores)
    y = (y_intra + y_inter).reshape(Bsz, T, H, hd)
    return y, S_last


def mamba2_forward(p, cfg: ArchConfig, x, return_state=False):
    s, inner, H, hd, N = _mamba_dims(cfg)
    B, T, _ = x.shape
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"])
    z, xBC, dt = jnp.split(proj, [inner, 2 * inner + 2 * N], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)
    xh, B_, C_ = jnp.split(xBC, [inner, inner + N], axis=-1)
    xh = xh.reshape(B, T, H, hd)
    chunk = min(s.chunk_size, T)
    pad = (-T) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    y, S_last = _ssd_chunked(xh, dt, B_, C_, p["a_log"], chunk)
    y = y[:, :T]
    y = y + p["d_skip"][None, None, :, None] * xh[:, :T].astype(jnp.float32)
    y = y.reshape(B, T, inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    if return_state:
        return out, S_last
    return out


@dataclasses.dataclass
class Mamba2State:
    conv: jax.Array          # (B, W-1, conv_ch) trailing inputs
    ssm: jax.Array           # (B, H, hd, N) f32

    def tree_flatten(self):
        return (self.conv, self.ssm), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_dataclass(
    Mamba2State, data_fields=("conv", "ssm"), meta_fields=())


def init_mamba2_state(cfg: ArchConfig, batch, dtype):
    s, inner, H, hd, N = _mamba_dims(cfg)
    conv_ch = inner + 2 * N
    return Mamba2State(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, H, hd, N), jnp.float32),
    )


def mamba2_step(p, cfg: ArchConfig, x, state: Mamba2State):
    """x: (B,1,d) -> (y, new_state)."""
    s, inner, H, hd, N = _mamba_dims(cfg)
    B = x.shape[0]
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"])
    z, xBC, dt = jnp.split(proj, [inner, 2 * inner + 2 * N], axis=-1)
    hist = jnp.concatenate([state.conv, xBC], axis=1)     # (B, W, C)
    conv_out = (jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"])
    xBC = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    xh, B_, C_ = jnp.split(xBC, [inner, inner + N], axis=-1)
    xh = xh.reshape(B, H, hd).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtv * A)                              # (B,H)
    Bv = B_[:, 0].astype(jnp.float32)                     # (B,N)
    Cv = C_[:, 0].astype(jnp.float32)
    S = (decay[..., None, None] * state.ssm
         + jnp.einsum("bh,bhd,bn->bhdn", dtv, xh, Bv))
    y = jnp.einsum("bn,bhdn->bhd", Cv, S)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    return out, Mamba2State(conv=hist[:, 1:], ssm=S)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise linear-attention-with-gates form
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ArchConfig):
    H = cfg.ssm.num_heads or cfg.num_heads
    inner = cfg.ssm.expand * cfg.d_model
    return inner, H, inner // H


def init_mlstm(key, cfg: ArchConfig, dtype):
    inner, H, hd = _mlstm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, inner), dtype),
        "wk": _dense_init(ks[1], (d, inner), dtype),
        "wv": _dense_init(ks[2], (d, inner), dtype),
        "w_if": _dense_init(ks[3], (d, 2 * H), dtype, scale=0.01),
        "b_i": jnp.full((H,), -3.0, jnp.float32),   # small input gates at init
        "b_f": jnp.full((H,), 3.0, jnp.float32),    # open forget gates at init
        "wz": _dense_init(ks[4], (d, inner), dtype),
        "out_proj": _dense_init(ks[5], (inner, d), dtype),
        "norm_scale": jnp.ones((inner,), dtype),
    }


def _mlstm_gates(p, x):
    gf = jnp.einsum("btd,de->bte", x, p["w_if"]).astype(jnp.float32)
    H = p["b_i"].shape[0]
    i_raw = gf[..., :H] + p["b_i"]
    f_raw = gf[..., H:] + p["b_f"]
    log_f = jax.nn.log_sigmoid(f_raw)                     # <= 0
    log_i = jnp.clip(i_raw, -20.0, 10.0)                  # soft-capped exp gate
    return log_i, log_f


def mlstm_forward(p, cfg: ArchConfig, x, return_state=False):
    inner, H, hd = _mlstm_dims(cfg)
    B, T, _ = x.shape
    q = jnp.einsum("btd,de->bte", x, p["wq"]).reshape(B, T, H, hd)
    k = jnp.einsum("btd,de->bte", x, p["wk"]).reshape(B, T, H, hd)
    v = jnp.einsum("btd,de->bte", x, p["wv"]).reshape(B, T, H, hd)
    z = jnp.einsum("btd,de->bte", x, p["wz"])
    log_i, log_f = _mlstm_gates(p, x)                     # (B,T,H)

    chunk = min(cfg.ssm.chunk_size, T)
    pad = (-T) % chunk
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
    Tp = T + pad
    nc = Tp // chunk
    qc = q.reshape(B, nc, chunk, H, hd).astype(jnp.float32) / jnp.sqrt(float(hd))
    kc = k.reshape(B, nc, chunk, H, hd).astype(jnp.float32)
    vc = v.reshape(B, nc, chunk, H, hd).astype(jnp.float32)
    li = log_i.reshape(B, nc, chunk, H)
    lf = log_f.reshape(B, nc, chunk, H)

    cs = jnp.cumsum(lf, axis=2)                           # (B,nc,L,H)
    total = cs[:, :, -1]

    # within-chunk: M[t,s] = exp(cs_t - cs_s + li_s), causal
    rel = cs[:, :, :, None, :] - cs[:, :, None, :, :] + li[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    qk = jnp.einsum("bcthd,bcshd->bctsh", qc, kc)
    y_intra = jnp.einsum("bctsh,bctsh,bcshd->bcthd", qk, M, vc)

    # chunk summary: C_chunk = sum_s exp(total - cs_s + li_s) k_s v_s^T
    w_end = jnp.exp(total[:, :, None, :] - cs + li)       # (B,nc,L,H)
    C_chunk = jnp.einsum("bclh,bclhd,bclhe->bchde", w_end, kc, vc)
    n_chunk = jnp.einsum("bclh,bclhd->bchd", w_end, kc)

    def step(carry, inp):
        C_prev, n_prev = carry
        tot, Cc, nc_ = inp
        decay = jnp.exp(tot)[..., None, None]
        C_new = decay * C_prev + Cc
        n_new = jnp.exp(tot)[..., None] * n_prev + nc_
        return (C_new, n_new), (C_prev, n_prev)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    (C_last, n_last), (C_bef, n_bef) = jax.lax.scan(
        step, (C0, n0),
        (total.transpose(1, 0, 2), C_chunk.transpose(1, 0, 2, 3, 4),
         n_chunk.transpose(1, 0, 2, 3)))
    C_bef = C_bef.transpose(1, 0, 2, 3, 4)
    n_bef = n_bef.transpose(1, 0, 2, 3)

    y_inter = jnp.einsum("bclhd,bclh,bchde->bclhe",
                         qc, jnp.exp(cs), C_bef)
    n_inter = jnp.einsum("bclhd,bclh,bchd->bclh", qc, jnp.exp(cs), n_bef)
    n_intra_s = jnp.einsum("bctsh,bcshd,bcthd->bcth", M, kc, qc)
    denom = jnp.maximum(jnp.abs(n_inter + n_intra_s), 1.0)[..., None]
    y = (y_intra + y_inter) / denom
    y = y.reshape(B, Tp, inner)[:, :T].astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    if return_state:
        return out, (C_last, n_last)
    return out


@dataclasses.dataclass
class MLSTMState:
    C: jax.Array             # (B,H,hd,hd) f32
    n: jax.Array             # (B,H,hd) f32

    def tree_flatten(self):
        return (self.C, self.n), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_dataclass(
    MLSTMState, data_fields=("C", "n"), meta_fields=())


def init_mlstm_state(cfg: ArchConfig, batch, dtype):
    inner, H, hd = _mlstm_dims(cfg)
    return MLSTMState(C=jnp.zeros((batch, H, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, H, hd), jnp.float32))


def mlstm_step(p, cfg: ArchConfig, x, state: MLSTMState):
    inner, H, hd = _mlstm_dims(cfg)
    B = x.shape[0]
    q = jnp.einsum("btd,de->bte", x, p["wq"]).reshape(B, H, hd).astype(jnp.float32)
    k = jnp.einsum("btd,de->bte", x, p["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = jnp.einsum("btd,de->bte", x, p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    z = jnp.einsum("btd,de->bte", x, p["wz"])
    log_i, log_f = _mlstm_gates(p, x)                     # (B,1,H)
    fi, ii = jnp.exp(log_f[:, 0]), jnp.exp(log_i[:, 0])   # (B,H)
    q = q / jnp.sqrt(float(hd))
    C = fi[..., None, None] * state.C + ii[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = fi[..., None] * state.n + ii[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), 1.0)
    y = (num / den[..., None]).reshape(B, 1, inner).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    return out, MLSTMState(C=C, n=n)


# ---------------------------------------------------------------------------
# sLSTM — stabilized scalar-memory recurrence with head-wise recurrent mixing
# ---------------------------------------------------------------------------

def _slstm_dims(cfg: ArchConfig):
    H = cfg.ssm.num_heads or cfg.num_heads
    return cfg.d_model, H, cfg.d_model // H


def init_slstm(key, cfg: ArchConfig, dtype):
    d, H, hd = _slstm_dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_in": _dense_init(ks[0], (d, 4 * d), dtype),        # i,f,z,o
        "r": _dense_init(ks[1], (H, hd, 4 * hd), dtype, scale=1.0 / hd ** 0.5),
        "b": jnp.concatenate([jnp.full((d,), -3.0), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "out_proj": _dense_init(ks[2], (d, d), dtype),
        "norm_scale": jnp.ones((d,), dtype),
    }


def _slstm_cell(p, wx_t, carry):
    """One sLSTM step.  wx_t: (B, 4d) precomputed input projection."""
    c, n, m, h = carry                                    # (B,H,hd) each, f32
    B, H, hd = c.shape
    rh = jnp.einsum("bhd,hde->bhe", h, p["r"].astype(jnp.float32))  # (B,H,4hd)
    wx = wx_t.astype(jnp.float32).reshape(B, 4, H, hd).transpose(0, 2, 3, 1)
    rr = rh.reshape(B, H, 4, hd).transpose(0, 1, 3, 2)
    pre = wx + rr + p["b"].reshape(4, H, hd).transpose(1, 2, 0)[None]
    i_r, f_r, z_r, o_r = [pre[..., j] for j in range(4)]
    zt = jnp.tanh(z_r)
    ot = jax.nn.sigmoid(o_r)
    log_f = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(log_f + m, i_r)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(i_r - m_new) * zt
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(i_r - m_new)
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new)


def slstm_forward(p, cfg: ArchConfig, x, return_state=False):
    d, H, hd = _slstm_dims(cfg)
    B, T, _ = x.shape
    wx = jnp.einsum("btd,de->bte", x, p["w_in"])          # (B,T,4d)

    def step(carry, wx_t):
        new = _slstm_cell(p, wx_t, carry)
        return new, new[3]

    init = tuple(jnp.zeros((B, H, hd), jnp.float32) for _ in range(3)) + (
        jnp.zeros((B, H, hd), jnp.float32),)
    init = (init[0], init[1], jnp.full((B, H, hd), -1e9, jnp.float32), init[3])
    carry, hs = jax.lax.scan(step, init, wx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(x.dtype)
    y = _gated_rmsnorm(y, jnp.ones_like(y), p["norm_scale"])
    out = jnp.einsum("btd,de->bte", y, p["out_proj"])
    if return_state:
        return out, carry
    return out


@dataclasses.dataclass
class SLSTMState:
    c: jax.Array
    n: jax.Array
    m: jax.Array
    h: jax.Array

    def tree_flatten(self):
        return (self.c, self.n, self.m, self.h), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_dataclass(
    SLSTMState, data_fields=("c", "n", "m", "h"), meta_fields=())


def init_slstm_state(cfg: ArchConfig, batch, dtype):
    d, H, hd = _slstm_dims(cfg)
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, H, hd), -1e9, jnp.float32),
                      h=z)


def slstm_step(p, cfg: ArchConfig, x, state: SLSTMState):
    d, H, hd = _slstm_dims(cfg)
    B = x.shape[0]
    wx = jnp.einsum("btd,de->bte", x, p["w_in"])[:, 0]
    carry = _slstm_cell(p, wx, (state.c, state.n, state.m, state.h))
    y = carry[3].reshape(B, 1, d).astype(x.dtype)
    y = _gated_rmsnorm(y, jnp.ones_like(y), p["norm_scale"])
    out = jnp.einsum("btd,de->bte", y, p["out_proj"])
    return out, SLSTMState(*carry)
