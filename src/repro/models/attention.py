"""Attention blocks: GQA (with optional sliding window), MLA (DeepSeek-V2),
cross-attention (whisper), plus their decode-time KV caches.

Reference implementations are pure jnp (the Pallas flash kernel in
``repro.kernels`` is the TPU hot-spot path and is validated against these).

Shapes: hidden (B, S, d_model); caches (B, T, kv_heads, head_dim).
MLA caches the *compressed* latent (B, T, kv_lora) + shared rope key
(B, T, rope_dim) and uses the absorbed-matmul decode path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, apply_mrope, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, kv * hd), dtype),
        "wv": _dense_init(ks[2], (d, kv * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def init_mla(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, h * qk_head), dtype),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "wk_rope": _dense_init(ks[3], (d, m.qk_rope_head_dim), dtype),
        "wk_b": _dense_init(ks[4], (m.kv_lora_rank, h * m.qk_nope_head_dim), dtype),
        "wv_b": _dense_init(ks[5], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": _dense_init(ks[6], (h * m.v_head_dim, d), dtype),
    }


def init_cross_attn(key, cfg: ArchConfig, dtype):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, h * hd), dtype),
        "wv": _dense_init(ks[2], (d, h * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }


# ---------------------------------------------------------------------------
# Masks + core attention math
# ---------------------------------------------------------------------------

def causal_mask(q_len, kv_len, q_offset=0, window=0):
    """(q_len, kv_len) bool mask.  window=0 -> plain causal."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m


Q_CHUNK = 1024          # q-row tiling threshold for long sequences


def _attn_rows(q, k, v, mask, D):
    """One q-row-block of attention.  q: (B,c,H,D); k,v: (B,T,H,Dv);
    mask broadcastable to (B,1,c,T)."""
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(D))
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)


def gqa_attention(q, k, v, mask=None):
    """q: (B,S,H,D); k,v: (B,T,KV,D); mask broadcastable to (B,1,S,T).

    Head-major formulation: KV heads are repeated up to H so every einsum
    carries a clean head axis — SPMD shards it on 'model' without the
    involuntary full rematerializations the (kv, group) split provokes.

    Decode (S == 1) keeps the grouped form (no KV repeat — repeating a 32k
    cache 8x would be a 9x HBM hit).  Long sequences (S > Q_CHUNK) tile over
    q rows so live score buffers stay (B, H, Q_CHUNK, T) — the jnp analogue
    of the Pallas flash kernel's row blocking.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    if S == 1 and KV != H:
        G = H // KV
        qg = q.reshape(B, KV, G, D)
        scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(D))
        if mask is not None:           # (B,1,1,T) -> (B,1,1,T) broadcast
            scores = jnp.where(mask[:, :, 0, None, :] if mask.ndim == 4
                               else mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgt,btkd->bkgd", w.astype(v.dtype), v)
        return out.reshape(B, 1, H, v.shape[-1])

    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    if mask is not None and mask.ndim == 3:
        mask = mask[:, :, None]

    if S <= Q_CHUNK or S % Q_CHUNK:
        out = _attn_rows(q, k, v, mask, D)
        return out.reshape(B, S, H, v.shape[-1])

    nc = S // Q_CHUNK

    def body(_, i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * Q_CHUNK, Q_CHUNK, axis=1)
        mc = (jax.lax.dynamic_slice_in_dim(mask, i * Q_CHUNK,
                                           Q_CHUNK, axis=2)
              if mask is not None and mask.shape[2] == S else mask)
        return _, _attn_rows(qc, k, v, mc, D)

    _, chunks = jax.lax.scan(body, None, jnp.arange(nc))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v.shape[-1])
    return out


def _rope_any(cfg, x, positions):
    if cfg.rope_theta == 0.0:
        return x            # learned absolute positions (whisper)
    if cfg.rope_kind == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# GQA forward (train/prefill) and decode
# ---------------------------------------------------------------------------

def _project_qkv(p, cfg, x):
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, h, hd), k.reshape(B, S, kv, hd),
            v.reshape(B, S, kv, hd))


def gqa_forward(p, cfg: ArchConfig, x, positions, *, window=0,
                attention_impl="reference"):
    """Full-sequence causal attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    q = _rope_any(cfg, q, positions)
    k = _rope_any(cfg, k, positions)
    if attention_impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        mask = causal_mask(S, S, window=window)[None, None]
        out = gqa_attention(q, k, v, mask)
    out = out.reshape(B, S, -1)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


@dataclasses.dataclass
class KVCache:
    k: jax.Array            # (B, T, KV, D) — T = max_len or window
    v: jax.Array
    pos: jax.Array          # (B, T) int32 absolute position per slot (-1 empty)
    index: jax.Array        # scalar int32: next write slot (ring for window)
    window: int = 0         # 0 -> full cache

    def tree_flatten(self):
        return (self.k, self.v, self.pos, self.index), (self.window,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, window=aux[0])


jax.tree_util.register_dataclass(
    KVCache, data_fields=("k", "v", "pos", "index"), meta_fields=("window",))


def init_kv_cache(cfg: ArchConfig, batch, max_len, dtype, window=0):
    T = window if window else max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, T, kv, hd), dtype),
        v=jnp.zeros((batch, T, kv, hd), dtype),
        pos=jnp.full((batch, T), -1, jnp.int32),
        index=jnp.zeros((), jnp.int32),
        window=window,
    )


def gqa_decode(p, cfg: ArchConfig, x, cache: KVCache, position):
    """One-token decode.  x: (B, 1, d); position: scalar int32 (absolute)."""
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x)
    pos_b = jnp.broadcast_to(position[None, None], (B, 1))
    if cfg.rope_kind == "mrope":
        pos3 = jnp.broadcast_to(position[None, None, None], (3, B, 1))
        q = _rope_any(cfg, q, pos3)
        k_new = _rope_any(cfg, k_new, pos3)
    else:
        q = _rope_any(cfg, q, pos_b)
        k_new = _rope_any(cfg, k_new, pos_b)
    slot = cache.index % cache.k.shape[1] if cache.window else cache.index
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, jnp.broadcast_to(position[None, None], (B, 1)).astype(jnp.int32),
        slot, axis=1)
    valid = pos >= 0                                  # (B, T)
    if cache.window:
        valid = valid & (pos > position - cache.window)
    mask = valid[:, None, None, :]                    # (B,1,1,T)
    out = gqa_attention(q, k, v, mask)                # (B,1,H,D)
    out = out.reshape(B, 1, -1)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"])
    new_cache = KVCache(k=k, v=v, pos=pos, index=cache.index + 1,
                        window=cache.window)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array          # (B, T, kv_lora)
    k_rope: jax.Array        # (B, T, rope_dim)
    index: jax.Array

    def tree_flatten(self):
        return (self.c_kv, self.k_rope, self.index), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_dataclass(
    MLACache, data_fields=("c_kv", "k_rope", "index"), meta_fields=())


def init_mla_cache(cfg: ArchConfig, batch, max_len, dtype):
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def _mla_q(p, cfg, x, positions):
    m, B, S, h = cfg.mla, x.shape[0], x.shape[1], cfg.num_heads
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,re->bse", cq, p["wq_b"]).reshape(
        B, S, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(p, cfg: ArchConfig, x, positions):
    """Training/prefill MLA: decompress keys/values (flash-friendly form)."""
    m, B, S, h = cfg.mla, x.shape[0], x.shape[1], cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wk_rope"])   # shared across heads
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,re->bse", c_kv, p["wk_b"]).reshape(
        B, S, h, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,re->bse", c_kv, p["wv_b"]).reshape(B, S, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, h, m.qk_rope_head_dim))], axis=-1)
    mask = causal_mask(S, S)[None, None]
    out = gqa_attention(q, k, v, mask)                    # MHA: KV == H
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, -1), p["wo"])


def mla_decode(p, cfg: ArchConfig, x, cache: MLACache, position):
    """Absorbed-matmul decode: scores against the *compressed* cache."""
    m, B, h = cfg.mla, x.shape[0], cfg.num_heads
    pos_b = jnp.broadcast_to(position[None, None], (B, 1))
    q_nope, q_rope = _mla_q(p, cfg, x, pos_b)             # (B,1,h,·)
    c_new = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    kr_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["wk_rope"])[:, :, None, :],
        pos_b, cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, cache.index, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, cache.index, axis=1)
    # absorb W_uk into q: (B,1,h,nope) x (r, h*nope) -> (B,1,h,r)
    wk_b = p["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk_b)
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
              + jnp.einsum("bshn,btn->bhst", q_rope, k_rope)
              ).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    T = c_kv.shape[1]
    valid = jnp.arange(T)[None, None, None, :] <= cache.index
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    lat = jnp.einsum("bhst,btr->bshr", w, c_kv)           # (B,1,h,r)
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", lat, wv_b)
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, 1, -1), p["wo"])
    return y, MLACache(c_kv=c_kv, k_rope=k_rope, index=cache.index + 1)


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder -> encoder output)
# ---------------------------------------------------------------------------

def cross_attn_forward(p, cfg: ArchConfig, x, enc_out):
    B, S, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    Te = enc_out.shape[1]
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, h, hd)
    k = jnp.einsum("btd,de->bte", enc_out, p["wk"]).reshape(B, Te, h, hd)
    v = jnp.einsum("btd,de->bte", enc_out, p["wv"]).reshape(B, Te, h, hd)
    out = gqa_attention(q, k, v, mask=None)
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, -1), p["wo"])
