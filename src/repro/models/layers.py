"""Shared building blocks: norms, MLPs, rotary embeddings (standard + M-RoPE).

Parameters are plain pytrees (dicts of jnp arrays).  Every ``init_*`` takes a
PRNG key and returns the param subtree; every ``apply_*`` is a pure function.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_norm(kind, d, dtype):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(kind, p, x):
    return apply_rmsnorm(p, x) if kind == "rmsnorm" else apply_layernorm(p, x)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wi_gate": _dense_init(ks[0], (d_model, d_ff), dtype),
            "wi_up": _dense_init(ks[1], (d_model, d_ff), dtype),
            "wo": _dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {  # gelu
        "wi": _dense_init(ks[0], (d_model, d_ff), dtype),
        "bi": jnp.zeros((d_ff,), dtype),
        "wo": _dense_init(ks[1], (d_ff, d_model), dtype),
        "bo": jnp.zeros((d_model,), dtype),
    }


def apply_mlp(p, x, kind):
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, p["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jnp.einsum("...f,fd->...d", h, p["wo"])
    h = jnp.einsum("...d,df->...f", x, p["wi"]) + p["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"]) + p["bo"]


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    # half-dim inverse frequencies
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                           # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta, sections: Sequence[int]):
    """Qwen2-VL multimodal RoPE.

    x: (batch, seq, heads, head_dim); positions3: (3, batch, seq) —
    temporal/height/width position ids.  ``sections`` splits head_dim/2
    frequency slots among the three axes.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    # per-frequency-slot axis selector
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)
    ])
    assert sec.shape[0] == hd // 2, (sec.shape, hd)
    # gather the right positional stream per slot: (batch, seq, hd/2)
    pos3t = positions3.transpose(1, 2, 0).astype(jnp.float32)   # (b, s, 3)
    pos = pos3t[:, :, sec]                                      # (b, s, hd/2)
    ang = pos * inv[None, None, :]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def init_embedding(key, vocab, d_model, dtype):
    return {"table": _dense_init(key, (vocab, d_model), dtype, scale=0.02)}


def init_learned_positions(key, max_len, d_model, dtype):
    return {"pos": _dense_init(key, (max_len, d_model), dtype, scale=0.02)}
