"""Unified CausalLM covering all assigned families.

A model is a sequence of *segments*: maximal runs of identical layer specs
(run-length encoding of the per-layer block plan).  Each segment's params are
stacked on a leading axis and executed with ``jax.lax.scan`` — this keeps the
HLO size O(#distinct block kinds), not O(num_layers), which is what makes the
80-layer dry-runs compile quickly on 512 virtual devices.

Entry points:
  init_params(key, cfg)                         -> param pytree
  forward(params, cfg, batch, ...)              -> logits (train / prefill)
  init_decode_state(cfg, batch, max_len, dtype) -> per-layer caches
  decode_step(params, cfg, tokens, state)       -> (logits, new state)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, init_embedding,
                                 init_learned_positions, init_mlp, init_norm,
                                 _dense_init)
from repro.models.moe import init_moe, moe_forward


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                 # attn | mla | mamba2 | mlstm | slstm | shared_attn
    moe: bool = False
    window: int = 0           # sliding window for attn (0 = full)
    cross: bool = False       # whisper decoder: add cross-attention


def layer_plan(cfg: ArchConfig) -> List[LayerSpec]:
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind == "attn" and cfg.attention == "mla":
            kind = "mla"
        window = 0
        if kind == "attn" and cfg.sliding_window and not cfg.is_global_attn_layer(i):
            window = cfg.sliding_window
        specs.append(LayerSpec(
            kind=kind,
            moe=cfg.is_moe_layer(i) if kind in ("attn", "mla") else False,
            window=window,
            cross=cfg.cross_attention and kind == "attn",
        ))
    return specs


def segments(cfg: ArchConfig) -> List[Tuple[LayerSpec, int]]:
    """Run-length encoding of the layer plan."""
    out: List[Tuple[LayerSpec, int]] = []
    for s in layer_plan(cfg):
        if out and out[-1][0] == s:
            out[-1] = (s, out[-1][1] + 1)
        else:
            out.append((s, 1))
    return out


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if spec.kind in ("attn", "mla"):
        p["attn"] = (attn.init_mla(ks[0], cfg, dtype) if spec.kind == "mla"
                     else attn.init_gqa(ks[0], cfg, dtype))
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if spec.moe:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
        if spec.cross:
            p["cross"] = attn.init_cross_attn(ks[2], cfg, dtype)
            p["norm_cross"] = init_norm(cfg.norm, cfg.d_model, dtype)
    elif spec.kind == "mamba2":
        p["block"] = ssm_mod.init_mamba2(ks[0], cfg, dtype)
    elif spec.kind == "mlstm":
        p["block"] = ssm_mod.init_mlstm(ks[0], cfg, dtype)
    elif spec.kind == "slstm":
        p["block"] = ssm_mod.init_slstm(ks[0], cfg, dtype)
    elif spec.kind == "shared_attn":
        # zamba2: weights live in params["shared_attn"]; per-layer we only
        # keep the input norm + the down-projection back into the stream.
        p["down"] = _dense_init(ks[0], (cfg.d_model, cfg.d_model), dtype)
    else:
        raise ValueError(spec.kind)
    return p


@jax.custom_vjp
def _grad_cast_leaf(x):
    return x


def _grad_cast_leaf_fwd(x):
    # zero-size residual carries the primal dtype (dtypes aren't jax types)
    return x, jnp.zeros((0,), x.dtype)


def _grad_cast_leaf_bwd(res, ct):
    return (ct.astype(res.dtype),)


_grad_cast_leaf.defvjp(_grad_cast_leaf_fwd, _grad_cast_leaf_bwd)


def grad_cast(tree):
    """Identity whose COTANGENT is cast to the primal dtype.  Applied to the
    per-layer param slice: mixed-precision internals (f32 silu/softmax/rope)
    otherwise promote weight-grad matmuls to f32, doubling the bytes of the
    per-layer gradient reduction (measured f32[8192,49152] all-reduces on
    qwen110b)."""
    return jax.tree.map(_grad_cast_leaf, tree)


def init_shared_attn(key, cfg: ArchConfig, dtype):
    """zamba2 shared block: concat(h, h0) -> proj -> attn -> mlp."""
    ks = jax.random.split(key, 4)
    return {
        "w_concat": _dense_init(ks[0], (2 * cfg.d_model, cfg.d_model), dtype),
        "attn": attn.init_gqa(ks[1], cfg, dtype),
        "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def _apply_block(p, cfg: ArchConfig, spec: LayerSpec, h, *, positions,
                 h0=None, shared=None, enc_out=None, causal=True,
                 attention_impl="reference", constrain_inner=None):
    """Full-sequence (train / prefill) block application.  Returns (h, aux)."""
    ci = constrain_inner or (lambda x, kind="attn": x)
    res = lambda y: ci(y, kind="residual")
    aux = {}
    x = ci(apply_norm(cfg.norm, p["norm1"], h), kind="attn")
    if spec.kind == "attn":
        y = attn.gqa_forward(p["attn"], cfg, x, positions, window=spec.window,
                             attention_impl=attention_impl)
        h = h + res(y)
        if spec.cross and enc_out is not None:
            xc = apply_norm(cfg.norm, p["norm_cross"], h)
            h = h + res(attn.cross_attn_forward(p["cross"], cfg, xc, enc_out))
        x2 = ci(apply_norm(cfg.norm, p["norm2"], h), kind="mlp")
        if spec.moe:
            y2, aux = moe_forward(p["moe"], cfg, x2)
        else:
            y2 = apply_mlp(p["mlp"], x2, cfg.mlp_kind)
        h = h + res(y2)
    elif spec.kind == "mla":
        y = attn.mla_forward(p["attn"], cfg, x, positions)
        h = h + res(y)
        x2 = ci(apply_norm(cfg.norm, p["norm2"], h), kind="mlp")
        if spec.moe:
            y2, aux = moe_forward(p["moe"], cfg, x2)
        else:
            y2 = apply_mlp(p["mlp"], x2, cfg.mlp_kind)
        h = h + res(y2)
    elif spec.kind in ("mamba2", "mlstm", "slstm"):
        fwd = {"mamba2": ssm_mod.mamba2_forward,
               "mlstm": ssm_mod.mlstm_forward,
               "slstm": ssm_mod.slstm_forward}[spec.kind]
        h = h + res(fwd(p["block"], cfg, x))
    elif spec.kind == "shared_attn":
        z = jnp.concatenate([x, h0], axis=-1)
        z = jnp.einsum("bsd,de->bse", z, shared["w_concat"])
        z = z + attn.gqa_forward(shared["attn"], cfg, z, positions,
                                 attention_impl=attention_impl)
        z2 = apply_norm(cfg.norm, shared["norm2"], z)
        z = z + apply_mlp(shared["mlp"], z2, cfg.mlp_kind)
        h = h + res(jnp.einsum("bsd,de->bse", z, p["down"]))
    return h, aux


def _decode_block(p, cfg: ArchConfig, spec: LayerSpec, h, cache, *, position,
                  h0=None, shared=None, enc_out=None):
    """One-token decode through a block.  Returns (h, new_cache)."""
    x = apply_norm(cfg.norm, p["norm1"], h)
    if spec.kind == "attn":
        y, cache = attn.gqa_decode(p["attn"], cfg, x, cache, position)
        h = h + y
        if spec.cross and enc_out is not None:
            xc = apply_norm(cfg.norm, p["norm_cross"], h)
            h = h + attn.cross_attn_forward(p["cross"], cfg, xc, enc_out)
        x2 = apply_norm(cfg.norm, p["norm2"], h)
        if spec.moe:
            y2, _ = moe_forward(p["moe"], cfg, x2, dropless=True)
        else:
            y2 = apply_mlp(p["mlp"], x2, cfg.mlp_kind)
        h = h + y2
    elif spec.kind == "mla":
        y, cache = attn.mla_decode(p["attn"], cfg, x, cache, position)
        h = h + y
        x2 = apply_norm(cfg.norm, p["norm2"], h)
        if spec.moe:
            y2, _ = moe_forward(p["moe"], cfg, x2, dropless=True)
        else:
            y2 = apply_mlp(p["mlp"], x2, cfg.mlp_kind)
        h = h + y2
    elif spec.kind in ("mamba2", "mlstm", "slstm"):
        step = {"mamba2": ssm_mod.mamba2_step,
                "mlstm": ssm_mod.mlstm_step,
                "slstm": ssm_mod.slstm_step}[spec.kind]
        y, cache = step(p["block"], cfg, x, cache)
        h = h + y
    elif spec.kind == "shared_attn":
        z = jnp.concatenate([x, h0], axis=-1)
        z = jnp.einsum("bsd,de->bse", z, shared["w_concat"])
        y, cache = attn.gqa_decode(shared["attn"], cfg, z, cache, position)
        z = z + y
        z2 = apply_norm(cfg.norm, shared["norm2"], z)
        z = z + apply_mlp(shared["mlp"], z2, cfg.mlp_kind)
        h = h + jnp.einsum("bsd,de->bse", z, p["down"])
    return h, cache


def _init_block_cache(cfg: ArchConfig, spec: LayerSpec, batch, max_len, dtype):
    if spec.kind == "attn":
        return attn.init_kv_cache(cfg, batch, max_len, dtype,
                                  window=spec.window)
    if spec.kind == "shared_attn":
        return attn.init_kv_cache(cfg, batch, max_len, dtype)
    if spec.kind == "mla":
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    if spec.kind == "mamba2":
        return ssm_mod.init_mamba2_state(cfg, batch, dtype)
    if spec.kind == "mlstm":
        return ssm_mod.init_mlstm_state(cfg, batch, dtype)
    if spec.kind == "slstm":
        return ssm_mod.init_slstm_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------

def _init_encoder(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, cfg.encoder_layers + 2)
    layers = []
    for i in range(cfg.encoder_layers):
        k = jax.random.split(ks[i], 3)
        layers.append({
            "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn.init_gqa(k[0], cfg, dtype),
            "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": init_mlp(k[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "pos": init_learned_positions(ks[-2], cfg.encoder_seq, cfg.d_model,
                                      dtype),
        "layers": stacked,
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }


def _encode(params, cfg: ArchConfig, frames, remat=False):
    """frames: (B, encoder_seq, d) — the stubbed conv-frontend output."""
    h = frames + params["pos"]["pos"][None, :frames.shape[1]]

    def body(h, lp):
        x = apply_norm(cfg.norm, lp["norm1"], h)
        B, S, _ = x.shape
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", x, lp["attn"]["wq"]).reshape(
            B, S, cfg.num_heads, hd)
        k = jnp.einsum("bsd,de->bse", x, lp["attn"]["wk"]).reshape(
            B, S, cfg.num_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", x, lp["attn"]["wv"]).reshape(
            B, S, cfg.num_kv_heads, hd)
        y = attn.gqa_attention(q, k, v, mask=None)        # bidirectional
        h = h + jnp.einsum("bse,ed->bsd", y.reshape(B, S, -1),
                           lp["attn"]["wo"])
        x2 = apply_norm(cfg.norm, lp["norm2"], h)
        h = h + apply_mlp(lp["mlp"], x2, cfg.mlp_kind)
        return h, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return apply_norm(cfg.norm, params["final_norm"], h)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    segs = segments(cfg)
    ks = jax.random.split(key, len(segs) + 5)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(
            ks[1], (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.rope_theta == 0.0:           # learned absolute positions
        params["pos_embed"] = init_learned_positions(
            ks[2], cfg.max_seq_len, cfg.d_model, dtype)
    if cfg.encoder_layers:
        params["encoder"] = _init_encoder(ks[3], cfg, dtype)
    if any(s.kind == "shared_attn" for s, _ in segs):
        params["shared_attn"] = init_shared_attn(ks[4], cfg, dtype)

    seg_params = []
    for i, (spec, n) in enumerate(segs):
        keys = jax.random.split(jax.random.fold_in(ks[-1], i), n)
        stacked = jax.vmap(
            lambda k, spec=spec: _init_block(k, cfg, spec, dtype))(keys)
        seg_params.append(stacked)
    params["segments"] = seg_params
    return params


def _embed_inputs(params, cfg: ArchConfig, batch):
    """Returns (h, positions).  batch may contain tokens, vision_embeds,
    positions (M-RoPE 3-stream), frames (audio)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.vision_tokens and "vision_embeds" in batch:
        V = batch["vision_embeds"].shape[1]
        h = jnp.concatenate([batch["vision_embeds"].astype(h.dtype),
                             h[:, V:]], axis=1)
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.rope_kind == "mrope":
        p = jnp.arange(S)[None].repeat(B, 0)
        positions = jnp.stack([p, p, p])               # text-only M-RoPE
    else:
        positions = jnp.arange(S)[None].repeat(B, 0)
    if cfg.rope_theta == 0.0 and "pos_embed" in params:
        # clip so shapes beyond the learned table still lower (whisper's
        # assigned 32k shapes are a shape exercise — DESIGN.md §4)
        ids = jnp.clip(jnp.arange(S), 0,
                       params["pos_embed"]["pos"].shape[0] - 1)
        h = h + jnp.take(params["pos_embed"]["pos"], ids, axis=0)[None]
    return h, positions


def forward_hidden(params, cfg: ArchConfig, batch, *, remat=False,
                   attention_impl="reference", constrain=None,
                   constrain_layer=None, constrain_inner=None):
    """Train / prefill trunk.  Returns (final-norm hidden states, aux).

    ``constrain``: optional h -> h sharding-constraint hook (sequence-parallel
    activation layout), applied to the residual stream after every segment.
    """
    constrain = constrain or (lambda x: x)
    h, positions = _embed_inputs(params, cfg, batch)
    h = constrain(h)
    h0 = h
    enc_out = None
    if cfg.encoder_layers and "frames" in batch:
        enc_out = _encode(params["encoder"], cfg, batch["frames"],
                          remat=remat)
    shared = params.get("shared_attn")
    aux_losses = {"load_balance_loss": jnp.zeros((), jnp.float32)}

    for (spec, n), stack in zip(segments(cfg), params["segments"]):
        def body(carry, layer_p, spec=spec):
            h, lb = carry
            layer_p = grad_cast(layer_p)   # bf16 weight-grad cotangents
            if constrain_layer is not None:
                # pins the per-layer param slice (and, via the transpose rule,
                # its cotangent) to the FSDP layout -> per-layer
                # reduce-scatter of gradients inside the scan backward
                layer_p = constrain_layer(layer_p)
            base_fn = functools.partial(
                _apply_block, cfg=cfg, spec=spec, positions=positions,
                h0=h0, shared=shared, enc_out=enc_out,
                attention_impl=attention_impl,
                constrain_inner=constrain_inner)
            if remat:
                ck_fn = jax.checkpoint(
                    lambda p_, h_: base_fn(p_, h=h_),
                    policy=jax.checkpoint_policies.nothing_saveable)
                h_new, aux = ck_fn(layer_p, h)
            else:
                h_new, aux = base_fn(layer_p, h=h)
            lb = lb + aux.get("load_balance_loss", 0.0)
            return (h_new, lb), None

        (h, aux_losses["load_balance_loss"]), _ = jax.lax.scan(
            body, (h, aux_losses["load_balance_loss"]), stack)
        h = constrain(h)

    h = apply_norm(cfg.norm, params["final_norm"], h)
    return h, aux_losses


def project_logits(params, cfg: ArchConfig, h):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits.astype(jnp.float32)


def forward(params, cfg: ArchConfig, batch, *, remat=False,
            attention_impl="reference", constrain=None):
    """Train / prefill forward returning full logits.  Returns (logits, aux)."""
    constrain = constrain or (lambda x: x)
    h, aux = forward_hidden(params, cfg, batch, remat=remat,
                            attention_impl=attention_impl,
                            constrain=constrain)
    return constrain(project_logits(params, cfg, h)), aux


encode = _encode


def init_decode_state(cfg: ArchConfig, batch, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    caches = []
    for spec, n in segments(cfg):
        one = _init_block_cache(cfg, spec, batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape), one))
    return {"caches": caches, "position": jnp.zeros((), jnp.int32)}


def decode_step(params, cfg: ArchConfig, tokens, state, *, enc_out=None,
                vision_embeds=None, constrain=None):
    """tokens: (B, 1) -> (logits (B,1,V), new_state).

    ``constrain``: optional decode activation hook.  Pinning h REPLICATED
    between blocks turns every weight use into a partial-matmul + tiny psum
    (the (B,1,d) activation is ~2MB) instead of re-gathering the FSDP-
    sharded weights every token (measured 13.9GB/step/device on qwen110b).
    """
    constrain = constrain or (lambda x: x)
    B = tokens.shape[0]
    h = jnp.take(params["embed"]["table"], tokens, axis=0)
    h = constrain(h)
    position = state["position"]
    if cfg.rope_theta == 0.0 and "pos_embed" in params:
        h = h + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"]["pos"], position, 1, axis=0)[None, 0:1]
    h0 = h
    shared = params.get("shared_attn")
    new_caches = []
    for (spec, n), stack, cache in zip(segments(cfg), params["segments"],
                                       state["caches"]):
        def body(h, xs, spec=spec):
            layer_p, layer_cache = xs
            h, new_cache = _decode_block(
                layer_p, cfg, spec, h, layer_cache, position=position,
                h0=h0, shared=shared, enc_out=enc_out)
            return constrain(h), new_cache

        h, nc = jax.lax.scan(body, h, (stack, cache))
        h = constrain(h)
        new_caches.append(nc)
    h = apply_norm(cfg.norm, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits.astype(jnp.float32), {"caches": new_caches,
                                        "position": position + 1}


def chunked_ce(params, cfg: ArchConfig, h, labels, *, chunk=0,
               constrain=None, constrain_head=None):
    # ``constrain`` is the *logits* constraint (vocab-parallel);
    # ``constrain_head`` pins the (V,d)/(d,V) head weight OUTSIDE the chunk
    # scan (otherwise XLA re-gathers the f32 head every chunk — measured
    # 150MB x 1024 iterations on gemma3/train_4k)
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits are rematerialized in the
    backward pass (jax.checkpoint), so the live logits buffer is
    (B, chunk, V) — the enabler for vocab-262k configs at 1M-token batches.
    """
    constrain = constrain or (lambda x: x)
    B, S, d = h.shape
    if chunk <= 0:
        # auto: target ~128 MB of f32 logits per DEVICE per chunk (more
        # chunks => more per-chunk head-grad reductions, measured 311MB x
        # #chunks on qwen110b; fewer chunks => bigger live logits buffer).
        # chunk must DIVIDE S: pick the largest divisor <= the target
        # (naive halving can collapse to chunk=1 -> one-token chunks).
        budget = 128 * 2 ** 20 * max(jax.device_count(), 1)
        target = max(1, min(S, budget // max(B * cfg.vocab_size * 4, 1)))
        chunk = 1
        for c in range(target, 0, -1):
            if S % c == 0:
                chunk = c
                break
    if S % chunk:
        chunk = S     # fallback: no chunking for awkward lengths
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    if cfg.tie_embeddings:
        w = params["embed"]["table"]          # (V, d)
        proj = lambda hh, ww: jnp.einsum("bsd,vd->bsv", hh, ww)
    else:
        w = params["lm_head"]                 # (d, V)
        proj = lambda hh, ww: jnp.einsum("bsd,dv->bsv", hh, ww)
    if constrain_head is not None:
        w = constrain_head(w)                 # hoisted out of the scan

    @jax.checkpoint
    def chunk_loss(h_c, lab_c):
        logits = constrain(proj(h_c, w).astype(jnp.float32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        # label pick via local-vocab mask-sum: take_along_axis on the
        # vocab-sharded dim would all-gather the full f32 logits chunk
        # (226MB x #chunks on gemma3 — measured); the iota-mask reduction
        # stays shard-local and psums a scalar instead
        onehot = (jnp.arange(logits.shape[-1])[None, None, :]
                  == jnp.maximum(lab_c, 0)[..., None])
        ll = jnp.sum(jnp.where(onehot, logp, 0.0), axis=-1)
        mask = (lab_c >= 0).astype(jnp.float32)
        return jnp.sum(ll * mask), jnp.sum(mask)

    def body(acc, xs):
        s, n = chunk_loss(*xs)
        return (acc[0] + s, acc[1] + n), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return -tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ArchConfig, batch, *, remat=False,
            attention_impl="reference", lb_coef=0.01, constrain=None,
            ce_chunk=0, constrain_layer=None, constrain_logits=None,
            constrain_inner=None, constrain_head=None):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    h, aux = forward_hidden(params, cfg, batch, remat=remat,
                            attention_impl=attention_impl,
                            constrain=constrain,
                            constrain_layer=constrain_layer,
                            constrain_inner=constrain_inner)
    loss = chunked_ce(params, cfg, h, batch["labels"], chunk=ce_chunk,
                      constrain=constrain_logits,
                      constrain_head=constrain_head)
    total = loss + lb_coef * aux["load_balance_loss"]
    return total, {"ce_loss": loss, **aux}
