"""Mixture-of-Experts block: top-k routing with capacity-factor dispatch,
optional shared experts (DeepSeek-V2) and a parallel dense residual MLP
(Arctic).

TPU/SPMD adaptation: dispatch bookkeeping (one-hot cumsum -> position in
expert) is computed PER BATCH ROW, so it stays shard-local under the
batch@data layout — a global-token cumsum would serialize across shards
(measured 1.5GB x layers x microbatches of collective traffic on
deepseek-v2).  The only cross-shard exchange is the (B, E, cap, d) expert
buffer resharding batch@data -> expert@model, i.e. the MoE all-to-all.

Gather/scatter (bytes) rather than one-hot einsums (N*E*cap*d FLOPs).
Router load-balance aux loss follows Switch/GShard; per-expert dispatch
entropy is exported as the paper's *diversity* proxy (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, apply_mlp, init_mlp


def init_moe(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "router": _dense_init(ks[0], (d, m.num_experts), jnp.float32),
        # experts stacked on axis 0: (E, d, ff) / (E, ff, d)
        "wi_gate": _dense_init(ks[1], (m.num_experts, d, m.expert_d_ff), dtype),
        "wi_up": _dense_init(ks[2], (m.num_experts, d, m.expert_d_ff), dtype),
        "wo": _dense_init(ks[3], (m.num_experts, m.expert_d_ff, d), dtype),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, m.shared_d_ff, "swiglu", dtype)
    if m.dense_residual_d_ff:
        p["dense_residual"] = init_mlp(ks[5], d, m.dense_residual_d_ff,
                                       "swiglu", dtype)
    return p


def moe_forward(p, cfg: ArchConfig, x, dropless=False):
    """x: (B, S, d) -> (y, aux) where aux has load-balance loss + diversity.

    ``dropless=True`` sizes capacity so no token is ever dropped — used for
    decode, where a 1-token batch must not lose its expert assignment.
    """
    m = cfg.moe
    B, S, d = x.shape
    E = m.num_experts
    k = m.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    gate_vals, top_idx = jax.lax.top_k(probs, k)                  # (B,S,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # --- per-row dispatch bookkeeping (shard-local under batch@data) ------
    cap = (S if dropless
           else max(1, int(m.capacity_factor * S * k / E)))
    flat_e = top_idx.reshape(B, S * k)                            # (B, Sk)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (B,Sk,E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1)                   # (B,Sk,E)
    pos_in_e = jnp.sum(pos_in_e * onehot, axis=-1)                # (B,Sk)
    keep = pos_in_e < cap
    gate_vals = gate_vals * keep.reshape(B, S, k)

    dest = jnp.where(keep, flat_e * cap + pos_in_e, E * cap)      # (B,Sk)
    tok_ids = jnp.reshape(
        jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                         (B, S, k)), (B, S * k))
    token_for_slot = jnp.zeros((B, E * cap + 1), jnp.int32
                               ).at[jnp.arange(B)[:, None], dest].set(
                                   tok_ids, mode="drop")
    filled = jnp.zeros((B, E * cap + 1), jnp.bool_
                       ).at[jnp.arange(B)[:, None], dest].set(True,
                                                              mode="drop")

    # --- gather rows -> (B, E, cap, d) expert buffers ---------------------
    xe = jnp.take_along_axis(x, token_for_slot[:, :E * cap, None], axis=1)
    xe = xe * filled[:, :E * cap, None].astype(x.dtype)
    xe = xe.reshape(B, E, cap, d)

    # --- expert compute (E@model): the b<->e reshard is the all-to-all ----
    g = jnp.einsum("becd,edf->becf", xe, p["wi_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("becf,efd->becd", h, p["wo"])                 # (B,E,cap,d)

    # --- combine: per-row gather back + gated scatter-add -----------------
    ye_flat = jnp.concatenate(
        [ye.reshape(B, E * cap, d), jnp.zeros((B, 1, d), ye.dtype)], axis=1)
    contrib = jnp.take_along_axis(ye_flat, dest[..., None], axis=1)  # (B,Sk,d)
    contrib = contrib * gate_vals.reshape(B, S * k, 1).astype(ye.dtype)
    y = jnp.sum(contrib.reshape(B, S, k, d), axis=2)

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], x, "swiglu")
    if m.dense_residual_d_ff:
        y = y + apply_mlp(p["dense_residual"], x, "swiglu")

    # aux: Switch load-balance loss + dispatch entropy (diversity proxy)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    entropy = -jnp.sum(frac_probs * jnp.log(frac_probs + 1e-9))
    aux = {"load_balance_loss": lb_loss,
           "dispatch_entropy": entropy,
           "expert_fraction": frac_probs}
    return y, aux
