"""repro.models — architecture blocks for the configs registry: attention
variants (`attention`), transformer layers and norms (`layers`), MoE
routing (`moe`), state-space/xLSTM blocks (`ssm`), and the `model` module
that assembles an `ArchConfig` into init/apply functions used by train,
serve, and dryrun.
"""
