"""repro.serve — the serving tier: `engine.greedy_generate` implements
batched greedy decoding against a preallocated KV cache, shared by the
`repro.launch.serve` CLI and the serve tests/benchmarks, and
`engine.SlotDriver` is the batched request driver (continuous-batching-
lite: fixed slots, per-slot active flags) that `repro.service` layers
its probe batching on.
"""

from repro.serve.engine import SlotDriver, mask_tree  # noqa: F401
