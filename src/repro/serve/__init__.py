"""repro.serve — the serving tier: `engine.greedy_generate` implements
batched greedy decoding against a preallocated KV cache, shared by the
`repro.launch.serve` CLI and the serve tests/benchmarks.
"""
