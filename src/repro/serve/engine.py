"""Serving: prefill + single-token decode steps, and a batched request
driver (continuous-batching-lite: fixed slots, per-slot position/active
flags) used by the serving example.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M


def make_prefill_step(cfg: ArchConfig, attention_impl="reference",
                      constrain=None):
    def prefill(params, batch):
        logits, _ = M.forward(params, cfg, batch,
                              attention_impl=attention_impl,
                              constrain=constrain)
        return logits[:, -1, :]          # next-token logits
    return prefill


def make_serve_step(cfg: ArchConfig, constrain=None):
    """serve_step: ONE new token against a KV cache of the shape's seq_len."""
    def serve(params, state, tokens):
        enc_out = state.get("enc_out")
        logits, new_state = M.decode_step(params, cfg, tokens, state["decode"],
                                          enc_out=enc_out,
                                          constrain=constrain)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = {"decode": new_state}
        if enc_out is not None:
            out["enc_out"] = enc_out
        return next_tok, out
    return serve


def init_serve_state(cfg: ArchConfig, batch, max_len, dtype=None,
                     with_encoder=False):
    state = {"decode": M.init_decode_state(cfg, batch, max_len, dtype)}
    if with_encoder or cfg.encoder_layers:
        d = jnp.dtype(dtype or cfg.dtype)
        state["enc_out"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), d)
    return state


def greedy_generate(params, cfg: ArchConfig, prompt_tokens, steps,
                    max_len=None, enc_out=None):
    """Simple generate loop used by examples/tests (CPU-scale)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + steps + 8)
    state = M.init_decode_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
    tok = None
    for t in range(S):
        logits, state = M.decode_step(params, cfg, prompt_tokens[:, t:t + 1],
                                      state, enc_out=enc_out)
    out = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, state = M.decode_step(params, cfg, tok, state, enc_out=enc_out)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
