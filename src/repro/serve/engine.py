"""Serving: prefill + single-token decode steps, and the batched request
driver (:class:`SlotDriver` — continuous-batching-lite: fixed slots,
per-slot position/active flags).

The driver is deliberately generic: the step function owns the compute,
the driver owns slot bookkeeping and the masking contract that makes
mixed-traffic batching safe.  `repro.service.batcher` layers the
scalability-advisor probe batching on it (one vmapped characters call
for a slot group of concurrent requests); the LM serving loop is the
other natural consumer.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M


def mask_tree(active, new, old):
    """Per-slot select over a slots-batched pytree: where ``active[i]``,
    take ``new``'s slot ``i``, else keep ``old``'s — the masking primitive
    behind the driver's isolation guarantee.  ``active`` is ``(n_slots,)``
    bool; every leaf's leading axis is the slot axis."""
    def sel(n, o):
        a = active.reshape((active.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)
    return jax.tree.map(sel, new, old)


def _default_writer(state, slot: int, payload):
    """Write a payload pytree (one slot's worth, no slot axis) into slot
    ``slot`` of the slots-batched state.  Leaves missing from the payload
    keep their current slot contents."""
    def put(leaf, p):
        return leaf if p is None else leaf.at[slot].set(p)
    if not isinstance(payload, dict) or not isinstance(state, dict):
        return jax.tree.map(lambda l, p: l.at[slot].set(p), state, payload)
    return {k: (put(v, payload.get(k)) if k in payload else v)
            if not isinstance(v, dict)
            else _default_writer(v, slot, payload.get(k, {}))
            for k, v in state.items()}


class SlotDriver:
    """Continuous-batching-lite request driver: ``n_slots`` fixed slots,
    per-slot active flags and positions, masked step application.

    ``step_fn(state, active) -> (new_state, done)`` computes one step for
    every slot at once (``state`` is a pytree whose leaves all carry the
    slot axis first; ``active``/``done`` are ``(n_slots,)`` bool).  The
    driver jits a wrapper that re-selects the OLD state wherever a slot is
    inactive and zeroes ``done`` there, so:

      * an inactive slot's state is bit-frozen between requests (slot
        recycling can never leak a neighbor's stale compute), and
      * a request's output stream is a pure function of its own slot —
        neighbors joining, stepping, or finishing mid-flight cannot
        perturb it (pinned in tests/test_serve.py).

    One jitted dispatch per :meth:`step` regardless of how many requests
    are in flight — the continuous-batching idiom `repro.service` builds
    its probe batcher on.
    """

    def __init__(self, step_fn: Callable, init_state, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots={n_slots} must be >= 1")
        lead = {int(x.shape[0]) for x in jax.tree.leaves(init_state)}
        if lead and lead != {n_slots}:
            raise ValueError(f"every state leaf needs leading slot axis "
                             f"{n_slots}, got {sorted(lead)}")
        self.n_slots = int(n_slots)
        self._state = init_state
        self._active = np.zeros(self.n_slots, dtype=bool)
        self._positions = np.zeros(self.n_slots, dtype=np.int64)
        self._requests: List[Optional[Any]] = [None] * self.n_slots

        def wrapped(state, active):
            new_state, done = step_fn(state, active)
            return (mask_tree(active, new_state, state),
                    jnp.logical_and(done, active))

        self._step = jax.jit(wrapped)

    # -- bookkeeping views --------------------------------------------------
    @property
    def active(self) -> np.ndarray:
        return self._active.copy()

    @property
    def positions(self) -> np.ndarray:
        return self._positions.copy()

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    @property
    def state(self):
        return self._state

    # -- admission ----------------------------------------------------------
    def admit(self, request_id, payload,
              writer: Optional[Callable] = None) -> Optional[int]:
        """Place a request into a free slot; returns the slot index, or
        None when every slot is busy (the caller queues or sheds — the
        driver itself never blocks).  ``writer(state, slot, payload)``
        customizes how the payload lands in the state (default: per-leaf
        ``.at[slot].set``)."""
        free = np.flatnonzero(~self._active)
        if free.size == 0:
            return None
        slot = int(free[0])
        self._state = (writer or _default_writer)(self._state, slot, payload)
        self._active[slot] = True
        self._positions[slot] = 0
        self._requests[slot] = request_id
        return slot

    # -- stepping -----------------------------------------------------------
    def step(self) -> List[Tuple[Any, Dict]]:
        """Advance every active slot one step (one jitted dispatch).
        Returns ``[(request_id, slot_state_slice), ...]`` for requests
        that finished this step; their slots are freed for recycling."""
        if not self._active.any():
            return []
        active = jnp.asarray(self._active)
        self._state, done = self._step(self._state, active)
        done_host = np.asarray(jax.device_get(done))
        self._positions[self._active] += 1
        finished = []
        for slot in np.flatnonzero(done_host):
            slot = int(slot)
            out = jax.device_get(
                jax.tree.map(lambda x: x[slot], self._state))
            finished.append((self._requests[slot], out))
            self._active[slot] = False
            self._requests[slot] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> List:
        """Step until every slot drains (admissions between steps are the
        caller's loop); convenience for one-shot batch usage."""
        outs: List = []
        for _ in range(max_steps):
            if not self._active.any():
                return outs
            outs.extend(self.step())
        raise RuntimeError(f"slots still active after {max_steps} steps")


def make_prefill_step(cfg: ArchConfig, attention_impl="reference",
                      constrain=None):
    def prefill(params, batch):
        logits, _ = M.forward(params, cfg, batch,
                              attention_impl=attention_impl,
                              constrain=constrain)
        return logits[:, -1, :]          # next-token logits
    return prefill


def make_serve_step(cfg: ArchConfig, constrain=None):
    """serve_step: ONE new token against a KV cache of the shape's seq_len."""
    def serve(params, state, tokens):
        enc_out = state.get("enc_out")
        logits, new_state = M.decode_step(params, cfg, tokens, state["decode"],
                                          enc_out=enc_out,
                                          constrain=constrain)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = {"decode": new_state}
        if enc_out is not None:
            out["enc_out"] = enc_out
        return next_tok, out
    return serve


def init_serve_state(cfg: ArchConfig, batch, max_len, dtype=None,
                     with_encoder=False):
    state = {"decode": M.init_decode_state(cfg, batch, max_len, dtype)}
    if with_encoder or cfg.encoder_layers:
        d = jnp.dtype(dtype or cfg.dtype)
        state["enc_out"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), d)
    return state


def greedy_generate(params, cfg: ArchConfig, prompt_tokens, steps,
                    max_len=None, enc_out=None):
    """Simple generate loop used by examples/tests (CPU-scale)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + steps + 8)
    state = M.init_decode_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
    tok = None
    for t in range(S):
        logits, state = M.decode_step(params, cfg, prompt_tokens[:, t:t + 1],
                                      state, enc_out=enc_out)
    out = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, state = M.decode_step(params, cfg, tok, state, enc_out=enc_out)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
