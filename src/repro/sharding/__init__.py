from repro.sharding.rules import (param_specs, batch_specs,
                                  decode_state_specs, opt_state_specs,
                                  act_constraint, decode_act_constraint,
                                  head_constraint, inner_act_constraint,
                                  layer_constraint, logits_constraint,
                                  FSDP_AXES, data_axes)
