"""repro.sharding — mesh partitioning rules for the model stack: named
PartitionSpecs for params, batches, optimizer and decode state, plus
activation-sharding constraints (FSDP + tensor-parallel axes).  Consumed
by `repro.train.steps` and the `repro.launch` mesh/dryrun tooling; the
paper-side worker-count sweeps in `repro.experiments` simulate parallelism
in-process instead and don't shard.
"""

from repro.sharding.rules import (param_specs, batch_specs,
                                  decode_state_specs, opt_state_specs,
                                  act_constraint, decode_act_constraint,
                                  head_constraint, inner_act_constraint,
                                  layer_constraint, logits_constraint,
                                  FSDP_AXES, data_axes)
