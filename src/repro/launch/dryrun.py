"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh(es); print memory/cost analysis and collective schedule.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
fails loudly here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --json dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import (ARCH_IDS, get_arch, pair_supported)
from repro.launch import hlo_stats
from repro.launch import specs as S
from repro.distributed import (batch_specs, data_axes, decode_state_specs,
                               make_production_mesh, param_specs)

# v5e hardware constants for the roofline terms (EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def arch_for_pair(arch_id, shape_name):
    if arch_id == "qwen2.5-3b" and shape_name == "long_500k":
        from repro.configs.qwen2_5_3b import SLIDING_VARIANT
        return SLIDING_VARIANT
    return get_arch(arch_id)


def lower_pair(arch_id, shape_name, mesh, *, strategy="sync", seq_shard=True,
               donate=True, microbatches=4):
    """Returns (lowered, meta) for the right step kind for this shape."""
    cfg = arch_for_pair(arch_id, shape_name)
    shape = INPUT_SHAPES[shape_name]

    if shape.mode == "train":
        import jax.numpy as _jnp
        from repro.train.steps import make_train_step, train_state_specs
        step = make_train_step(cfg, mesh, strategy=strategy, remat=True,
                               seq_shard=seq_shard, microbatches=microbatches,
                               grad_accum_dtype=getattr(
                                   _jnp, os.environ.get(
                                       "REPRO_GRAD_ACCUM_DTYPE", "float32")),
                               accum_mode=os.environ.get(
                                   "REPRO_ACCUM_MODE", "explicit"))
        state_shapes = S.train_state_shapes(cfg, strategy)
        st_specs = train_state_specs(state_shapes, mesh)
        b_specs = batch_specs(S.input_specs(cfg, shape), mesh)
        jf = jax.jit(step,
                     in_shardings=(_ns(mesh, st_specs), _ns(mesh, b_specs)),
                     out_shardings=(_ns(mesh, st_specs), None),
                     donate_argnums=(0,) if donate else ())
        lowered = jf.lower(state_shapes, S.input_specs(cfg, shape))
    elif shape.mode == "prefill":
        from repro.serve.engine import make_prefill_step
        from repro.distributed import act_constraint
        step = make_prefill_step(
            cfg, constrain=act_constraint(mesh, seq_shard=seq_shard))
        p_shapes = S.param_shapes(cfg)
        p_specs = param_specs(p_shapes, mesh)
        b_specs = batch_specs(S.input_specs(cfg, shape), mesh)
        jf = jax.jit(step,
                     in_shardings=(_ns(mesh, p_specs), _ns(mesh, b_specs)))
        lowered = jf.lower(p_shapes, S.input_specs(cfg, shape))
    else:  # decode
        from repro.serve.engine import make_serve_step
        from repro.distributed import decode_act_constraint
        c_dec = (decode_act_constraint(mesh)
                 if os.environ.get("REPRO_DECODE_REPL", "1") == "1" else None)
        step = make_serve_step(cfg, constrain=c_dec)
        p_shapes = S.param_shapes(cfg)
        p_specs = param_specs(p_shapes, mesh)
        st_shapes = S.serve_state_shapes(cfg, shape)
        shardable = shape.global_batch >= mesh.shape.get("data", 1)
        st_specs = {"decode": decode_state_specs(
            st_shapes["decode"], mesh, shardable_batch=shardable)}
        if "enc_out" in st_shapes:
            fd = data_axes(mesh)
            st_specs["enc_out"] = P(fd if shardable else None, None, None)
        tok_spec = batch_specs(S.decode_token_specs(cfg, shape), mesh,
                               shardable_batch=shardable)
        jf = jax.jit(step,
                     in_shardings=(_ns(mesh, p_specs), _ns(mesh, st_specs),
                                   _ns(mesh, tok_spec)),
                     donate_argnums=(1,) if donate else ())
        lowered = jf.lower(p_shapes, st_shapes,
                           S.decode_token_specs(cfg, shape))
    return lowered, {"cfg": cfg, "shape": shape}


def analyze(lowered, mesh, verbose=True):
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    n_chips = mesh.devices.size

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = hlo_stats.collective_stats_trips(hlo)   # while-loop trip-aware
    coll_bytes = sum(v["bytes"] for v in coll.values())

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    result = {
        "chips": int(n_chips),
        "compile_s": round(compile_s, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": float(coll_bytes),
        "collectives": {k: {"count": int(v["count"]),
                            "bytes": float(v["bytes"])}
                        for k, v in coll.items()},
        "compute_term_s": flops_dev / PEAK_FLOPS,
        "memory_term_s": bytes_dev / HBM_BW,
        "collective_term_s": coll_bytes / ICI_BW,
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            result[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    terms = {"compute": result["compute_term_s"],
             "memory": result["memory_term_s"],
             "collective": result["collective_term_s"]}
    result["dominant_term"] = max(terms, key=terms.get)
    if verbose:
        print(f"  compiled in {compile_s:.1f}s on {n_chips} chips")
        print(f"  per-device: flops={flops_dev:.3e} bytes={bytes_dev:.3e} "
              f"collective_bytes={coll_bytes:.3e}")
        print(f"  roofline terms (s): compute={terms['compute']:.4f} "
              f"memory={terms['memory']:.4f} "
              f"collective={terms['collective']:.4f} "
              f"-> dominant: {result['dominant_term']}")
        arg = result.get("argument_size_in_bytes", 0)
        tmp = result.get("temp_size_in_bytes", 0)
        print(f"  memory: args={arg/1e9:.2f}GB temp={tmp/1e9:.2f}GB")
        if coll:
            sched = ", ".join(f"{k} x{v['count']} ({v['bytes']/1e6:.1f}MB)"
                              for k, v in sorted(coll.items()))
            print(f"  collective schedule: {sched}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2-pod (512 chip) mesh")
    ap.add_argument("--strategy", default="sync", choices=["sync", "stale"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--json", help="write results to this path")
    args = ap.parse_args()

    pairs = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES])
    meshes = [("1pod_16x16", make_production_mesh(multi_pod=False))]
    if args.multi_pod:
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    results = {}
    n_fail = 0
    for mesh_name, mesh in meshes:
        for arch_id, shape_name in pairs:
            key = f"{arch_id}|{shape_name}|{mesh_name}"
            ok, reason = pair_supported(arch_id, shape_name)
            if not ok:
                print(f"[SKIP] {key}: {reason}")
                results[key] = {"status": "skipped", "reason": reason}
                continue
            print(f"[RUN ] {key} (strategy={args.strategy})")
            try:
                lowered, meta = lower_pair(
                    arch_id, shape_name, mesh, strategy=args.strategy,
                    seq_shard=not args.no_seq_shard,
                    microbatches=args.microbatches)
                res = analyze(lowered, mesh)
                res["status"] = "ok"
                results[key] = res
            except Exception as e:
                n_fail += 1
                traceback.print_exc()
                results[key] = {"status": "fail",
                                "error": f"{type(e).__name__}: {e}"}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.json}")
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    print(f"== dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed ==")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
