"""Serving launcher: batched greedy decoding against a KV cache (reduced
configs execute on CPU; full configs belong to dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --requests 4 \
      --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_arch
from repro.models import model as M
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.requests, args.prompt_len),
                                 0, cfg.vocab_size)
    enc = None
    if cfg.encoder_layers:
        frames = 0.1 * jax.random.normal(
            key, (args.requests, cfg.encoder_seq, cfg.d_model))
        enc = M.encode(params["encoder"], cfg, frames)
    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, args.gen, enc_out=enc)
    dt = time.time() - t0
    total = args.requests * args.gen
    print(f"arch={cfg.name} generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, batch={args.requests})")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
