"""ShapeDtypeStruct stand-ins for every model input / state — the dry-run
contract: weak-type-correct, shardable, zero device allocation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import model as M


def input_specs(cfg: ArchConfig, shape: InputShape):
    """Inputs for train/prefill.  Modality frontends are the stated stub:
    audio provides frame embeddings, VLM provides patch embeddings."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    dtype = jnp.dtype(cfg.dtype)
    batch = {"tokens": sd((B, S), jnp.int32)}
    if shape.mode == "train":
        batch["labels"] = sd((B, S), jnp.int32)
    if cfg.vision_tokens:
        batch["vision_embeds"] = sd((B, cfg.vision_tokens, cfg.d_model), dtype)
        batch["positions"] = sd((3, B, S), jnp.int32)     # M-RoPE t/h/w
    if cfg.encoder_layers:
        batch["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), dtype)
    return batch


def param_shapes(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def train_state_shapes(cfg: ArchConfig, strategy="sync"):
    from repro.train.steps import init_train_state
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg,
                                 strategy=strategy))


def decode_state_shapes(cfg: ArchConfig, shape: InputShape):
    return jax.eval_shape(
        lambda: M.init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                    jnp.dtype(cfg.dtype)))


def serve_state_shapes(cfg: ArchConfig, shape: InputShape):
    from repro.serve.engine import init_serve_state
    return jax.eval_shape(
        lambda: init_serve_state(cfg, shape.global_batch, shape.seq_len,
                                 jnp.dtype(cfg.dtype)))


def decode_token_specs(cfg: ArchConfig, shape: InputShape):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
