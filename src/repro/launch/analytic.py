"""Analytic FLOP/byte model per (arch x shape) — the roofline's numerator.

Why analytic: XLA:CPU's ``cost_analysis`` counts each while-loop body ONCE
(scan trip counts are not multiplied in), so HLO flops under-count layer-
scanned models by ~num_layers.  EXPERIMENTS.md reports both numbers; the
roofline terms use the analytic MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE
+ exact attention terms), and the HLO numbers calibrate the per-iteration
constant.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import model as M


def param_counts(cfg: ArchConfig):
    """(total_params, active_params) — active excludes non-routed experts."""
    shapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    total = int(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        # per MoE layer: routed expert params not in the top_k are inactive
        expert_params = 3 * cfg.d_model * m.expert_d_ff
        n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
        inactive = n_moe_layers * (m.num_experts - m.top_k) * expert_params
        active = total - inactive
    return total, active


def _attn_layers(cfg: ArchConfig):
    full, windowed = 0, 0
    for i, spec in enumerate(M.layer_plan(cfg)):
        if spec.kind in ("attn", "mla", "shared_attn"):
            if spec.window:
                windowed += 1
            else:
                full += 1
    return full, windowed


def model_flops(cfg: ArchConfig, shape: InputShape):
    """Returns dict with matmul + attention FLOPs for the shape's mode."""
    B, S = shape.global_batch, shape.seq_len
    total, active = param_counts(cfg)
    full_l, win_l = _attn_layers(cfg)
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    w = cfg.sliding_window or 0

    if shape.mode == "train":
        tokens = B * S
        mat = 6 * active * tokens
        # causal attention: 2 matmuls * (S^2/2) * H * hd, fwd+bwd = x3
        attn = full_l * 3 * 2 * 2 * B * (S * S / 2) * H * hd
        attn += win_l * 3 * 2 * 2 * B * S * min(w, S) * H * hd
    elif shape.mode == "prefill":
        tokens = B * S
        mat = 2 * active * tokens
        attn = full_l * 2 * 2 * B * (S * S / 2) * H * hd
        attn += win_l * 2 * 2 * B * S * min(w, S) * H * hd
    else:  # decode: ONE token against a cache of S
        tokens = B
        mat = 2 * active * tokens
        attn = full_l * 2 * 2 * B * S * H * hd
        attn += win_l * 2 * 2 * B * min(w, S) * H * hd

    return {"params_total": total, "params_active": active,
            "matmul_flops": float(mat), "attention_flops": float(attn),
            "model_flops": float(mat + attn), "tokens": tokens}


def model_bytes(cfg: ArchConfig, shape: InputShape, *, opt_bytes=8,
                param_bytes=2):
    """Minimum HBM traffic per step: params read (+opt state r/w for train)
    + KV cache traffic for decode."""
    total, active = param_counts(cfg)
    if shape.mode == "train":
        # fwd+bwd params read twice + grad write + opt m/v read+write
        b = total * (2 * param_bytes + param_bytes + 2 * opt_bytes)
    elif shape.mode == "prefill":
        b = total * param_bytes
    else:
        b = active * param_bytes
        # KV cache read per decode step
        kv = 0
        for spec in M.layer_plan(cfg):
            if spec.kind == "attn" or spec.kind == "shared_attn":
                T = min(spec.window or shape.seq_len, shape.seq_len)
                kv += (2 * shape.global_batch * T * cfg.num_kv_heads
                       * cfg.resolved_head_dim * param_bytes)
            elif spec.kind == "mla":
                kv += (shape.global_batch * shape.seq_len *
                       (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
                       * param_bytes)
        b += kv
    return float(b)
