"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked on first jax init, and smoke tests
must see 1 CPU device, not 512).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data=2, model=2, pod=0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
