"""repro.launch — executable entry points for the model stack.  `train`
runs real (CPU-scale, reduced-config) optimization; `serve` runs batched
greedy decoding; `dryrun` lowers/compiles every (arch x shape) on the
production mesh without executing (the 512-virtual-device coherence
proof); `specs`, `hlo_stats` and `analytic` are its supporting
shape/cost tooling (mesh builders live in `repro.distributed.mesh`).
The paper-experiment entry point is separate:
``python -m repro.experiments.run`` (``--devices N`` shards it over a
device mesh via `repro.distributed`).
"""
