"""repro.launch — executable entry points for the model stack.  `train`
runs real (CPU-scale, reduced-config) optimization; `serve` runs batched
greedy decoding; `dryrun` lowers/compiles every (arch x shape) on the
production mesh without executing (the 512-virtual-device coherence
proof); `mesh`, `specs`, `hlo_stats` and `analytic` are its supporting
mesh/shape/cost tooling.  The paper-experiment entry point is separate:
``python -m repro.experiments.run``.
"""
