"""Training launcher.

CPU-scale real runs (reduced configs, actual optimization):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
      --steps 50 --strategy sync

Production lowering (full config, mesh, no execution) is dryrun.py's job —
this launcher EXECUTES.  On the CPU container it therefore defaults to the
reduced configs; passing --full without a TPU will be slow/OOM and warns.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_arch
from repro.core.advisor import ScalabilityAdvisor
from repro.data.lm import LMConfig, hmm_stream, token_characters
from repro.models import model as M
from repro.optim import adamw_init, adamw_update
from repro.train.checkpoint import save_checkpoint


def train_loop(cfg, *, steps=50, batch_size=8, seq_len=64, lr=1e-3,
               strategy="sync", log_every=10, ckpt=None, advisor_every=0,
               lb_coef=0.01, key=None):
    key = key or jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    opt = adamw_init(params)
    prev_grads = (jax.tree.map(jnp.zeros_like, params)
                  if strategy == "stale" else None)

    def loss_fn(p, batch):
        return M.loss_fn(p, cfg, batch, lb_coef=lb_coef)

    @jax.jit
    def sync_step(p, opt, batch):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        p, opt = adamw_update(p, g, opt, lr=lr)
        return p, opt, l, g

    @jax.jit
    def stale_step(p, opt, prev_g, batch):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        p, opt = adamw_update(p, prev_g, opt, lr=lr)
        return p, opt, l, g

    lm = LMConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                  batch_size=batch_size)
    stream = hmm_stream(key, lm, steps)
    adv = ScalabilityAdvisor()
    history = []
    t0 = time.time()
    for step, batch in enumerate(stream):
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (batch_size, cfg.vision_tokens, cfg.d_model), cfg.dtype)
        if cfg.encoder_layers:
            batch["frames"] = jnp.zeros(
                (batch_size, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        if strategy == "stale":
            params, opt, l, g = stale_step(params, opt, prev_grads, batch)
            prev_grads = g
        else:
            params, opt, l, g = sync_step(params, opt, batch)
        history.append(float(l))
        if step % log_every == 0:
            msg = f"step {step:4d} loss {float(l):.4f}"
            if advisor_every and step and step % advisor_every == 0:
                # split the batch in two shards and probe gradient characters
                half = batch_size // 2
                b1 = {k: v[:half] if v.shape[0] != 3 else v[:, :half]
                      for k, v in batch.items()}
                b2 = {k: v[half:] if v.shape[0] != 3 else v[:, half:]
                      for k, v in batch.items()}
                g1 = jax.grad(lambda p: loss_fn(p, b1)[0])(params)
                g2 = jax.grad(lambda p: loss_fn(p, b2)[0])(params)
                rep = adv.from_grads([g1, g2])
                msg += (f" | advisor: noise={rep['grad_noise_scale']:.3f} "
                        f"m_max_sync~{rep['predicted_m_max_sync']}")
            ch = token_characters(batch["tokens"])
            msg += f" | div={ch['sequence_diversity']:.2f}"
            print(msg)
    dt = time.time() - t0
    print(f"trained {steps} steps in {dt:.1f}s "
          f"({steps / dt:.2f} it/s), loss {history[0]:.3f} -> {history[-1]:.3f}")
    if ckpt:
        save_checkpoint(ckpt, {"params": params}, step=steps)
        print(f"checkpoint -> {ckpt}")
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--strategy", default="sync", choices=["sync", "stale"])
    ap.add_argument("--ckpt")
    ap.add_argument("--advisor-every", type=int, default=0)
    ap.add_argument("--json")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    elif jax.default_backend() != "tpu":
        print("WARNING: --full on a non-TPU backend will be slow/OOM")
    _, history = train_loop(cfg, steps=args.steps, batch_size=args.batch_size,
                            seq_len=args.seq_len, lr=args.lr,
                            strategy=args.strategy, ckpt=args.ckpt,
                            advisor_every=args.advisor_every)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"arch": args.arch, "history": history}, f)


if __name__ == "__main__":
    main()
