"""Parse collective traffic + op stats out of (S)HLO text.

``collective_bytes`` sums the *result* shape bytes of every collective op in
the post-SPMD module — a per-device link-traffic proxy (ring all-gather moves
~result bytes per device; all-reduce ~2x operand bytes; we report the raw sum
per op kind so the roofline can weight them).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape token like bf16[256,4096,8192]
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {"count": n, "bytes": total_result_bytes}}."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s*(\(?[a-z0-9]+\[.*?\)?)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":        # avoid double counting async pairs
            continue
        shapes_txt, kind = m.group(1), m.group(2)
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes_txt))
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += total
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in collective_stats(hlo_text).values()))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\s{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# Trip-count-aware accounting: collectives inside while-loop bodies (scans
# over layers / microbatches / CE chunks) execute trip_count times, but the
# HLO text lists them once.  We reconstruct multipliers from the loop
# structure: computation blocks, while ops (condition/body refs), and the
# loop bound constant in each condition computation.
# ---------------------------------------------------------------------------

def _normalize(hlo_text):
    """Join multi-line op statements so per-line regexes see whole ops."""
    out = []
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        st = line.strip()
        is_stmt = st.startswith("%") or st.startswith("ROOT") or \
            st.startswith("ENTRY") or st == "}" or st.endswith("{")
        if is_stmt or not out:
            out.append(line)
        else:
            out[-1] += " " + st
    return "\n".join(out)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(\([^)]*\))?\s*"
                       r"(\([^)]*\)\s*)?->.*{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\([^)]*\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(
    r"\bwhile\([^)]*\)[^\n]*?body=%?([\w.\-]+)[^\n]*?condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"\b(?:call|conditional|async-start)\([^\n]*?"
                      r"(?:to_apply|called_computation)=%?([\w.\-]+)")


def _split_computations(hlo_text):
    """Returns {comp_name: body_text} and the entry computation name."""
    text = _normalize(hlo_text)
    comps, entry = {}, None
    cur, buf = None, []
    for line in text.splitlines():
        st = line.strip()
        if ("->" in st and st.endswith("{")
                and (st.startswith("%") or st.startswith("ENTRY"))):
            if cur is not None:
                comps[cur] = "\n".join(buf)
            name = st.split()[1] if st.startswith("ENTRY") else st.split()[0]
            cur = name.lstrip("%").split("(")[0].rstrip()
            buf = [line]
            if st.startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps, entry


def _lookup(comps, name):
    if name in comps:
        return comps[name]
    for k in comps:                      # fuzzy: clone/suffix variants
        if k.startswith(name) or name.startswith(k):
            return comps[k]
    return ""


def _trip_count(cond_text):
    consts = [int(x) for x in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def _whiles_in(text):
    pairs = [(c, b) for c, b in _WHILE_RE.findall(text)]
    pairs += [(c, b) for b, c in _WHILE_RE2.findall(text)
              if (c, b) not in pairs]
    return pairs


def collective_stats_trips(hlo_text):
    """{op_kind: {count, bytes}} with while-loop trip multipliers applied."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return collective_stats(hlo_text)
    import sys
    sys.setrecursionlimit(10000)

    def stats_of(comp_name, mult, acc, seen, via_lookup=True):
        text = _lookup(comps, comp_name) if via_lookup else comp_name
        local = collective_stats(text)
        for k, v in local.items():
            acc[k]["count"] += v["count"] * mult
            acc[k]["bytes"] += v["bytes"] * mult
        for cond, body in _whiles_in(text):
            tc = _trip_count(_lookup(comps, cond))
            if body not in seen:
                stats_of(body, mult * tc, acc, seen | {body})
        for callee in _CALL_RE.findall(text):
            if callee not in seen:
                stats_of(callee, mult, acc, seen | {callee})
        return acc

    acc = defaultdict(lambda: {"count": 0, "bytes": 0})
    stats_of(entry, 1, acc, frozenset())
    return dict(acc)


def total_collective_bytes_trips(hlo_text):
    return int(sum(v["bytes"]
                   for v in collective_stats_trips(hlo_text).values()))
