"""Device meshes for the sweep engine and the model stack.

Two families live here:

**Sweep mesh** (:class:`DeviceMesh`, :func:`get_mesh`) — the 1-D
``('shard',)`` mesh `repro.distributed.partition` shards the engine's
batched (m-grid x seed) simulations over.  It is auto-detected from
``jax.devices()`` (``devices="auto"``), overridable to any prefix of the
device list (``devices=4``), and degrades to an explicit *single-device
fallback* (``n_devices == 1``) in which the engine takes today's
unsharded code path bit-exactly.  The mesh is an **execution resource,
never part of result identity**: spec fingerprints exclude it (see
`repro.experiments.spec.EXECUTION_ONLY_FIELDS`) and the invariance
contract (docs/distributed.md) pins results across mesh sizes at 1e-5.

**Model-stack meshes** (:func:`make_production_mesh`,
:func:`make_debug_mesh`) — the named ('pod','data','model') meshes the
`repro.train` / `repro.launch` stack lays FSDP/TP shardings over
(absorbed from the former ``repro.launch.mesh``).  These are FUNCTIONS,
not module-level constants: importing this module never touches jax
device state (device count is locked on first jax init, and smoke tests
must see 1 CPU device, not 512).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: the sweep mesh's single axis name (the batched grid-element axis)
SHARD_AXIS = "shard"


@dataclasses.dataclass(frozen=True)
class DeviceMesh:
    """A 1-D mesh over the engine's batched grid-element axis.

    Thin, picklable-ish wrapper around ``jax.sharding.Mesh((n,),
    ('shard',))`` carrying the derived shardings the partitioner needs.
    ``n_devices == 1`` is the *fallback signal*: the engine bypasses the
    partitioner entirely and runs the exact unsharded path.
    """

    mesh: Mesh

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    @property
    def devices(self):
        return tuple(self.mesh.devices.flat)

    def sharding(self) -> NamedSharding:
        """Leading-axis sharding for a batched array of grid elements."""
        return NamedSharding(self.mesh, P(SHARD_AXIS))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def describe(self) -> str:
        """One-line ``--list``-style report (printed at CLI startup)."""
        devs = self.devices
        kinds = sorted({d.platform for d in devs})
        ids = ", ".join(str(d.id) for d in devs[:8])
        if len(devs) > 8:
            ids += ", ..."
        mode = ("single-device fallback (unsharded engine path)"
                if self.n_devices == 1 else
                f"sharding grid elements over axis {SHARD_AXIS!r}")
        return (f"mesh: {self.n_devices} x {'/'.join(kinds)} device"
                f"{'s' if self.n_devices != 1 else ''} [{ids}] — {mode}")


MeshLike = Union[None, str, int, DeviceMesh]


#: one-shot flag for the over-subscription clamp warning — a sweep over
#: many specs should say it once, not once per job (tests reset it)
_CLAMP_WARNED = False


def get_mesh(devices: MeshLike = None) -> DeviceMesh:
    """Resolve a sweep mesh from a ``--devices``-style request.

    ``None`` / ``"auto"`` take every available XLA device; an int takes
    the first ``devices`` of them (so 1 forces the single-device
    fallback on any host); a :class:`DeviceMesh` passes through.
    Requesting more devices than exist **clamps to what the host has**
    with a one-shot warning (graceful degradation: results are
    mesh-invariant, so a spec tuned for an 8-device host still runs —
    just slower — on a laptop); on a CPU container the full request can
    be honored via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    *before* the first jax import.
    """
    global _CLAMP_WARNED
    if isinstance(devices, DeviceMesh):
        return devices
    avail = jax.devices()
    if devices is None or devices == "auto":
        n = len(avail)
    else:
        n = int(devices)
        if n < 1:
            raise ValueError(f"devices={devices!r} must be >= 1")
        if n > len(avail):
            if not _CLAMP_WARNED:
                warnings.warn(
                    f"devices={n} requested but only {len(avail)} XLA "
                    f"device{'s' if len(avail) != 1 else ''} available — "
                    f"clamping to {len(avail)} (results are mesh-invariant; "
                    f"on CPU set XLA_FLAGS=--xla_force_host_platform_"
                    f"device_count={n} before the first jax import to honor "
                    f"the request)", RuntimeWarning, stacklevel=2)
                _CLAMP_WARNED = True
            n = len(avail)
    return from_devices(avail[:n])


def from_devices(devs: Sequence) -> DeviceMesh:
    """Build the 1-D sweep mesh over an explicit device list."""
    import numpy as np
    return DeviceMesh(Mesh(np.asarray(devs), (SHARD_AXIS,)))


def resolve(mesh: MeshLike) -> Optional[DeviceMesh]:
    """Engine-side resolution: ``None`` means "no distribution requested"
    (not "auto") so every existing caller keeps the unsharded path."""
    if mesh is None:
        return None
    return get_mesh(mesh)


# ---------------------------------------------------------------------------
# Model-stack meshes (absorbed from the former repro.launch.mesh)
# ---------------------------------------------------------------------------

def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data=2, model=2, pod=0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
