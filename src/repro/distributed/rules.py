"""Logical sharding rules -> jax.sharding.PartitionSpec (model stack).

These are the *model-stack* partition rules — FSDP/TP layouts for the
production-flavored training/serving side (`repro.train.steps`,
`repro.launch.dryrun`), folded into `repro.distributed` from the former
``repro.sharding`` package.  The paper-side sweep engine shards
differently: its batched simulations go through
`repro.distributed.partition` over the 1-D sweep mesh.

Layout (DESIGN.md §5):
  * FSDP:  params / optimizer state sharded over ('pod','data') on the
    d_model-ish dim; gradients reduce over the same axes.
  * TP:    heads / ffn-hidden / experts sharded over 'model'.
  * batch: ('pod','data'); KV-cache sequence dim: 'model' (sequence-parallel
    decode attention); SSM heads: 'model'.

Rules are name-based over pytree paths.  Stacked segment params carry a
leading layer axis (never sharded).  GSPMD handles non-divisible dims by
padding, so rules don't need per-arch divisibility checks.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

FSDP_AXES = ("pod", "data")     # collapsed to just ('data',) on 1-pod meshes


def data_axes(mesh) -> Any:
    """The data-parallel (FSDP/batch) mesh axes present in `mesh`."""
    names = mesh.axis_names
    ax = tuple(a for a in FSDP_AXES if a in names)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


# name -> spec builder over the *unstacked* weight dims.
# FD = fsdp axes placeholder, substituted at call time.
_FD = "__FSDP__"

_RULES_2D = {
    # (in, out) projections: shard in-dim on FSDP, out-dim on model
    "wq": (_FD, "model"), "wk": (_FD, "model"), "wv": (_FD, "model"),
    "wi": (_FD, "model"), "wi_gate": (_FD, "model"), "wi_up": (_FD, "model"),
    "wq_a": (_FD, None), "wq_b": (_FD, "model"),
    "wkv_a": (_FD, None), "wk_rope": (_FD, None),
    "wk_b": (_FD, "model"), "wv_b": (_FD, "model"),
    "in_proj": (_FD, "model"), "w_if": (_FD, None), "wz": (_FD, "model"),
    "w_in": (_FD, "model"), "w_concat": (_FD, "model"),
    "router": (_FD, None),
    "lm_head": (_FD, "model"),
    # output projections: shard in-dim on model, out-dim on FSDP
    "wo": ("model", _FD), "out_proj": ("model", _FD), "down": ("model", _FD),
    # embeddings
    "table": ("model", _FD),
    "pos": (None, _FD),
    # depthwise conv (W, C): channels on model
    "conv_w": (None, "model"),
    # sLSTM recurrent mixer (H, hd, 4hd): small; replicate
    "r": (None, None, None),
}

# 3D MoE expert banks (E, d, ff)/(E, ff, d): experts on model, d on FSDP
_RULES_MOE = {
    "wi_gate": ("model", _FD, None),
    "wi_up": ("model", _FD, None),
    "wo": ("model", None, _FD),
}


def _axis_size(mesh_shape, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh_shape.get(a, 1)
        return n
    return mesh_shape.get(ax, 1)


def fit_spec(spec, shape, mesh):
    """jit in_shardings require divisibility; drop axes on dims that don't
    divide (internal with_sharding_constraint handles padding, the boundary
    does not)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, ax in enumerate(spec):
        if i >= len(shape):
            break
        size = _axis_size(mesh_shape, ax)
        out.append(ax if (size > 1 and shape[i] % size == 0) or size == 1
                   else None)
    return P(*out)


def _leaf_spec(path, leaf, fd):
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    name = keys[-1]
    stacked = "segments" in keys or "layers" in keys
    base_ndim = leaf.ndim - (1 if stacked else 0)
    in_moe = "moe" in keys

    if base_ndim <= 1:
        spec = (None,) * base_ndim
    elif in_moe and name in _RULES_MOE and base_ndim == 3:
        spec = _RULES_MOE[name]
    elif name in _RULES_2D and base_ndim == len(_RULES_2D[name]):
        spec = _RULES_2D[name]
    elif name in _RULES_2D and base_ndim == 2:
        spec = _RULES_2D[name][:2]
    else:
        spec = (None,) * base_ndim
    spec = tuple(fd if s == _FD else s for s in spec)
    if stacked:
        spec = (None,) + spec
    return P(*spec)


def param_specs(params_tree, mesh):
    fd = data_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fit_spec(_leaf_spec(p, x, fd), x.shape, mesh),
        params_tree)


def opt_state_specs(opt_state_tree, param_spec_tree):
    """Adam m/v mirror the param sharding; scalar counts replicate."""
    def f(spec, leaf_like):
        return spec
    # opt state = {"m": params-like, "v": params-like, "count": scalar}
    return {
        "m": param_spec_tree,
        "v": param_spec_tree,
        "count": P(),
    }


def batch_specs(batch_tree, mesh, *, shardable_batch=True):
    """Inputs: batch dim over data axes (when divisible), rest replicated."""
    fd = data_axes(mesh)

    def f(leaf):
        if leaf.ndim == 0:
            return P()
        if not shardable_batch:
            return P(*([None] * leaf.ndim))
        if leaf.ndim == 3 and leaf.shape[0] == 3:     # M-RoPE (3, B, S)
            spec = P(None, fd, *([None] * (leaf.ndim - 2)))
        else:
            spec = P(fd, *([None] * (leaf.ndim - 1)))
        return fit_spec(spec, leaf.shape, mesh)

    return jax.tree.map(f, batch_tree)


def decode_state_specs(state_tree, mesh, *, shardable_batch=True):
    """KV caches: (L, B, T, ...) -> batch on data axes, seq/heads on model.

    When the batch is not shardable (long_500k, B=1) the sequence dim is
    sharded over *both* data and model axes.
    """
    fd = data_axes(mesh)
    seq_ax = "model" if shardable_batch else (
        (fd + ("model",)) if isinstance(fd, tuple) else (fd, "model"))
    b_ax = fd if shardable_batch else None

    def f(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1]
        if name == "position" or leaf.ndim <= 1:
            return P()
        # all cache leaves carry leading (L, B, ...) dims
        if name in ("k", "v"):            # (L,B,T,KV,D)
            spec = P(None, b_ax, seq_ax, None, None)
        elif name == "pos":               # (L,B,T)
            spec = P(None, b_ax, seq_ax)
        elif name in ("c_kv", "k_rope"):  # (L,B,T,r)
            spec = P(None, b_ax, seq_ax, None)
        elif name == "conv":              # (L,B,W-1,C)
            spec = P(None, b_ax, None, "model")
        elif name == "ssm":               # (L,B,H,hd,N)
            spec = P(None, b_ax, "model", None, None)
        elif name == "C":                 # (L,B,H,hd,hd)
            spec = P(None, b_ax, "model", None, None)
        elif name in ("n", "c", "m", "h"):  # (L,B,H,hd)
            spec = P(None, b_ax, "model", None)
        elif name == "index":             # (L,)
            spec = P(None)
        else:
            spec = P(*([None] * leaf.ndim))
        return fit_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(f, state_tree)


def layer_constraint(mesh):
    """Constraint applied to the per-layer param slice inside the scan body.

    Paths inside the body lack the 'segments' prefix, so _leaf_spec sees the
    unstacked shapes.  Via the transpose rule this also pins the gradient
    cotangent -> per-layer reduce-scatter instead of a whole-stack all-reduce.
    """
    fd = data_axes(mesh)

    def constrain(tree):
        return jax.tree_util.tree_map_with_path(
            lambda p, x: jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(mesh, _leaf_spec(p, x, fd))),
            tree)

    return constrain


def logits_constraint(mesh):
    """CE chunk logits (B, c, V): vocab on 'model' — keeps the lm_head use
    and its gradient V-sharded instead of gathering a (d, V) f32 per device."""
    fd = data_axes(mesh)

    def constrain(logits):
        return jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(mesh, P(fd, None, "model")))

    return constrain


def head_constraint(mesh):
    """LM head weight inside the CE scan: vocab on 'model', d replicated —
    gathered once per step instead of once per chunk."""
    def constrain(w):
        v_first = w.shape[0] > w.shape[1]      # (V, d) tied vs (d, V) head
        spec = P("model", None) if v_first else P(None, "model")
        return jax.lax.with_sharding_constraint(
            w, jax.sharding.NamedSharding(mesh, spec))
    return constrain


def decode_act_constraint(mesh):
    """Decode-time h pin: d_model sharded over the data axes (batch
    replicated).  The (B,1,d) activation then CONTRACTS against the FSDP
    weight shard locally -> partial matmul + tiny psum, instead of
    re-gathering ~params/TP bytes of weights every decoded token (GSPMD's
    dot heuristic otherwise gathers the weight side; measured 18.6GB/step
    on qwen110b)."""
    fd = data_axes(mesh)

    def constrain(h):
        if h.ndim == 3 and h.shape[-1] % 2 == 0:
            return jax.lax.with_sharding_constraint(
                h, jax.sharding.NamedSharding(mesh, P(None, None, fd)))
        return h
    return constrain


def act_constraint(mesh, *, seq_shard=True):
    """Returns a callable h -> h applying the sequence-parallel activation
    sharding constraint (B on data, S on model)."""
    fd = data_axes(mesh)

    def constrain(h):
        if h.ndim == 3 and seq_shard and h.shape[1] > 1:
            return jax.lax.with_sharding_constraint(
                h, jax.sharding.NamedSharding(mesh, P(fd, "model", None)))
        return h

    return constrain


def inner_act_constraint(mesh, *, seq_shard=True, cfg=None):
    """Megatron-SP block-entry constraint: gather the sequence dim so the
    'model' axis is free for TP (heads / d_ff / experts) inside the block.

    Without this, seq-sharding and TP fight over 'model' and XLA resolves
    the conflict by all-gathering FULL weight matrices per device (observed:
    f32[8192,49152] per-device buffers on qwen110b).  With it, the block
    boundary becomes the classic SP pattern: all-gather(seq) on entry,
    reduce-scatter(seq) via the residual-stream constraint on exit.

    Heads-aware refinement (§Perf iteration 2): when the arch's head count
    does not divide the 'model' axis (gemma3: H=4 on TP=16), head-TP is
    impossible and gathering the sequence only feeds a full-batch f32
    re-gather inside attention (measured 537MB x layers x microbatches).
    In that case the attention input KEEPS its sequence sharding — the
    chunked reference attention then computes q-row-parallel attention
    against gathered (small, GQA) k/v.  The MLP side gathers only when
    d_ff divides the model axis.
    """
    fd = data_axes(mesh)
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    attn_gather = True
    mlp_gather = True
    if cfg is not None:
        attn_gather = cfg.num_heads % n_model == 0
        mlp_gather = (cfg.d_ff % n_model == 0) if cfg.d_ff else False

    def constrain(x, kind="attn"):
        if x.ndim != 3 or not seq_shard or x.shape[1] <= 1:
            return x
        if kind == "residual":
            # block OUTPUTS pinned REPLICATED over 'model': the wsc
            # transpose pins the cotangent to the same spec, so a gathered
            # output means a gathered output-cotangent — which is exactly
            # what the TP backward needs (dW = h_ff^T @ dy with ff@model,
            # dy replicated).  A seq-sharded pin instead re-creates the
            # model-axis conflict and XLA gathers full f32 weights in the
            # backward (measured 1.6GB x layers x microbatches, qwen110b).
            spec = P(fd, None, None)
        else:
            gather = attn_gather if kind == "attn" else mlp_gather
            spec = P(fd, None, None) if gather else P(fd, "model", None)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))

    return constrain
