"""True multi-device Hogwild!: worker shards racing on a shared parameter.

The engine's Hogwild! (`repro.core.algorithms.hogwild`) *emulates* the
lock-free race as a sequential staleness recurrence — gradient ``j`` is
computed against the model from iteration ``j - tau`` with ``tau``
cycling over ``[1, m]`` (Thm 1).  That recurrence is the **parity
oracle**: deterministic, single-device, and what every grid sweep and
cache artifact is defined by.

This module runs the race for real.  The ``m`` workers are split into
``D`` shards (one per mesh device) under ``jax.experimental.shard_map``;
each shard races ahead on its own copy of the parameter vector — its
local workers apply full-step SGD updates sequentially, *reading*
whatever their shard's copy currently holds — and every ``sync_every``
rounds the shards reconcile by **summing their deltas onto the shared
parameter** (``x <- x_base + psum(x_local - x_base)``), i.e. every
gradient lands with its full step exactly as Hogwild!'s writes do, but
cross-shard reads are stale by up to ``sync_every * m`` server
iterations.  The shared parameter buffer is donated
(``donate_argnums``), so the reconciled model overwrites the stale one
in place instead of allocating per sync.

When it matches the oracle and when it diverges (docs/distributed.md):
both apply every gradient at full step against a model that is at most
O(m) iterations stale, so at small ``gamma * m`` the curves track within
a loose tolerance (tested in tests/test_distributed.py).  They are NOT
bit-comparable: the oracle's lag is exactly ``tau = (j % m) + 1`` while
the race's lag depends on the shard layout — ``D = 1`` degenerates to
fresh sequential SGD (no staleness at all), and large ``sync_every * m``
or large ``gamma`` amplify the divergence the same way real Hogwild!
degrades past the paper's ``m_max``.
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.algorithms.lr import LAMBDA, lr_grad, test_logloss
from repro.distributed import mesh as mesh_mod
from repro.resilience import faults
from repro.telemetry import instrument, metrics, recorder

#: compile counter for the sharded racing mode — `scripts/bench_engine.py
#: dist_worker` snapshots it around the race timing (the engine's own
#: `JIT_CALLS` only counts grid-path compiles).  Registry-backed (PR 9);
#: the module-level ``JIT_CALLS`` read stays source-compatible via
#: ``__getattr__`` below.
_JIT_CALLS = metrics.counter(
    "repro_distributed_race_jit_compiles_total",
    help="racing-mode shard_map pipelines compiled")

#: host-side communication accounting for the racing mode: every psum
#: reconcile (scheduled sync rounds plus the forced per-eval sync) is one
#: cross-device collective round — the comm-cost axis ROADMAP item 3
#: models (wider sync_every trades staleness for fewer rounds)
_PSUM_ROUNDS = metrics.counter(
    "repro_distributed_psum_rounds_total",
    help="psum reconcile rounds executed by the racing mode")


def __getattr__(name):
    # PEP 562 read alias for the legacy module global (see engine.py)
    if name == "JIT_CALLS":
        return _JIT_CALLS.value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _build_race(X, y, Xte, yte, dmesh, *, w, gamma, lam, sync_every,
                fspec=None):
    """jitted ``(x0, samples, mask[, fstream]) -> losses`` racing pipeline.

    ``samples``: (n_evals, rounds_per_eval, D, w) sample indices, worker
    axis laid out over the mesh; ``mask``: (D, w) live-worker mask (0 for
    the padding workers that round ``m`` up to a multiple of ``D``).

    ``fspec`` (a resolved `repro.resilience.faults.FaultSpec`) switches to
    the faulted pipeline, which additionally takes ``fstream`` — the
    per-(round, worker) fault events, sharded exactly like ``samples``.
    A dropped update's gradient never enters its shard's local delta, so
    the next ``psum`` reconcile genuinely never sees it: the message is
    lost on the wire, not masked after the fact.  A straggle event makes
    the worker read its shard's *round-start* model (one round extra
    stale); corruption rewrites the gradient payload.  Zero-rate streams
    are bit-exact with the unfaulted pipeline.
    """
    axis = mesh_mod.SHARD_AXIS

    def shard_fn(x0, samples, mask):
        samples = samples[:, :, 0, :]            # local view: (E, R, w)
        mask = mask[0]                           # (w,)

        def worker_step(x_loc, inp):
            i, live = inp
            g = lr_grad(x_loc, X[i], y[i], lam)
            # the racing read: the gradient saw whatever this shard's
            # copy held; the write lands at full step (masked if padded)
            return x_loc - gamma * live * g, None

        def reconcile(args):
            # every shard's accumulated delta lands on the shared
            # parameter (sum, not mean — all writes count)
            x_base, x_loc = args
            x_sync = x_base + jax.lax.psum(x_loc - x_base, axis)
            return x_sync, x_sync

        def round_step(carry, s_round):
            x_base, x_loc, r = carry
            x_loc, _ = jax.lax.scan(worker_step, x_loc, (s_round, mask))
            # the round counter is replicated, so every shard takes the
            # same branch and non-sync rounds pay NO collective — wider
            # sync windows trade staleness for communication, which is
            # the whole tradeoff this mode exists to measure
            do = (r % sync_every) == (sync_every - 1)
            x_base, x_loc = jax.lax.cond(do, reconcile,
                                         lambda args: args,
                                         (x_base, x_loc))
            return (x_base, x_loc, r + 1), None

        def eval_block(carry, samples_e):
            carry, _ = jax.lax.scan(round_step, carry, samples_e)
            x_base, x_loc, r = carry
            # force a sync at the eval boundary: the evaluated model is
            # the shared parameter, identical on every shard
            x_sync, _ = reconcile((x_base, x_loc))
            return ((x_sync, x_sync, r),
                    test_logloss(x_sync, Xte, yte))

        carry0 = (x0, x0, jnp.zeros((), jnp.int32))
        (x, _, _), losses = jax.lax.scan(eval_block, carry0, samples)
        return x, losses

    def shard_fn_faulted(x0, samples, mask, fstream):
        samples = samples[:, :, 0, :]            # local view: (E, R, w)
        mask = mask[0]                           # (w,)
        fstream = {k: v[:, :, 0, :] for k, v in fstream.items()}

        def worker_step(carry, inp):
            x_loc, b = carry
            i, live, fd = inp
            # a straggler read its shard's round-start model — one round
            # of extra staleness on top of the race's own.  Both reads
            # are evaluated and the GRADIENT is selected: a select on the
            # model before `lr_grad` changes XLA's dot-reduction fusion
            # and costs ~1 ulp/step vs the unfaulted pipeline, while the
            # post-gradient select keeps zero-rate streams bit-exact.
            g = jnp.where(fd["straggle"] > 0,
                          lr_grad(b, X[i], y[i], lam),
                          lr_grad(x_loc, X[i], y[i], lam))
            g = faults.corrupt(fspec, g, fd["corrupt"])
            # drop: the update never enters the local delta, so the next
            # psum never sums it — a genuinely lost message; dup lands it
            # twice; zero-rate scale is a computed exact 1.0
            scale = faults.delivery_scale(fd)
            return (x_loc - gamma * live * scale * g, b), None

        def reconcile(args):
            x_base, x_loc = args
            x_sync = x_base + jax.lax.psum(x_loc - x_base, axis)
            return x_sync, x_sync

        def round_step(carry, inp):
            s_round, f_round = inp
            x_base, x_loc, r = carry
            (x_loc, _), _ = jax.lax.scan(
                worker_step, (x_loc, x_loc), (s_round, mask, f_round))
            do = (r % sync_every) == (sync_every - 1)
            x_base, x_loc = jax.lax.cond(do, reconcile,
                                         lambda args: args,
                                         (x_base, x_loc))
            return (x_base, x_loc, r + 1), None

        def eval_block(carry, inp):
            samples_e, fstream_e = inp
            carry, _ = jax.lax.scan(round_step, carry, (samples_e, fstream_e))
            x_base, x_loc, r = carry
            x_sync, _ = reconcile((x_base, x_loc))
            return ((x_sync, x_sync, r),
                    test_logloss(x_sync, Xte, yte))

        carry0 = (x0, x0, jnp.zeros((), jnp.int32))
        (x, _, _), losses = jax.lax.scan(eval_block, carry0,
                                         (samples, fstream))
        return x, losses

    if fspec is None:
        mapped = shard_map(
            shard_fn, mesh=dmesh.mesh,
            in_specs=(P(), P(None, None, mesh_mod.SHARD_AXIS, None),
                      P(mesh_mod.SHARD_AXIS, None)),
            out_specs=(P(), P()), check_rep=False)
    else:
        mapped = shard_map(
            shard_fn_faulted, mesh=dmesh.mesh,
            in_specs=(P(), P(None, None, mesh_mod.SHARD_AXIS, None),
                      P(mesh_mod.SHARD_AXIS, None),
                      P(None, None, mesh_mod.SHARD_AXIS, None)),
            out_specs=(P(), P()), check_rep=False)
    _JIT_CALLS.inc()
    return jax.jit(mapped, donate_argnums=(0,))


def run_hogwild_sharded(train, test, *, m: int = 8, iters: int = 4000,
                        gamma: float = 0.1, lam: float = LAMBDA,
                        eval_every: int = 100, key=None,
                        mesh: mesh_mod.MeshLike = None,
                        sync_every: int = 1,
                        fault: "faults.FaultLike" = None) -> Dict:
    """Race ``m`` workers over the mesh's devices; returns a curve dict.

    Server-iteration accounting matches the oracle: ``iters`` total
    gradient applications, a test-loss eval every ``eval_every`` of them
    (``eval_every`` must be a multiple of ``m`` so eval points land on
    round boundaries).  ``mesh`` resolves via `mesh.get_mesh` (auto =
    every device); workers pad up to a multiple of the device count with
    masked (inert) slots, so any ``m`` runs on any mesh.

    ``fault`` (FaultSpec / dict / None) injects per-(round, worker)
    delivery faults into the race — see :func:`_build_race`.  The event
    stream is drawn at the race's ``(E, R, D, w)`` layout from the fault
    seed; threefry draws depend only on the element count, so at
    ``m == D * w`` it is flat-identical to the sequential oracle's
    ``(iters,)`` stream — the engine's faulted Hogwild! with the same
    spec is the parity oracle at ``sync_every=1`` (for delivery faults;
    corruption parity additionally needs a gradient-linear corruption
    model like ``sign_flip``).
    """
    dmesh = mesh_mod.get_mesh(mesh)
    fspec = faults.resolve(fault)
    D = dmesh.n_devices
    if eval_every % m:
        raise ValueError(
            f"eval_every={eval_every} must be a multiple of m={m}: the "
            f"racing mode applies m gradients per round and evals on "
            f"round boundaries")
    key = key if key is not None else jax.random.PRNGKey(0)
    n = train.X.shape[0]
    w = -(-m // D)                       # workers per shard
    m_eff = w * D
    n_evals = iters // eval_every
    rounds_per_eval = eval_every // m
    # one sample per (round, worker slot); padded slots draw but never
    # apply, keeping live workers' streams independent of the mesh size
    samples = jax.random.randint(
        key, (n_evals, rounds_per_eval, D, w), 0, n)
    mask = (jnp.arange(m_eff) < m).astype(jnp.float32).reshape(D, w)

    race = _build_race(train.X, train.y, test.X, test.y, dmesh,
                       w=w, gamma=gamma, lam=lam, sync_every=sync_every,
                       fspec=fspec)
    x0 = jnp.zeros((train.X.shape[1],))
    if fspec is None:
        x, losses = instrument.dispatch(
            race, x0, samples, mask, span_name="race",
            m=m, devices=D, sync_every=sync_every)
    else:
        fstream = faults.make_stream(
            fspec, (n_evals, rounds_per_eval, D, w))
        x, losses = instrument.dispatch(
            race, x0, samples, mask, fstream, span_name="race",
            m=m, devices=D, sync_every=sync_every, faulted=True)
    # host-side mirror of the pipeline's sync schedule: the global round
    # counter r hits (r % sync_every == sync_every - 1) exactly
    # R_total // sync_every times over R_total rounds, and every eval
    # block forces one extra reconcile at its boundary
    r_total = n_evals * rounds_per_eval
    psum_rounds = r_total // sync_every + n_evals
    _PSUM_ROUNDS.inc(psum_rounds)
    recorder.publish("race", m=m, devices=D, sync_every=sync_every,
                     psum_rounds=psum_rounds,
                     faulted=fspec is not None)
    out = {
        "algorithm": "hogwild_sharded",
        "m": m,
        "devices": D,
        "sync_every": sync_every,
        "iters": n_evals * eval_every,
        "eval_every": eval_every,
        "losses": jax.device_get(losses),
        "x": x,
        "iters_per_worker": iters / m,
        "psum_rounds": psum_rounds,
    }
    if fspec is not None:
        out["fault"] = fspec.to_dict()
    return out


def sweep_hogwild_sharded(train, test, ms: Sequence[int], *, iters: int,
                          eval_every: int, gamma: float = 0.1,
                          lam: float = LAMBDA, key=None,
                          mesh: mesh_mod.MeshLike = None,
                          sync_every: int = 1,
                          fault: "faults.FaultLike" = None) -> Dict:
    """Racing-mode m-grid (Python loop per m — this mode parallelizes over
    *devices*, not grid members; the engine's vmapped grid with the
    staleness oracle remains the cached, mesh-invariant default).

    Each m's eval cadence is aligned DOWN to its nearest round boundary
    (``ev_m = m * (eval_every // m)``, at least one round) and its budget
    to ``(iters // eval_every) * ev_m`` — so any grid runs, every row has
    the same number of evals, and eval points sit within one round of
    the requested cadence.
    """
    dmesh = mesh_mod.get_mesh(mesh)
    n_evals = iters // eval_every
    curves = []
    for m in ms:
        ev = int(m) * max(1, eval_every // int(m))
        curves.append(run_hogwild_sharded(
            train, test, m=int(m), iters=n_evals * ev, eval_every=ev,
            gamma=gamma, lam=lam, key=key, mesh=dmesh,
            sync_every=sync_every, fault=fault)["losses"])
    return {
        "algorithm": "hogwild_sharded",
        "problem": "logistic",
        "ms": [int(m) for m in ms],
        "devices": dmesh.n_devices,
        "iters": int(iters),
        "eval_every": int(eval_every),
        "n_seeds": 1,
        "losses": [[float(v) for v in row] for row in curves],
    }
