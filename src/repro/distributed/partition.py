"""Shard the engine's batched (m-grid x seed) simulations over a mesh.

The generic engine (`repro.experiments.engine`) runs each bucket of the
worker grid as ONE vmapped simulation — a batch whose elements are
independent ``(grid member m, seed replicate s)`` cells.  Independence is
the whole trick: the batch axis can be laid out across devices with
``jax.sharding`` and every element still computes exactly what it computes
on one device, so results are **mesh-invariant** (tested at 1e-5; see
docs/distributed.md for the contract).

:func:`run_grid_sharded` is the distributed twin of the engine's
``_run_grid``: for every bucket it

  1. flattens the bucket's (members x seeds) cells into one element axis
     — so a 4-member bucket with 8 seed replicates exposes 32 units of
     parallelism, not 4 (the seed axis shards too, per the tentpole),
  2. pads that axis to a multiple of the device count by repeating the
     first element (cheapest correct filler; the rows are dropped after),
  3. lays the padded ``(m, s)`` index arrays over the mesh's ``'shard'``
     axis with :class:`jax.sharding.NamedSharding` and dispatches ONE
     jitted vmap — computation follows the input sharding, so XLA splits
     the batch across devices while constants (dataset, draws) replicate,
  4. gathers, drops the padding rows, and scatters results back to grid
     order.

One jit per bucket, exactly like the unsharded path — the compile count
per mesh stays 1 per bucket (`scripts/bench_engine.py` measures this in
BENCH_5.json).  The engine owns bucket policy and jit accounting; both
arrive as arguments, which keeps this module free of engine imports.
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.mesh import DeviceMesh
from repro.telemetry import instrument, trace


def pad_to_multiple(n: int, k: int) -> int:
    """Smallest multiple of ``k`` that is >= ``n``."""
    return -(-n // k) * k


def element_plan(pos: Sequence[int], ms: Sequence[int], n_seeds: int,
                 n_devices: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Flattened, padded (m, seed) index arrays for one bucket.

    Element ``e`` of the batch is grid member ``pos[e // n_seeds]`` under
    seed ``e % n_seeds``; padding repeats element 0.  Returns
    ``(m_idx, s_idx, n_real)`` with ``len(m_idx) % n_devices == 0``.
    """
    m_idx = [ms[i] for i in pos for _ in range(n_seeds)]
    s_idx = [s for _ in pos for s in range(n_seeds)]
    n_real = len(m_idx)
    n_pad = pad_to_multiple(n_real, n_devices) - n_real
    m_idx += m_idx[:1] * n_pad
    s_idx += s_idx[:1] * n_pad
    return (np.asarray(m_idx, np.int32), np.asarray(s_idx, np.int32),
            n_real)


def run_grid_sharded(make_sim_elem: Callable, ms: Sequence[int],
                     n_seeds: int, dmesh: DeviceMesh,
                     buckets: List[Tuple[Tuple[int, ...], int]],
                     jit_fn: Callable = jax.jit) -> jnp.ndarray:
    """Run the whole grid sharded over ``dmesh``; rows follow ``ms`` order.

    ``make_sim_elem(m_pad)`` must return ``sim_elem(m, s) -> (n_evals,)``
    obeying the engine's masked-simulation contract (numerics independent
    of ``m_pad`` for any ``m <= m_pad``); ``buckets`` is the engine's
    ``[(positions, m_pad), ...]`` partition (a single flat bucket for
    ``force_flat`` algorithms).  ``jit_fn`` is injected so the engine's
    ``JIT_CALLS`` compile accounting covers the sharded path too.

    Returns ``(S, n_evals)`` for ``n_seeds == 1``, else
    ``(S, n_seeds, n_evals)`` — the same contract as the engine's
    ``_run_grid``, so `_losses_dict` consumes either path unchanged.
    """
    sharded = dmesh.sharding()
    rows: List = [None] * len(ms)
    for pos, m_pad in buckets:
        m_idx, s_idx, n_real = element_plan(pos, ms, n_seeds,
                                            dmesh.n_devices)
        with trace.span("shard_put", devices=dmesh.n_devices,
                        elements=len(m_idx)):
            m_arr = jax.device_put(m_idx, sharded)
            s_arr = jax.device_put(s_idx, sharded)
        out = instrument.dispatch(
            jit_fn(jax.vmap(make_sim_elem(m_pad))), m_arr, s_arr,
            span_name="mesh_bucket", devices=dmesh.n_devices,
            elements=len(m_idx), m_pad=m_pad)
        with trace.span("gather", elements=n_real):
            out = np.asarray(jax.device_get(out))[:n_real]
        out = out.reshape(len(pos), n_seeds, -1)
        for k, i in enumerate(pos):
            rows[i] = out[k] if n_seeds > 1 else out[k, 0]
    return jnp.stack([jnp.asarray(r) for r in rows])
