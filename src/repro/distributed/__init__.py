"""repro.distributed — device-mesh execution for sweeps and the model stack.

The reproduction of a scalability paper should itself scale: this package
shards the sweep engine's batched (m-grid x seed) simulations across every
available XLA device while keeping results **mesh-invariant** — the same
spec produces the same curves (1e-5) and the same cache fingerprint on 1
device or 8 (docs/distributed.md spells out the contract; CI runs the
suite under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

  `mesh`            :class:`DeviceMesh` — the 1-D sweep mesh: auto-detected
                    (:func:`get_mesh`), overridable (``--devices N``),
                    single-device fallback that is bit-exact with the
                    unsharded engine path.  Also hosts the model stack's
                    named-mesh builders (absorbed from `repro.launch.mesh`).
  `partition`       the grid partitioner: flattens each bucket's
                    (members x seeds) cells into one padded element axis,
                    lays it over the mesh, one jit per bucket.
  `hogwild_shards`  TRUE multi-device Hogwild! — worker shards racing on a
                    donated shared parameter under ``shard_map``; the
                    engine's sequential staleness recurrence remains the
                    parity oracle.
  `rules`           the model stack's FSDP/TP PartitionSpec rules (absorbed
                    from the former ``repro.sharding``).

Execution never enters result identity: `repro.experiments.spec`
fingerprints exclude the ``devices`` field, so a sweep cached on one mesh
is a hit on any other.
"""

from repro.distributed.hogwild_shards import (run_hogwild_sharded,
                                              sweep_hogwild_sharded)
from repro.distributed.mesh import (SHARD_AXIS, DeviceMesh, MeshLike,
                                    from_devices, get_mesh,
                                    make_debug_mesh, make_production_mesh,
                                    resolve)
from repro.distributed.partition import (element_plan, pad_to_multiple,
                                         run_grid_sharded)
from repro.distributed.rules import (FSDP_AXES, act_constraint, batch_specs,
                                     data_axes, decode_act_constraint,
                                     decode_state_specs, head_constraint,
                                     inner_act_constraint, layer_constraint,
                                     logits_constraint, opt_state_specs,
                                     param_specs)

__all__ = [
    "SHARD_AXIS", "DeviceMesh", "MeshLike", "from_devices", "get_mesh",
    "resolve", "make_debug_mesh", "make_production_mesh",
    "element_plan", "pad_to_multiple", "run_grid_sharded",
    "run_hogwild_sharded", "sweep_hogwild_sharded",
    "FSDP_AXES", "act_constraint", "batch_specs", "data_axes",
    "decode_act_constraint", "decode_state_specs", "head_constraint",
    "inner_act_constraint", "layer_constraint", "logits_constraint",
    "opt_state_specs", "param_specs",
]
