"""Named sweep specs — one per paper figure/table (mirrors configs/registry).

Each builder resolves a fully-concrete :class:`SweepSpec` (quick mode folds
the CI-friendly iteration/size constants in, exactly as the legacy
`benchmarks/paper_*.py` scripts did), so a spec name + ``quick`` flag is a
complete, hashable description of a paper experiment:

  ``variance_sparsity``   Figs 3-5   dense-vs-sparse on minibatch/ECD/Hogwild!
  ``diversity``           Fig 6      duplication variants on DADM/minibatch
  ``ls``                  Figs 7-10  C_sim-controlled sequences, no shuffle
  ``upper_bound``         Table II   cost-per-worker m_max sweep + predictions
  ``scalability_study``   end-to-end characters + m=1 vs m=8 study
  ``problem_generality``  beyond Eq. 4: ridge & hinge objectives on the
                          label-noise / heavy-tailed dataset variants —
                          the dataset-characters claims off the logistic
                          loss, purely via registry entries
  ``character_surface``   the thesis as a surface: one generator
                          (`character_knob`) swept continuously over
                          variance x density x duplication, with seed
                          replicates, cost readouts, and predictions —
                          the input of `repro.analysis.fit`'s
                          characters -> m_max regression
  ``critical_params``     the critical-parameter surface: momentum lr x
                          local-SGD sync window x async-SVRG anchor
                          period, each knob swept at two dataset-character
                          settings — does the m_max cliff move with the
                          knob AND the characters?
  ``fault_tolerance``     fault injection as a sweep axis: Hogwild! and
                          local SGD under seeded delivery-fault rates
                          (straggle + sign-flip) at the two character
                          settings — measured m_max degradation vs fault
                          rate (docs/robustness.md)

Use :func:`get_spec` / :data:`SPEC_IDS`; ``iters`` / ``n`` / ``seeds``
overrides thread through for fast smoke runs (``seeds`` replaces the
spec's ``n_seeds``, e.g. for the `repro.analysis.report` CLI).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.experiments.spec import (DatasetSpec, EpsilonSpec, JobSpec,
                                    SweepSpec)


def _variance_sparsity(quick=False, iters: Optional[int] = None,
                       n: Optional[int] = None) -> SweepSpec:
    iters = iters if iters is not None else (600 if quick else 1500)
    n = n if n is not None else (1000 if quick else 2000)
    datasets = {
        "higgs_like": DatasetSpec("higgs_like", {"n": n, "d": 28}),
        "realsim_like": DatasetSpec("realsim_like",
                                    {"n": n, "d": 400, "density": 0.05}),
    }
    jobs = tuple(JobSpec(algo, ds)
                 for ds in ("higgs_like", "realsim_like")
                 for algo in ("minibatch", "ecd_psgd", "hogwild"))
    return SweepSpec(
        name="variance_sparsity",
        description="Figs 3-5: feature-variance & sparsity vs parallel gain",
        ms=(1, 2, 4, 8), iters=iters, eval_every=iters // 10,
        datasets=datasets, jobs=jobs).validate()


def _diversity(quick=False, iters: Optional[int] = None,
               n: Optional[int] = None) -> SweepSpec:
    iters = iters if iters is not None else (400 if quick else 800)
    n = n if n is not None else (800 if quick else 1600)
    base = {"n": n, "d": 300, "density": 0.05}
    datasets = {v: DatasetSpec("realsim_like", base, variant=v)
                for v in ("high", "mid", "low")}
    jobs = tuple(JobSpec(algo, ds)
                 for ds in ("high", "mid", "low")
                 for algo in ("dadm", "minibatch"))
    return SweepSpec(
        name="diversity",
        description="Fig 6: sample-diversity duplication variants",
        ms=(1, 4, 16), iters=iters, eval_every=iters // 8,
        datasets=datasets, jobs=jobs).validate()


def _ls(quick=False, iters: Optional[int] = None,
        n: Optional[int] = None) -> SweepSpec:
    iters = iters if iters is not None else (500 if quick else 1200)
    n = n if n is not None else (1000 if quick else 2400)
    sparse = {"d": 200, "density": 0.05, "lo": 0, "hi": 1}
    datasets = {
        "small_ls_dense": DatasetSpec(
            "ls_sequence", {"n": n, "d": 28, "mutate_frac": 0.1},
            shuffle_split=False),
        "large_ls_dense": DatasetSpec(
            "ls_sequence", {"n": n, "d": 28, "mutate_frac": 0.9},
            shuffle_split=False),
        "small_ls_sparse": DatasetSpec(
            "ls_sequence", {"n": n, "mutate_frac": 0.1, **sparse},
            shuffle_split=False),
        "large_ls_sparse": DatasetSpec(
            "ls_sequence", {"n": n, "mutate_frac": 0.9, **sparse},
            shuffle_split=False),
    }
    jobs = tuple([JobSpec(a, ds) for ds in ("small_ls_dense",
                                            "large_ls_dense")
                  for a in ("minibatch", "ecd_psgd")]
                 + [JobSpec(a, ds) for ds in ("small_ls_sparse",
                                              "large_ls_sparse")
                    for a in ("hogwild", "dadm")])
    return SweepSpec(
        name="ls",
        description="Figs 7-10: sampling-sequence similarity (C_sim) sweeps",
        ms=(1, 4, 8), iters=iters, eval_every=iters // 8,
        datasets=datasets, jobs=jobs, measure_csim=8, csim_rows=400,
    ).validate()


def _upper_bound(quick=False, iters: Optional[int] = None,
                 n: Optional[int] = None) -> SweepSpec:
    if n is not None:
        warnings.warn("the upper_bound spec ignores the n override: "
                      "its dataset sizes are fixed by §VII.E")
    iters = iters if iters is not None else (1200 if quick else 3000)
    datasets = {
        "ub": DatasetSpec("upper_bound",
                          {"n": 4000, "d": 400, "density": 0.7}),
        "dense": DatasetSpec("higgs_like", {"n": 4000, "d": 28}),
        "sparse8": DatasetSpec("realsim_like",
                               {"n": 1000, "d": 300, "density": 0.05}),
    }
    jobs = (
        JobSpec("hogwild", "ub", {"gamma": 0.05}, predict=True),
        JobSpec("minibatch", "dense", predict=True),
        JobSpec("ecd_psgd", "dense"),
        JobSpec("dadm", "sparse8", predict=True, predict_rows=600),
    )
    return SweepSpec(
        name="upper_bound",
        description="Table II: cost-per-worker sweep + predicted m_max",
        ms=(2, 4, 8, 16, 24), iters=iters, eval_every=iters // 20,
        datasets=datasets, jobs=jobs,
        epsilon=EpsilonSpec(probe_m=2, frac=0.7)).validate()


def _scalability_study(quick=False, iters: Optional[int] = None,
                       n: Optional[int] = None) -> SweepSpec:
    iters = (800 if quick else 3000) if iters is None else iters
    n = (1500 if quick else 4000) if n is None else n
    datasets = {
        "higgs_like": DatasetSpec("higgs_like", {"n": n, "d": 28}),
        "realsim_like": DatasetSpec("realsim_like",
                                    {"n": n, "d": 400, "density": 0.05}),
    }
    jobs = tuple(JobSpec(algo, ds, predict=algo in ("hogwild", "minibatch"),
                         predict_rows=800)
                 for ds in ("higgs_like", "realsim_like")
                 for algo in ("minibatch", "hogwild", "ecd_psgd", "dadm"))
    return SweepSpec(
        name="scalability_study",
        description="end-to-end: characters + measured-vs-predicted study",
        ms=(1, 8), iters=iters, eval_every=iters // 8,
        datasets=datasets, jobs=jobs, characters_rows=800).validate()


def _problem_generality(quick=False, iters: Optional[int] = None,
                        n: Optional[int] = None) -> SweepSpec:
    """Stich-et-al-style generality check: the variance/sparsity story under
    ridge and hinge objectives, plus the label-noise and heavy-tailed
    dataset-character variants.  Every cell here reaches the engine purely
    through registry names — no engine edits for new losses or datasets.

    Ridge on the wide-range higgs_like features needs a tiny step size
    (squared-loss curvature ~ mean ||xi||^2), hence the per-job gamma.
    """
    iters = iters if iters is not None else (500 if quick else 1500)
    n = n if n is not None else (1000 if quick else 2000)
    datasets = {
        "higgs_like": DatasetSpec("higgs_like", {"n": n, "d": 28}),
        "noisy": DatasetSpec("label_noise",
                             {"base": "higgs_like", "flip_frac": 0.2,
                              "n": n, "d": 28}),
        "heavy": DatasetSpec("heavy_tailed", {"n": n, "d": 28, "df": 3.0}),
    }
    gammas = {"ridge": 0.003, "hinge": 0.05}
    jobs = tuple(
        JobSpec(algo, ds, kwargs={} if algo == "dadm"
                else {"gamma": gammas[prob]}, problem=prob)
        for ds in ("higgs_like", "noisy", "heavy")
        for prob in ("ridge", "hinge")
        for algo in ("minibatch", "dadm"))
    return SweepSpec(
        name="problem_generality",
        description="dataset characters beyond Eq. 4: ridge/hinge on "
                    "label-noise & heavy-tailed variants",
        ms=(1, 4, 8), iters=iters, eval_every=iters // 10,
        datasets=datasets, jobs=jobs).validate()


def _character_surface(quick=False, iters: Optional[int] = None,
                       n: Optional[int] = None) -> SweepSpec:
    """The paper's thesis as a fitted surface: sweep the `character_knob`
    generator over a (variance, density, duplication) grid, replicate each
    cell over a vmapped seed batch, and read cost/m_max per cell — the
    points `repro.analysis.fit.characters_regression` regresses m_max
    against and `repro.analysis.report` renders as the surface table.
    Every cell predicts too (`predict=True`), so the report can put the
    fitted bound next to the theory-side one.
    """
    iters = iters if iters is not None else (400 if quick else 1200)
    n = n if n is not None else (512 if quick else 1536)
    variances = (0.25, 4.0) if quick else (0.25, 1.0, 4.0)
    densities = (0.15, 1.0) if quick else (0.1, 0.5, 1.0)
    dups = (0.0, 0.75) if quick else (0.0, 0.5, 0.75)
    datasets = {}
    for v in variances:
        for p in densities:
            for dup in dups:
                datasets[f"v{v}_p{p}_dup{dup}"] = DatasetSpec(
                    "character_knob",
                    {"n": n, "d": 48, "variance": v, "density": p,
                     "duplication": dup})
    jobs = tuple(JobSpec("minibatch", ds, predict=True) for ds in datasets)
    return SweepSpec(
        name="character_surface",
        description="m_max surface over continuous variance/sparsity/"
                    "diversity knobs (seed-replicated)",
        ms=(1, 2, 4, 8) if quick else (1, 2, 4, 8, 16),
        iters=iters, eval_every=iters // 10,
        datasets=datasets, jobs=jobs,
        epsilon=EpsilonSpec(probe_m=2, frac=0.7),
        # measure characters on EVERY row: character_knob tiles duplicates
        # after the unique head, so a row-capped summary would report
        # diversity_ratio 1.0 for every duplication level and corrupt the
        # characters -> m_max regression
        characters_rows=n,
        n_seeds=3 if quick else 8).validate()


def _critical_params(quick=False, iters: Optional[int] = None,
                     n: Optional[int] = None) -> SweepSpec:
    """The critical-parameter surface (ROADMAP item 4, Stich arXiv
    2103.02351 / Zhang arXiv 1508.01633): for each of the three
    critical-parameter algorithms, sweep its critical knob — the momentum
    step size (lr axis), the local-SGD sync window, the async-SVRG anchor
    period — over TWO `character_knob` settings (low variance + heavy
    duplication vs high variance, full density, all-unique).  The worker
    grid is the batch axis for the synchronous pair and the staleness axis
    (tau_max = m) for async-SVRG.  Every cell costs and predicts, so the
    report can show the m_max cliff moving BOTH with the knob and with the
    dataset characters — the paper's thesis extended across optimizer
    classes.

    Knob labels disambiguate same-cell jobs (`JobSpec.label`); momentum
    gammas are pre-divided by 1/(1-beta) (see `Momentum.gamma_scale`).
    """
    iters = iters if iters is not None else (400 if quick else 1200)
    n = n if n is not None else (512 if quick else 1536)
    datasets = {
        "lo_char": DatasetSpec(
            "character_knob",
            {"n": n, "d": 48, "variance": 0.25, "density": 0.5,
             "duplication": 0.75}),
        "hi_char": DatasetSpec(
            "character_knob",
            {"n": n, "d": 48, "variance": 4.0, "density": 1.0,
             "duplication": 0.0}),
    }
    gammas = (0.005, 0.02) if quick else (0.005, 0.01, 0.02)
    windows = (1, 8) if quick else (1, 4, 16)
    anchors = (25, 200) if quick else (25, 100, 400)
    jobs = []
    for ds in datasets:
        for g in gammas:
            jobs.append(JobSpec("momentum", ds, {"gamma": g},
                                predict=True, label=f"g{g}"))
        for w in windows:
            jobs.append(JobSpec("local_sgd", ds,
                                {"gamma": 0.1, "sync_every": w},
                                predict=True, label=f"H{w}"))
        for h in anchors:
            jobs.append(JobSpec("async_svrg", ds,
                                {"gamma": 0.1, "anchor_every": h},
                                predict=True, label=f"A{h}"))
    return SweepSpec(
        name="critical_params",
        description="critical-parameter surface: momentum lr x local-SGD "
                    "sync window x async-SVRG anchor period, per dataset "
                    "character setting",
        ms=(1, 2, 4, 8) if quick else (1, 2, 4, 8, 16),
        iters=iters, eval_every=iters // 10,
        datasets=datasets, jobs=tuple(jobs),
        epsilon=EpsilonSpec(probe_m=2, frac=0.7),
        # duplicates tile after the unique head — measure every row (see
        # _character_surface)
        characters_rows=n,
        n_seeds=3 if quick else 8).validate()


def _fault_tolerance(quick=False, iters: Optional[int] = None,
                     n: Optional[int] = None) -> SweepSpec:
    """Fault injection as a sweep axis (docs/robustness.md): Hogwild! and
    local SGD under a grid of seeded delivery-fault rates (straggling +
    sign-flipped updates, `repro.resilience.faults.FaultSpec`), each at
    the two `character_knob` settings of the critical-parameter surface.
    Faults are environment, not experiment randomness — the fault seed is
    pinned, so every cell is bit-reproducible and the seed replicates
    share the fault schedule.  The readout is measured m_max degradation
    vs fault rate per character setting, rendered by
    `repro.analysis.report`'s fault-tolerance section.

    Design notes, all load-bearing:

    * the fault mix is straggle-dominant because extra staleness is
      capped at tau = m — the serial probe run is straggle-immune, so the
      probe epsilon stays honest while the large-m cells absorb the
      damage.  That makes the epsilon probe m=1, not the usual 2.
    * rates stop at 0.5: beyond it, near-permanent staleness starts
      acting like an averaging regularizer and the degradation is no
      longer monotone (measured, not hypothesized).
    * per-dataset step sizes equalize the *clean* baselines (logistic
      curvature scales with feature variance); without this the
      lo-variance cell sits at the edge of its iteration budget and any
      perturbation tips it first, inverting the character story.
    * the paper's thesis then shows up as: the hi-variance, all-unique
      cell has no redundancy to absorb stale/poisoned updates, so its
      cliff collapses with the rate while the duplicated lo-variance
      cell barely moves — and local SGD's sync averaging is the control
      (its replicas re-anchor every sync, so the async staleness
      compounding is absent).

    No predictions: the theory-side m_max bounds model staleness, not
    faulty delivery — the measured degradation IS the result.
    """
    iters = iters if iters is not None else (400 if quick else 1200)
    n = n if n is not None else (512 if quick else 1536)
    datasets = {
        "lo_char": DatasetSpec(
            "character_knob",
            {"n": n, "d": 48, "variance": 0.25, "density": 0.5,
             "duplication": 0.75}),
        "hi_char": DatasetSpec(
            "character_knob",
            {"n": n, "d": 48, "variance": 4.0, "density": 1.0,
             "duplication": 0.0}),
    }
    rates = (0.0, 0.25, 0.5) if quick else (0.0, 0.125, 0.25, 0.5)
    hogwild_gamma = {"lo_char": 0.1, "hi_char": 0.05}
    local_gamma = {"lo_char": 0.2, "hi_char": 0.1}
    jobs = []
    for ds in datasets:
        for rate in rates:
            fault = {"straggle_rate": rate, "straggle_rounds": 8,
                     "corrupt_rate": rate / 2,
                     "corrupt_kind": "sign_flip", "seed": 7}
            jobs.append(JobSpec("hogwild", ds,
                                {"gamma": hogwild_gamma[ds],
                                 "fault": fault},
                                label=f"f{rate}"))
            jobs.append(JobSpec("local_sgd", ds,
                                {"gamma": local_gamma[ds], "sync_every": 2,
                                 "fault": fault},
                                label=f"f{rate}"))
    return SweepSpec(
        name="fault_tolerance",
        description="measured m_max degradation vs injected fault rate "
                    "(straggle + sign-flip), per dataset character setting",
        ms=(1, 2, 3, 4, 6, 8) if quick else (1, 2, 3, 4, 6, 8, 12, 16),
        iters=iters, eval_every=iters // 10,
        datasets=datasets, jobs=tuple(jobs),
        epsilon=EpsilonSpec(probe_m=1, frac=0.7),
        # duplicates tile after the unique head — measure every row (see
        # _character_surface)
        characters_rows=n,
        n_seeds=3 if quick else 8).validate()


_BUILDERS = {
    "variance_sparsity": _variance_sparsity,
    "diversity": _diversity,
    "ls": _ls,
    "upper_bound": _upper_bound,
    "scalability_study": _scalability_study,
    "problem_generality": _problem_generality,
    "character_surface": _character_surface,
    "critical_params": _critical_params,
    "fault_tolerance": _fault_tolerance,
}

SPEC_IDS = sorted(_BUILDERS)


def get_spec(name: str, *, quick: bool = False,
             iters: Optional[int] = None,
             n: Optional[int] = None,
             seeds: Optional[int] = None) -> SweepSpec:
    """Resolve a named paper spec (quick mode folds in CI-scale constants).
    ``seeds`` overrides the spec's ``n_seeds`` — e.g. the analysis report
    replicates the single-seed paper specs without a new builder."""
    if name not in _BUILDERS:
        raise KeyError(f"unknown sweep spec {name!r}; known: {SPEC_IDS}")
    spec = _BUILDERS[name](quick=quick, iters=iters, n=n)
    if seeds is not None and seeds != spec.n_seeds:
        spec = dataclasses.replace(spec, n_seeds=seeds).validate()
    return spec
