"""Batched m-sweep kernels: bucketed `jax.vmap` grids over the worker axis.

The legacy benchmarks re-ran each algorithm once per worker count m in a
Python loop — S separate traces, S compilations, S dispatch chains.  Here
every algorithm (mini-batch SGD, ECD-PSGD, DADM, *and* Hogwild!) is
re-derived as a *masked, padded* simulation over a fixed worker axis of
size ``m_pad`` in which the actual worker count m is ordinary traced data:

  * workers with index >= m are masked out of every reduction (gradient
    average, ring average, dual all-gather), so the padded run is
    numerically the m-worker run;
  * all random draws (sample indices, quantization keys) are made once at
    the *global* ``m_top = max(ms)`` and sliced per padding width — sweep
    member m consumes the first m columns no matter which bucket it lands
    in, so numerics are identical across flat / bucketed / sequential
    execution;
  * each bucket of the grid then runs as ``jax.vmap(sim)(ms_bucket)`` —
    one trace, one compile, one `lax.scan` pipeline per bucket.

**Hogwild! is vmapped too** (new in ENGINE_VERSION 2).  The PR-1 engine
kept it sequential on the theory that the staleness recurrence
``hist[(j - tau) % m]`` changes *shape* with m — but only the history
*indices* depend on m, not any shape: `hogwild.masked_sim` allocates the
history at the static pad width and takes every index modulo the traced m,
so rows >= m are never touched and Thm 1's lag-equals-worker-count
semantics carry over unchanged.  The sweep therefore compiles **once** for
the whole grid instead of once per m.  Because the recurrence updates a
single model regardless of m (work is O(iters * d), not O(iters * m * d)),
Hogwild! always runs as one flat vmap — bucketing would only add compiles.

**Bucketed padding** (`_buckets`): a flat padded grid does S * work(m_top)
FLOPs, so wide grids like [1, 2, 4, ..., 64] pay work(64) for the m=1
member.  `_run_grid` instead partitions the grid greedily into buckets
whose pad waste is bounded — ``max(bucket) <= MAX_PAD_RATIO * min(bucket)``
(default 2x) — and vmaps each bucket at its own ``m_pad``.  The trade is
one extra compile per bucket against the padded FLOPs, so bucketing pays
exactly when per-step work scales with the worker axis: it is the default
for mini-batch and ECD-PSGD (m-scaled gathers / quantization), while DADM
(m-independent (n,)-sized dual state) and Hogwild! default to a single
flat vmap.  ``bucketed=False`` recovers the PR-1 flat grid everywhere;
`scripts/bench_engine.py` tracks both regimes in BENCH_2.json.

Every sweep function also takes ``use_vmap=False``, which runs the *same*
masked kernel (padded to m_top) once per m in a Python loop — the
sequential reference path the equivalence tests compare against.  For
Hogwild! the sequential path loops the legacy per-m `run_hogwild`, so the
vmapped grid is checked against the original recurrence, not itself.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms import hogwild as hogwild_mod
from repro.core.algorithms import run_hogwild
from repro.core.algorithms.lr import LAMBDA, test_logloss
from repro.core.compression import dequantize, quantize_stochastic

#: Pad-waste bound for `_buckets`: within a bucket, the padded worker axis
#: is at most this multiple of the smallest member.
MAX_PAD_RATIO = 2.0


def _losses_dict(algorithm: str, ms, losses, iters: int, eval_every: int):
    """Engine output contract: curves for every m of the grid."""
    return {
        "algorithm": algorithm,
        "ms": [int(m) for m in ms],
        "iters": int(iters),
        "eval_every": int(eval_every),
        # (S, n_evals) float list-of-lists, row i <-> ms[i]
        "losses": [[float(v) for v in row] for row in jax.device_get(losses)],
    }


def _buckets(ms: Sequence[int],
             max_pad_ratio: float = MAX_PAD_RATIO
             ) -> List[Tuple[Tuple[int, ...], int]]:
    """Greedy waste-bounded partition of the m-grid.

    Returns ``[(positions, m_pad), ...]`` where ``positions`` index into
    ``ms`` and ``m_pad = max(ms[i] for i in positions)``.  Scanning the
    grid in ascending order, a member opens a new bucket whenever it would
    exceed ``max_pad_ratio *`` the bucket's smallest m — so no member is
    ever padded past that ratio, bounding the wasted FLOPs of the padded
    vmap at ``max_pad_ratio``x per member.
    """
    order = sorted(range(len(ms)), key=lambda i: ms[i])
    out: List[Tuple[Tuple[int, ...], int]] = []
    cur: List[int] = []
    for i in order:
        if cur and ms[i] > max_pad_ratio * ms[cur[0]]:
            out.append((tuple(cur), ms[cur[-1]]))
            cur = []
        cur.append(i)
    if cur:
        out.append((tuple(cur), ms[cur[-1]]))
    return out


def _run_grid(make_sim, ms, use_vmap: bool, bucketed: bool = True):
    """Run ``sim = make_sim(m_pad)`` over the grid; rows follow ``ms`` order.

    ``make_sim(m_pad)`` must return a closure ``sim(m) -> (n_evals,)`` that
    is numerically independent of ``m_pad`` for any ``m <= m_pad`` (shared
    draws sliced, reductions masked) — that contract is what makes the
    three execution modes here interchangeable.
    """
    m_top = max(ms)
    if not use_vmap:
        jsim = jax.jit(make_sim(m_top))   # one compile serves every m
        return jnp.stack([jsim(m) for m in jnp.asarray(ms, jnp.int32)])
    if not bucketed:
        return jax.jit(jax.vmap(make_sim(m_top)))(jnp.asarray(ms, jnp.int32))
    rows = [None] * len(ms)
    for pos, m_pad in _buckets(ms):
        sub = jnp.asarray([ms[i] for i in pos], jnp.int32)
        out = jax.jit(jax.vmap(make_sim(m_pad)))(sub)
        for k, i in enumerate(pos):
            rows[i] = out[k]
    return jnp.stack(rows)


# ---------------------------------------------------------------------------
# Mini-batch SGD (Alg 2): batch size IS the worker count (Fact 1)
# ---------------------------------------------------------------------------

def sweep_minibatch(train, test, ms: Sequence[int], *, iters: int,
                    eval_every: int, gamma=0.1, lam=LAMBDA, key=None,
                    use_vmap=True, bucketed=True) -> Dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n, d = X.shape
    m_top = max(ms)
    order = jax.random.randint(key, (iters, m_top), 0, n)
    n_evals = iters // eval_every

    def make_sim(m_pad):
        sub_order = order[:, :m_pad]

        def sim(m):
            active = (jnp.arange(m_pad) < m).astype(jnp.float32)
            mf = m.astype(jnp.float32)

            def step(x, idx):
                Xb, yb = X[idx], y[idx]              # (m_pad, d), (m_pad,)
                sig = jax.nn.sigmoid(-(yb * (Xb @ x)))
                g = -((sig * yb * active) @ Xb) / mf + lam * x
                return x - gamma * g, None

            def outer(x, e):
                idxs = jax.lax.dynamic_slice_in_dim(sub_order, e * eval_every,
                                                    eval_every, axis=0)
                x, _ = jax.lax.scan(step, x, idxs)
                return x, test_logloss(x, Xte, yte)

            _, losses = jax.lax.scan(outer, jnp.zeros((d,)),
                                     jnp.arange(n_evals))
            return losses

        return sim

    losses = _run_grid(make_sim, ms, use_vmap, bucketed)
    return _losses_dict("minibatch", ms, losses, iters, eval_every)


# ---------------------------------------------------------------------------
# ECD-PSGD (Alg 4): ring of m workers as a masked (m_pad, m_pad) mixing matrix
# ---------------------------------------------------------------------------

def _ring_matrix(m, m_pad: int):
    """W with W[i] = (e_i + e_{i-1 mod m} + e_{i+1 mod m})/3 for i < m and
    identity rows for padded workers — the roll-based ring of ecd_psgd.py
    expressed so that m can be traced data."""
    ids = jnp.arange(m_pad)
    eye = jnp.eye(m_pad)
    W = (eye + eye[(ids - 1) % m] + eye[(ids + 1) % m]) / 3.0
    return jnp.where((ids < m)[:, None], W, eye)


def sweep_ecd_psgd(train, test, ms: Sequence[int], *, iters: int,
                   eval_every: int, gamma=0.1, lam=LAMBDA, compress_bits=8,
                   key=None, use_vmap=True, bucketed=True) -> Dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n, d = X.shape
    m_top = max(ms)
    k_order, k_q = jax.random.split(key)
    order = jax.random.randint(k_order, (iters, m_top), 0, n)
    # Per-(iteration, worker) quantization keys, hoisted out of the scan:
    # one vectorized fold_in+split here replaces two chained RNG ops per
    # step, and drawing at m_top keeps worker i's key identical in every
    # bucket (and to the flat grid).  Same draws as the in-scan version.
    wkeys = jax.vmap(lambda t: jax.random.split(
        jax.random.fold_in(k_q, t), m_top))(jnp.arange(iters))
    n_evals = iters // eval_every

    def make_sim(m_pad):
        sub_order = order[:, :m_pad]
        sub_keys = wkeys[:, :m_pad]

        def sim(m):
            active = (jnp.arange(m_pad) < m).astype(jnp.float32)
            mf = m.astype(jnp.float32)
            W = _ring_matrix(m, m_pad)

            def one_iter(carry, inp):
                xs, ys = carry               # (m_pad, d) models / y-vars
                idx, kqs, t = inp            # kqs: (m_pad,) worker keys
                tf = t.astype(jnp.float32) + 1.0
                x_half = W @ ys              # neighbors pull compressed y

                def grad_w(xi, i):
                    sig = jax.nn.sigmoid(-(y[i] * jnp.dot(X[i], xi)))
                    return -sig * y[i] * X[i] + lam * xi

                x_new = x_half - gamma * jax.vmap(grad_w)(xs, idx)
                # z = (1 - t/2) x_t + (t/2) x_{t+1};  y = (1-2/t) y + (2/t) C(z)
                z = (1.0 - tf / 2.0) * xs + (tf / 2.0) * x_new
                cz = jax.vmap(lambda zz, kk: dequantize(
                    *quantize_stochastic(zz, kk, bits=compress_bits)))(z, kqs)
                y_new = (1.0 - 2.0 / tf) * ys + (2.0 / tf) * cz
                return (x_new, y_new), None

            def outer(carry, e):
                base = e * eval_every
                ts = base + jnp.arange(eval_every)
                idxs = jax.lax.dynamic_slice_in_dim(sub_order, base,
                                                    eval_every, axis=0)
                keys = jax.lax.dynamic_slice_in_dim(sub_keys, base,
                                                    eval_every, axis=0)
                carry, _ = jax.lax.scan(one_iter, carry, (idxs, keys, ts))
                x_avg = (active @ carry[0]) / mf  # mean over live workers
                return carry, test_logloss(x_avg, Xte, yte)

            carry0 = (jnp.zeros((m_pad, d)), jnp.zeros((m_pad, d)))
            _, losses = jax.lax.scan(outer, carry0, jnp.arange(n_evals))
            return losses

        return sim

    losses = _run_grid(make_sim, ms, use_vmap, bucketed)
    return _losses_dict("ecd_psgd", ms, losses, iters, eval_every)


# ---------------------------------------------------------------------------
# DADM (Alg 3): masked dual all-gather over the padded worker axis
# ---------------------------------------------------------------------------

def sweep_dadm(train, test, ms: Sequence[int], *, iters: int, eval_every: int,
               local_batch=8, lam=LAMBDA, key=None, use_vmap=True,
               bucketed=False) -> Dict:
    # bucketed defaults to False here: DADM's dual state is (n,)-sized and
    # m-independent, so replaying the alpha/v updates once per bucket costs
    # more than the padded per-worker FLOPs it saves.  The flag is honored
    # if explicitly requested (the equivalence tests exercise it).
    key = key if key is not None else jax.random.PRNGKey(0)
    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n, d = X.shape
    m_top = max(ms)
    order = jax.random.randint(key, (iters, m_top, local_batch), 0, n)
    sq_norms = jnp.sum(X * X, axis=1)
    step_sz = jnp.minimum(1.0, (lam * n) / (sq_norms / 4.0 + lam * n))
    n_evals = iters // eval_every

    def make_sim(m_pad):
        sub_order = order[:, :m_pad]

        def sim(m):
            active = (jnp.arange(m_pad) < m).astype(jnp.float32)

            def one_iter(carry, idx):
                alpha, v = carry             # (n,), (d,)
                x = v

                def worker(idx_w):
                    Xi, yi, ai = X[idx_w], y[idx_w], alpha[idx_w]
                    p = jax.nn.sigmoid(-(yi * (Xi @ x)))
                    da = (p - ai) * step_sz[idx_w]
                    dv = (yi * da) @ Xi / (lam * n)
                    return da, dv

                das, dvs = jax.vmap(worker)(idx)     # (m_pad, lb), (m_pad, d)
                das = das * active[:, None]          # padded workers sit out
                alpha = alpha.at[idx.reshape(-1)].add(das.reshape(-1))
                v = v + active @ dvs                 # masked all-gather sum
                return (alpha, v), None

            alpha0 = jnp.full((n,), 0.5)
            v0 = (y * alpha0) @ X / (lam * n)

            def outer(carry, e):
                idxs = jax.lax.dynamic_slice_in_dim(sub_order, e * eval_every,
                                                    eval_every, axis=0)
                carry, _ = jax.lax.scan(one_iter, carry, idxs)
                return carry, test_logloss(carry[1], Xte, yte)

            _, losses = jax.lax.scan(outer, (alpha0, v0), jnp.arange(n_evals))
            return losses

        return sim

    losses = _run_grid(make_sim, ms, use_vmap, bucketed)
    return _losses_dict("dadm", ms, losses, iters, eval_every)


# ---------------------------------------------------------------------------
# Hogwild! (Alg 1): one flat vmap over the traced-m staleness recurrence
# ---------------------------------------------------------------------------

def sweep_hogwild(train, test, ms: Sequence[int], *, iters: int,
                  eval_every: int, gamma=0.1, lam=LAMBDA, key=None,
                  use_vmap=True, bucketed=True) -> Dict:
    del bucketed   # work is O(iters * d) regardless of m_pad — always flat
    key = key if key is not None else jax.random.PRNGKey(0)
    if not use_vmap:
        # Legacy per-m reference path (re-jits per m): the vmapped grid is
        # equivalence-tested against this, i.e. against the original
        # recurrence rather than against another padded kernel.
        curves = [run_hogwild(train, test, m=int(m), iters=iters, gamma=gamma,
                              lam=lam, eval_every=eval_every, key=key)["losses"]
                  for m in ms]
        return _losses_dict("hogwild", ms,
                            jnp.stack([jnp.asarray(c) for c in curves]),
                            iters, eval_every)

    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n = X.shape[0]
    # identical draw to run_hogwild's: the sequence is m-independent
    order = jax.random.randint(key, (iters,), 0, n)

    def make_sim(m_pad):
        sim = hogwild_mod.masked_sim(
            X, y, Xte, yte, order, m_pad=m_pad, gamma=gamma, lam=lam,
            eval_every=eval_every, n_evals=iters // eval_every)
        return lambda m: sim(m)[1]           # losses only

    losses = _run_grid(make_sim, ms, use_vmap=True, bucketed=False)
    return _losses_dict("hogwild", ms, losses, iters, eval_every)


SWEEPERS = {
    "minibatch": sweep_minibatch,
    "ecd_psgd": sweep_ecd_psgd,
    "dadm": sweep_dadm,
    "hogwild": sweep_hogwild,
}


def run_algorithm_sweep(algorithm: str, train, test, ms, *, iters,
                        eval_every, use_vmap=True, bucketed=None,
                        **kwargs) -> Dict:
    """Dispatch one (algorithm, dataset) job over the worker grid.

    ``bucketed=None`` keeps each sweeper's own default (bucketed for
    mini-batch/ECD-PSGD, flat for DADM/Hogwild!); True/False forces a
    policy for the sweepers that honor it.  Hogwild! always runs flat —
    its work is independent of the pad width, so `sweep_hogwild` ignores
    the flag rather than add compiles for nothing.
    """
    try:
        fn = SWEEPERS[algorithm]
    except KeyError:
        raise KeyError(f"unknown algorithm {algorithm!r}; "
                       f"known: {sorted(SWEEPERS)}") from None
    if bucketed is not None:
        kwargs["bucketed"] = bucketed
    return fn(train, test, list(ms), iters=iters, eval_every=eval_every,
              use_vmap=use_vmap, **kwargs)
