"""Batched m-sweep kernels: one `jax.vmap` over the whole worker grid.

The legacy benchmarks re-ran each algorithm once per worker count m in a
Python loop — S separate traces, S compilations, S dispatch chains.  Here
each synchronous algorithm (mini-batch SGD, ECD-PSGD, DADM) is re-derived
as a *masked, padded* simulation over a fixed worker axis of size
``m_max = max(ms)`` in which the actual worker count m is ordinary traced
data:

  * workers with index >= m are masked out of every reduction (gradient
    average, ring average, dual all-gather), so the padded run is
    numerically the m-worker run;
  * the per-iteration sample draw is a single shared ``(iters, m_max)``
    index tensor — sweep member m consumes its first m columns, so growing
    m adds workers without reshuffling the ones already present;
  * the whole grid then runs as ``jax.vmap(sim)(ms)`` — one trace, one
    compile, one `lax.scan` pipeline for every m at once.

Every sweep function also takes ``use_vmap=False``, which runs the *same*
masked kernel once per m in a Python loop — the sequential reference path
the equivalence tests compare against.

Hogwild! stays on the sequential path on purpose: its staleness recurrence
indexes history modulo m (`hist[(j - tau) % m]`), i.e. the *shape* of the
recurrence changes with m, and Thm 1's lag-equals-worker-count semantics
would not survive a padded rewrite.  It loops over `run_hogwild` per m.

Note the padded grid does S * work(m_max) FLOPs versus the loop's
sum_m work(m); the win is one fused scan instead of S dispatch chains,
which dominates at benchmark scale on CPU and accelerators alike.
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.core.algorithms import run_hogwild
from repro.core.algorithms.lr import LAMBDA, test_logloss
from repro.core.compression import dequantize, quantize_stochastic


def _losses_dict(algorithm: str, ms, losses, iters: int, eval_every: int):
    """Engine output contract: curves for every m of the grid."""
    return {
        "algorithm": algorithm,
        "ms": [int(m) for m in ms],
        "iters": int(iters),
        "eval_every": int(eval_every),
        # (S, n_evals) float list-of-lists, row i <-> ms[i]
        "losses": [[float(v) for v in row] for row in jax.device_get(losses)],
    }


def _run_grid(sim, ms, use_vmap: bool):
    ms_arr = jnp.asarray(ms, jnp.int32)
    if use_vmap:
        return jax.jit(jax.vmap(sim))(ms_arr)
    jsim = jax.jit(sim)          # one compile serves every m (traced scalar)
    return jnp.stack([jsim(m) for m in ms_arr])


# ---------------------------------------------------------------------------
# Mini-batch SGD (Alg 2): batch size IS the worker count (Fact 1)
# ---------------------------------------------------------------------------

def sweep_minibatch(train, test, ms: Sequence[int], *, iters: int,
                    eval_every: int, gamma=0.1, lam=LAMBDA, key=None,
                    use_vmap=True) -> Dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n, d = X.shape
    m_max = max(ms)
    order = jax.random.randint(key, (iters, m_max), 0, n)
    n_evals = iters // eval_every

    def sim(m):
        active = (jnp.arange(m_max) < m).astype(jnp.float32)
        mf = m.astype(jnp.float32)

        def step(x, idx):
            Xb, yb = X[idx], y[idx]                  # (m_max, d), (m_max,)
            sig = jax.nn.sigmoid(-(yb * (Xb @ x)))
            g = -((sig * yb * active) @ Xb) / mf + lam * x
            return x - gamma * g, None

        def outer(x, e):
            idxs = jax.lax.dynamic_slice_in_dim(order, e * eval_every,
                                                eval_every, axis=0)
            x, _ = jax.lax.scan(step, x, idxs)
            return x, test_logloss(x, Xte, yte)

        _, losses = jax.lax.scan(outer, jnp.zeros((d,)), jnp.arange(n_evals))
        return losses

    losses = _run_grid(sim, ms, use_vmap)
    return _losses_dict("minibatch", ms, losses, iters, eval_every)


# ---------------------------------------------------------------------------
# ECD-PSGD (Alg 4): ring of m workers as a masked (m_max, m_max) mixing matrix
# ---------------------------------------------------------------------------

def _ring_matrix(m, m_max: int):
    """W with W[i] = (e_i + e_{i-1 mod m} + e_{i+1 mod m})/3 for i < m and
    identity rows for padded workers — the roll-based ring of ecd_psgd.py
    expressed so that m can be traced data."""
    ids = jnp.arange(m_max)
    eye = jnp.eye(m_max)
    W = (eye + eye[(ids - 1) % m] + eye[(ids + 1) % m]) / 3.0
    return jnp.where((ids < m)[:, None], W, eye)


def sweep_ecd_psgd(train, test, ms: Sequence[int], *, iters: int,
                   eval_every: int, gamma=0.1, lam=LAMBDA, compress_bits=8,
                   key=None, use_vmap=True) -> Dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n, d = X.shape
    m_max = max(ms)
    k_order, k_q = jax.random.split(key)
    order = jax.random.randint(k_order, (iters, m_max), 0, n)
    n_evals = iters // eval_every

    def sim(m):
        active = (jnp.arange(m_max) < m).astype(jnp.float32)
        mf = m.astype(jnp.float32)
        W = _ring_matrix(m, m_max)

        def one_iter(carry, inp):
            xs, ys = carry                   # (m_max, d) models / y-vars
            idx, kq, t = inp
            tf = t.astype(jnp.float32) + 1.0
            x_half = W @ ys                  # neighbors pull compressed y

            def grad_w(xi, i):
                sig = jax.nn.sigmoid(-(y[i] * jnp.dot(X[i], xi)))
                return -sig * y[i] * X[i] + lam * xi

            x_new = x_half - gamma * jax.vmap(grad_w)(xs, idx)
            # z = (1 - t/2) x_t + (t/2) x_{t+1};  y = (1-2/t) y + (2/t) C(z)
            z = (1.0 - tf / 2.0) * xs + (tf / 2.0) * x_new
            kqs = jax.random.split(kq, m_max)
            cz = jax.vmap(lambda zz, kk: dequantize(
                *quantize_stochastic(zz, kk, bits=compress_bits)))(z, kqs)
            y_new = (1.0 - 2.0 / tf) * ys + (2.0 / tf) * cz
            return (x_new, y_new), None

        def outer(carry, e):
            base = e * eval_every
            ts = base + jnp.arange(eval_every)
            keys = jax.vmap(lambda t: jax.random.fold_in(k_q, t))(ts)
            idxs = jax.lax.dynamic_slice_in_dim(order, base, eval_every,
                                                axis=0)
            carry, _ = jax.lax.scan(one_iter, carry, (idxs, keys, ts))
            x_avg = (active @ carry[0]) / mf      # mean over live workers
            return carry, test_logloss(x_avg, Xte, yte)

        carry0 = (jnp.zeros((m_max, d)), jnp.zeros((m_max, d)))
        _, losses = jax.lax.scan(outer, carry0, jnp.arange(n_evals))
        return losses

    losses = _run_grid(sim, ms, use_vmap)
    return _losses_dict("ecd_psgd", ms, losses, iters, eval_every)


# ---------------------------------------------------------------------------
# DADM (Alg 3): masked dual all-gather over the padded worker axis
# ---------------------------------------------------------------------------

def sweep_dadm(train, test, ms: Sequence[int], *, iters: int, eval_every: int,
               local_batch=8, lam=LAMBDA, key=None, use_vmap=True) -> Dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    X, y, Xte, yte = train.X, train.y, test.X, test.y
    n, d = X.shape
    m_max = max(ms)
    order = jax.random.randint(key, (iters, m_max, local_batch), 0, n)
    sq_norms = jnp.sum(X * X, axis=1)
    step_sz = jnp.minimum(1.0, (lam * n) / (sq_norms / 4.0 + lam * n))
    n_evals = iters // eval_every

    def sim(m):
        active = (jnp.arange(m_max) < m).astype(jnp.float32)

        def one_iter(carry, idx):
            alpha, v = carry                 # (n,), (d,)
            x = v

            def worker(idx_w):
                Xi, yi, ai = X[idx_w], y[idx_w], alpha[idx_w]
                p = jax.nn.sigmoid(-(yi * (Xi @ x)))
                da = (p - ai) * step_sz[idx_w]
                dv = (yi * da) @ Xi / (lam * n)
                return da, dv

            das, dvs = jax.vmap(worker)(idx)         # (m_max, lb), (m_max, d)
            das = das * active[:, None]              # padded workers sit out
            alpha = alpha.at[idx.reshape(-1)].add(das.reshape(-1))
            v = v + active @ dvs                     # masked all-gather sum
            return (alpha, v), None

        alpha0 = jnp.full((n,), 0.5)
        v0 = (y * alpha0) @ X / (lam * n)

        def outer(carry, e):
            idxs = jax.lax.dynamic_slice_in_dim(order, e * eval_every,
                                                eval_every, axis=0)
            carry, _ = jax.lax.scan(one_iter, carry, idxs)
            return carry, test_logloss(carry[1], Xte, yte)

        _, losses = jax.lax.scan(outer, (alpha0, v0), jnp.arange(n_evals))
        return losses

    losses = _run_grid(sim, ms, use_vmap)
    return _losses_dict("dadm", ms, losses, iters, eval_every)


# ---------------------------------------------------------------------------
# Hogwild! — sequential path (see module docstring)
# ---------------------------------------------------------------------------

def sweep_hogwild(train, test, ms: Sequence[int], *, iters: int,
                  eval_every: int, gamma=0.1, lam=LAMBDA, key=None,
                  use_vmap=True) -> Dict:
    del use_vmap                 # accepted for interface symmetry only
    curves = []
    for m in ms:
        r = run_hogwild(train, test, m=int(m), iters=iters, gamma=gamma,
                        lam=lam, eval_every=eval_every, key=key)
        curves.append(r["losses"])
    return _losses_dict("hogwild", ms, jnp.stack(
        [jnp.asarray(c) for c in curves]), iters, eval_every)


SWEEPERS = {
    "minibatch": sweep_minibatch,
    "ecd_psgd": sweep_ecd_psgd,
    "dadm": sweep_dadm,
    "hogwild": sweep_hogwild,
}


def run_algorithm_sweep(algorithm: str, train, test, ms, *, iters,
                        eval_every, use_vmap=True, **kwargs) -> Dict:
    """Dispatch one (algorithm, dataset) job over the worker grid."""
    try:
        fn = SWEEPERS[algorithm]
    except KeyError:
        raise KeyError(f"unknown algorithm {algorithm!r}; "
                       f"known: {sorted(SWEEPERS)}") from None
    return fn(train, test, list(ms), iters=iters, eval_every=eval_every,
              use_vmap=use_vmap, **kwargs)
