"""Generic batched m-sweep engine: one vmapped path over the worker axis,
dispatching through the `Algorithm` x `Problem` registries.

The legacy benchmarks re-ran each algorithm once per worker count m in a
Python loop — S separate traces, S compilations, S dispatch chains.
ENGINE_VERSION 2 re-derived each of the paper's four algorithms as a
*masked, padded* simulation over a fixed worker axis of size ``m_pad`` in
which the actual worker count m is ordinary traced data — but as four
hand-written sweepers with a hardcoded logistic loss.  ENGINE_VERSION 3
keeps that one-trace machinery and makes it *generic*: :func:`sweep` builds
the masked simulation for ANY registered `repro.core.algorithms.base.
Algorithm` on ANY registered `repro.core.problems.Problem`, so new
optimizers and objectives run through the full grid, cache, and CLI with
zero edits here.

The masked-simulation contract (unchanged from ENGINE_VERSION 2):

  * workers with index >= m are masked out of every reduction (gradient
    average, ring average, dual all-gather), so the padded run is
    numerically the m-worker run;
  * all random draws (`Algorithm.make_draws`) are made once at the *global*
    ``m_top = max(ms)`` and sliced per padding width — sweep member m
    consumes the first m columns no matter which bucket it lands in, so
    numerics are identical across flat / bucketed / sequential execution;
  * each bucket of the grid then runs as ``jax.vmap(sim)(ms_bucket)`` —
    one trace, one compile, one `lax.scan` pipeline per bucket.

**Bucketed padding** (`_buckets`): a flat padded grid does S * work(m_top)
FLOPs, so wide grids like [1, 2, 4, ..., 64] pay work(64) for the m=1
member.  `_run_grid` instead partitions the grid greedily into buckets
whose pad waste is bounded — ``max(bucket) <= MAX_PAD_RATIO * min(bucket)``
(default 2x) — and vmaps each bucket at its own ``m_pad``.  The trade is
one extra compile per bucket against the padded FLOPs, so bucketing pays
exactly when per-step work scales with the worker axis; each Algorithm
declares its own policy (``bucketed_default``: on for mini-batch and
ECD-PSGD, off for DADM) and ``force_flat`` algorithms (Hogwild!, whose
work is O(iters * d) regardless of the pad width) always run as one flat
vmap.  ``bucketed=False`` recovers the flat grid everywhere.

``use_vmap=False`` runs the *same* masked kernel (padded to m_top) once
per m in a Python loop — the sequential reference path the equivalence
tests compare against.  The per-algorithm ``sweep_*`` wrappers keep the
ENGINE_VERSION-2 signatures; for Hogwild! the sequential path still loops
the legacy per-m `run_hogwild`, so the vmapped grid is checked against the
original staleness recurrence, not against another padded kernel.

**Seed axis** (ENGINE_VERSION 4): ``n_seeds > 1`` replicates every job
over independent draw sequences — `Algorithm.make_draws` is called once
per seed (seed 0 with the caller's key, bit-identical to the
ENGINE_VERSION-3 single-seed run; seed s with ``fold_in(key, s)``), the
per-seed draws are stacked, and the per-m simulation is ``jax.vmap``-ed
over that stacked axis *inside* ``sim(m)``.  The m-grid vmap then wraps
the seed vmap, so the whole (seeds x m) grid is still ONE trace and ONE
compile per bucket — no per-seed recompiles (`scripts/bench_engine.py`
measures this via `JIT_CALLS` in BENCH_5.json).  Results keep ``losses``
as the seed-0 rows (every legacy consumer unchanged) and add
``losses_seeds`` — the full (S, n_seeds, n_evals) block `repro.analysis.
stats` turns into mean/CI curves and bootstrap m_max distributions.

**Device-mesh sharding** (ENGINE_VERSION 5): ``mesh=`` hands each
bucket's batched simulation to `repro.distributed.partition`, which
flattens the (members x seeds) cells into one element axis, pads it to
the device count, and dispatches ONE jitted vmap whose inputs are laid
over the mesh — XLA then splits the batch across devices.  Because the
cells are independent, results are **mesh-invariant** (1e-5 contract,
tests/test_distributed.py) and cache fingerprints exclude the mesh
entirely.  ``mesh=None`` (every existing caller) and single-device
meshes take the exact unsharded path below — the single-device fallback
is bit-exact with ENGINE_VERSION 4.  The sequential reference path
(``use_vmap=False``) never shards.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import problems as problems_mod
from repro.core.algorithms import base as alg_base
from repro.core.algorithms import run_hogwild
from repro.core.algorithms.lr import LAMBDA
from repro.distributed import mesh as dist_mesh
from repro.distributed import partition as dist_partition
from repro.telemetry import instrument, metrics, recorder, trace

#: Pad-waste bound for `_buckets`: within a bucket, the padded worker axis
#: is at most this multiple of the smallest member.
MAX_PAD_RATIO = 2.0

#: Counts `jax.jit` wrappers actually dispatched by `_run_grid` — each one
#: is traced and compiled exactly once here, so this is the engine's
#: compile count.  Registry-backed (PR 9): increments are locked so the
#: multi-threaded service counts exactly; the module-level ``JIT_CALLS``
#: read (`scripts/bench_engine.py` snapshots, tests) stays source-
#: compatible via ``__getattr__`` below.
_JIT_CALLS = metrics.counter(
    "repro_engine_jit_compiles_total",
    help="jax.jit wrappers dispatched by the engine (one XLA compile each)")

#: Fraction of the last vmapped grid's padded worker-axis FLOPs that were
#: padding waste: 1 - sum(m) / sum(m_pad per member).  0 for a perfectly
#: bucketed grid, approaching (1 - 1/MAX_PAD_RATIO) at the bound.
_PAD_WASTE = metrics.gauge(
    "repro_engine_pad_waste_ratio",
    help="pad-waste fraction of the last grid: 1 - sum(m)/sum(m_pad)")


def __getattr__(name):
    # PEP 562 read alias: `engine.JIT_CALLS` was a racy module global;
    # every external usage is a read, so it now reflects the registry
    # counter (writes go through `_JIT_CALLS.inc()`).
    if name == "JIT_CALLS":
        return _JIT_CALLS.value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _jit(fn):
    _JIT_CALLS.inc()
    return jax.jit(fn)


def _note_pad_waste(assignments) -> None:
    """Record the grid's pad waste from ``(m, m_pad)`` member pairs."""
    total = sum(pad for _, pad in assignments)
    if total:
        waste = 1.0 - sum(m for m, _ in assignments) / total
        _PAD_WASTE.set(waste)
        recorder.publish("grid", members=len(assignments),
                         pad_waste=round(waste, 4))


def _losses_dict(algorithm: str, ms, losses, iters: int, eval_every: int,
                 problem: str = "logistic", n_seeds: int = 1):
    """Engine output contract: curves for every m of the grid.  The
    ``problem`` key is new in ENGINE_VERSION 3, ``n_seeds``/``losses_seeds``
    in ENGINE_VERSION 4 (both additive — legacy keys are unchanged;
    ``losses`` is always the seed-0 rows)."""
    losses = jax.device_get(losses)
    out = {
        "algorithm": algorithm,
        "problem": problem,
        "ms": [int(m) for m in ms],
        "iters": int(iters),
        "eval_every": int(eval_every),
        "n_seeds": int(n_seeds),
    }
    if n_seeds == 1:
        # (S, n_evals) float list-of-lists, row i <-> ms[i]
        out["losses"] = [[float(v) for v in row] for row in losses]
    else:
        # losses: (S, n_seeds, n_evals); seed 0 is the legacy sequence
        out["losses"] = [[float(v) for v in row[0]] for row in losses]
        out["losses_seeds"] = [[[float(v) for v in curve] for curve in row]
                               for row in losses]
    return out


def _buckets(ms: Sequence[int],
             max_pad_ratio: float = MAX_PAD_RATIO
             ) -> List[Tuple[Tuple[int, ...], int]]:
    """Greedy waste-bounded partition of the m-grid.

    Returns ``[(positions, m_pad), ...]`` where ``positions`` index into
    ``ms`` and ``m_pad = max(ms[i] for i in positions)``.  Scanning the
    grid in ascending order, a member opens a new bucket whenever it would
    exceed ``max_pad_ratio *`` the bucket's smallest m — so no member is
    ever padded past that ratio, bounding the wasted FLOPs of the padded
    vmap at ``max_pad_ratio``x per member.
    """
    order = sorted(range(len(ms)), key=lambda i: ms[i])
    out: List[Tuple[Tuple[int, ...], int]] = []
    cur: List[int] = []
    for i in order:
        if cur and ms[i] > max_pad_ratio * ms[cur[0]]:
            out.append((tuple(cur), ms[cur[-1]]))
            cur = []
        cur.append(i)
    if cur:
        out.append((tuple(cur), ms[cur[-1]]))
    return out


def _run_grid(make_sim, ms, use_vmap: bool, bucketed: bool = True):
    """Run ``sim = make_sim(m_pad)`` over the grid; rows follow ``ms`` order.

    ``make_sim(m_pad)`` must return a closure ``sim(m) -> (n_evals,)`` that
    is numerically independent of ``m_pad`` for any ``m <= m_pad`` (shared
    draws sliced, reductions masked) — that contract is what makes the
    three execution modes here interchangeable.
    """
    m_top = max(ms)
    if not use_vmap:
        _note_pad_waste([(m, m_top) for m in ms])
        jsim = _jit(make_sim(m_top))      # one compile serves every m
        return jnp.stack([
            instrument.timed_call(jsim, m, span_name="grid_member",
                                  m=int(m), m_pad=m_top)
            for m in jnp.asarray(ms, jnp.int32)])
    if not bucketed:
        _note_pad_waste([(m, m_top) for m in ms])
        return instrument.dispatch(
            _jit(jax.vmap(make_sim(m_top))), jnp.asarray(ms, jnp.int32),
            span_name="bucket", m_pad=m_top, members=len(ms))
    buckets = _buckets(ms)
    _note_pad_waste([(ms[i], m_pad) for pos, m_pad in buckets for i in pos])
    rows = [None] * len(ms)
    for pos, m_pad in buckets:
        sub = jnp.asarray([ms[i] for i in pos], jnp.int32)
        out = instrument.dispatch(
            _jit(jax.vmap(make_sim(m_pad))), sub,
            span_name="bucket", m_pad=m_pad, members=len(pos))
        for k, i in enumerate(pos):
            rows[i] = out[k]
    return jnp.stack(rows)


# ---------------------------------------------------------------------------
# The generic sweep: any registered Algorithm on any registered Problem
# ---------------------------------------------------------------------------

def sweep(algorithm: Union[str, alg_base.Algorithm], train, test,
          ms: Sequence[int], *, iters: int, eval_every: int,
          problem="logistic", lam: Optional[float] = None, key=None,
          use_vmap: bool = True, bucketed: Optional[bool] = None,
          n_seeds: int = 1, mesh: "dist_mesh.MeshLike" = None,
          **alg_kwargs) -> Dict:
    """Run ``algorithm`` on ``problem`` over the worker grid ``ms``.

    ``algorithm`` is a registry name (instantiated with ``alg_kwargs``,
    e.g. ``gamma=0.05``) or a ready `Algorithm` instance; ``problem`` a
    registry name / class / instance (``lam`` overrides its regularizer,
    preserving the legacy ``lam=`` kwarg).  ``bucketed=None`` defers to the
    algorithm's declared padding policy.  ``n_seeds > 1`` replicates every
    grid member over that many independent draw sequences, vmapped inside
    the same trace (seed 0 == the single-seed run bit-exactly).

    ``mesh`` shards each bucket's batched simulation over a device mesh
    (`repro.distributed`): ``None`` keeps the unsharded path, an int /
    ``"auto"`` / `DeviceMesh` resolves via `repro.distributed.get_mesh`.
    Execution-only: results are mesh-invariant at 1e-5 and a
    single-device mesh is bit-exact with ``mesh=None``.
    """
    if isinstance(algorithm, alg_base.Algorithm):
        if alg_kwargs:
            raise TypeError("pass algorithm kwargs either via the instance "
                            "or via **alg_kwargs, not both")
        alg = algorithm
    else:
        alg = alg_base.get_algorithm(algorithm)(**alg_kwargs)
    prob = problems_mod.resolve_problem(problem, lam)
    key = key if key is not None else jax.random.PRNGKey(0)
    if n_seeds < 1:
        raise ValueError(f"n_seeds={n_seeds} must be >= 1")

    ms = list(ms)
    m_top = max(ms)
    n = train.X.shape[0]
    Xte, yte = test.X, test.y
    n_evals = iters // eval_every
    # seed 0 uses the caller's key unchanged — the ENGINE_VERSION-3 draws
    # bit-exactly — and seed s folds s into it, so growing n_seeds only
    # appends replicates, never perturbs existing ones
    seed_keys = [key] + [jax.random.fold_in(key, s)
                         for s in range(1, n_seeds)]
    draws_by_seed = [alg.make_draws(k, n, iters, m_top) for k in seed_keys]

    def make_sim_with(m_pad):
        def sim_with(sub):
            def sim(m):
                ctx = alg_base.SimContext(m, m_pad)
                state0 = alg.init_state(prob, train, ctx)

                def step(state, inp):
                    batch, t = inp
                    return alg.step(prob, train, ctx, state, batch, t), None

                def outer(state, e):
                    base = e * eval_every
                    ts = base + jnp.arange(eval_every)
                    bsl = jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(
                        a, base, eval_every, axis=0), sub)
                    state, _ = jax.lax.scan(step, state, (bsl, ts))
                    return state, prob.test_loss(alg.readout(ctx, state),
                                                 Xte, yte)

                _, losses = jax.lax.scan(outer, state0, jnp.arange(n_evals))
                return losses

            return sim

        return sim_with

    def make_sim(m_pad):
        sim_with = make_sim_with(m_pad)
        subs = [alg.slice_draws(d, m_pad) for d in draws_by_seed]

        if n_seeds == 1:
            return sim_with(subs[0])       # the exact ENGINE_VERSION-3 path

        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *subs)

        def sim_seeded(m):
            # vmap the per-seed simulation over the stacked draw axis: the
            # m-grid vmap in `_run_grid` wraps this, so the whole
            # (seeds x m) block is one trace / one compile per bucket
            return jax.vmap(lambda sub: sim_with(sub)(m))(stacked)

        return sim_seeded

    def make_sim_elem(m_pad):
        # distributed twin of `make_sim`: one simulation per (m, seed)
        # cell, with the seed's draws gathered by the traced index — the
        # partitioner vmaps this over a flat element axis laid across the
        # mesh, so the seed axis shards exactly like the grid axis
        sim_with = make_sim_with(m_pad)
        subs = [alg.slice_draws(d, m_pad) for d in draws_by_seed]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *subs)

        def sim_elem(m, s):
            sub = jax.tree.map(lambda a: a[s], stacked)
            return sim_with(sub)(m)

        return sim_elem

    if bucketed is None:
        bucketed = alg.bucketed_default
    if alg.force_flat:
        bucketed = False
    dmesh = dist_mesh.resolve(mesh)
    with trace.span("grid", algorithm=alg.name, problem=prob.name,
                    members=len(ms), n_seeds=n_seeds):
        if dmesh is not None and dmesh.n_devices > 1 and use_vmap:
            buckets = (_buckets(ms) if bucketed
                       else [(tuple(range(len(ms))), m_top)])
            _note_pad_waste([(ms[i], m_pad)
                             for pos, m_pad in buckets for i in pos])
            losses = dist_partition.run_grid_sharded(
                make_sim_elem, ms, n_seeds, dmesh, buckets, jit_fn=_jit)
        else:
            losses = _run_grid(make_sim, ms, use_vmap, bucketed)
    return _losses_dict(alg.name, ms, losses, iters, eval_every,
                        problem=prob.name, n_seeds=n_seeds)


def run_algorithm_sweep(algorithm: str, train, test, ms, *, iters,
                        eval_every, use_vmap=True, bucketed=None,
                        n_seeds=1, mesh=None, **kwargs) -> Dict:
    """Dispatch one (algorithm, problem, dataset) job over the worker grid.

    Every registered algorithm routes through the generic :func:`sweep`;
    the four paper algorithms go via their ``sweep_*`` compatibility
    wrappers (which only add the legacy Hogwild! sequential reference
    path).  ``bucketed=None`` keeps each algorithm's declared default;
    ``mesh`` is the execution-only device mesh (see :func:`sweep`).
    """
    fn = SWEEPERS.get(algorithm)
    if fn is None:
        return sweep(algorithm, train, test, ms, iters=iters,
                     eval_every=eval_every, use_vmap=use_vmap,
                     bucketed=bucketed, n_seeds=n_seeds, mesh=mesh,
                     **kwargs)
    if bucketed is not None:
        kwargs["bucketed"] = bucketed
    return fn(train, test, list(ms), iters=iters, eval_every=eval_every,
              use_vmap=use_vmap, n_seeds=n_seeds, mesh=mesh, **kwargs)


# ---------------------------------------------------------------------------
# ENGINE_VERSION-2 compatibility wrappers (same signatures and defaults)
# ---------------------------------------------------------------------------

def sweep_minibatch(train, test, ms: Sequence[int], *, iters: int,
                    eval_every: int, gamma=0.1, lam=LAMBDA, key=None,
                    use_vmap=True, bucketed=True, n_seeds=1,
                    problem="logistic", mesh=None) -> Dict:
    return sweep("minibatch", train, test, ms, iters=iters,
                 eval_every=eval_every, problem=problem, lam=lam, key=key,
                 use_vmap=use_vmap, bucketed=bucketed, n_seeds=n_seeds,
                 mesh=mesh, gamma=gamma)


def sweep_ecd_psgd(train, test, ms: Sequence[int], *, iters: int,
                   eval_every: int, gamma=0.1, lam=LAMBDA, compress_bits=8,
                   key=None, use_vmap=True, bucketed=True, n_seeds=1,
                   problem="logistic", mesh=None) -> Dict:
    return sweep("ecd_psgd", train, test, ms, iters=iters,
                 eval_every=eval_every, problem=problem, lam=lam, key=key,
                 use_vmap=use_vmap, bucketed=bucketed, n_seeds=n_seeds,
                 mesh=mesh, gamma=gamma, compress_bits=compress_bits)


def sweep_dadm(train, test, ms: Sequence[int], *, iters: int, eval_every: int,
               local_batch=8, lam=LAMBDA, key=None, use_vmap=True,
               bucketed=False, n_seeds=1, problem="logistic",
               mesh=None) -> Dict:
    return sweep("dadm", train, test, ms, iters=iters,
                 eval_every=eval_every, problem=problem, lam=lam, key=key,
                 use_vmap=use_vmap, bucketed=bucketed, n_seeds=n_seeds,
                 mesh=mesh, local_batch=local_batch)


def sweep_hogwild(train, test, ms: Sequence[int], *, iters: int,
                  eval_every: int, gamma=0.1, lam=LAMBDA, key=None,
                  use_vmap=True, bucketed=True, n_seeds=1,
                  problem="logistic", mesh=None, fault=None) -> Dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    if (fault is None and not use_vmap and problem == "logistic"
            and n_seeds == 1):
        # Legacy per-m reference path (re-jits per m): the vmapped grid is
        # equivalence-tested against this, i.e. against the original
        # recurrence rather than against another padded kernel.
        _JIT_CALLS.inc(len(ms))
        curves = []
        for m in ms:
            with trace.span("grid_member", m=int(m), legacy=True):
                curves.append(run_hogwild(
                    train, test, m=int(m), iters=iters, gamma=gamma,
                    lam=lam, eval_every=eval_every, key=key)["losses"])
        return _losses_dict("hogwild", ms,
                            jnp.stack([jnp.asarray(c) for c in curves]),
                            iters, eval_every)
    del bucketed   # force_flat: work is O(iters * d) regardless of m_pad
    return sweep("hogwild", train, test, ms, iters=iters,
                 eval_every=eval_every, problem=problem, lam=lam, key=key,
                 use_vmap=use_vmap, n_seeds=n_seeds, mesh=mesh, gamma=gamma,
                 fault=fault)


SWEEPERS = {
    "minibatch": sweep_minibatch,
    "ecd_psgd": sweep_ecd_psgd,
    "dadm": sweep_dadm,
    "hogwild": sweep_hogwild,
}
