"""Declarative sweep specifications (the `repro.experiments` input language).

A paper experiment is "run algorithm A on problem P over dataset D for
every worker count m in a grid, then read scalability off the convergence
curves".  A :class:`SweepSpec` captures that declaratively:

  * ``datasets``  — named :class:`DatasetSpec` entries, each a reference to
    a generator registered in `repro.data.synth.GENERATORS` plus its
    kwargs, an optional diversity ``variant``, and the train/valid split
    policy (LS-sequence specs keep sampling order, so no shuffle).
  * ``jobs``      — (algorithm, problem, dataset) cells with per-job
    algorithm kwargs and an optional theory-side prediction request.
    ``algorithm`` and ``problem`` name entries in the live registries
    (`repro.core.algorithms.base.ALGORITHMS` / `repro.core.problems.
    PROBLEMS`) — registering a new entry makes it spec-addressable with no
    engine edits.
  * ``ms``        — the worker-count grid shared by every job.
  * ``epsilon``   — optional cost readout: epsilon is the loss the
    ``probe_m``-worker run reaches after ``frac`` of its budget, and cost is
    iterations-per-worker to reach it (paper §V.B.1, Table II).
  * ``n_seeds``   — seed replicates per job: every curve is re-run under
    ``n_seeds`` independent draw sequences, vmapped inside the same single
    trace (seed 0 is the legacy sequence), feeding the `repro.analysis`
    statistics (mean/CI curves, bootstrap ``m_max`` distributions).

Specs are frozen, JSON-round-trippable (``to_dict`` / ``from_dict``) and
content-hashable (:func:`fingerprint`) — the fingerprint keys the on-disk
artifact cache and covers, besides the spec dict and ``ENGINE_VERSION``,
the *source* of every registry entry the spec references
(:func:`registry_signature`): editing a registered Algorithm, Problem, or
generator invalidates exactly the cached sweeps that used it.  Fields that
only steer *execution* — the ``devices`` mesh request — are excluded from
the fingerprint (`EXECUTION_ONLY_FIELDS`): results are mesh-invariant, so
the mesh must never split the cache.  Named paper specs live in
`repro.experiments.registry`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
from typing import Dict, Optional, Tuple, Union

import jax

from repro.core import problems as problems_mod
from repro.core.algorithms import base as alg_base
from repro.data import synth

# ENGINE_VERSION is hashed into every spec fingerprint (see `fingerprint`),
# which keys the on-disk artifact cache: bumping it orphans every cached
# sweep artifact at once, forcing recomputation under the new engine.  Bump
# it whenever engine *numerics* change — new kernels, different random-draw
# layout, changed readouts — never for pure refactors that keep curves
# bit-compatible.  Stale artifacts are never deleted, just unreachable.
#
#   1: PR-1 unified vmapped engine (Hogwild! sequential)
#   2: PR-2 one-trace grid: vmapped Hogwild!, bucketed m-padding, fused
#      dataset-characters pipeline (Pallas-routed C_sim / LS_sync)
#   3: PR-3 protocol engine: generic Algorithm x Problem dispatch, jobs
#      carry a `problem`, dataset characters always reported, registry
#      sources folded into the fingerprint
#   4: PR-4 seed axis: `SweepSpec.n_seeds` replicates every job over a seed
#      batch vmapped INSIDE the same single trace (seed 0 reproduces the
#      ENGINE_VERSION-3 draws bit-exactly; extra seeds fold the seed index
#      into the sweep key); results gain `n_seeds`/`losses_seeds`, consumed
#      by the `repro.analysis` statistics subsystem
#   5: PR-5 device-mesh sharded execution (`repro.distributed`): each
#      bucket's batched sim can be laid over every available XLA device.
#      The single-device path is bit-compatible with ENGINE_VERSION 4 and
#      multi-device execution is pinned mesh-invariant at 1e-5, but the
#      engine generation is bumped conservatively because curves may now
#      be produced under any mesh; the mesh itself NEVER enters the
#      fingerprint (`EXECUTION_ONLY_FIELDS`) — a sweep cached on 1 device
#      is a hit on 8
ENGINE_VERSION = 5

#: SweepSpec fields that steer *execution only* (where the sweep runs,
#: never what it computes).  `fingerprint` strips them, so they cannot
#: split the artifact cache; `cache.store` keeps them out of artifacts.
EXECUTION_ONLY_FIELDS = ("devices",)

#: Import-time snapshots for display / back-compat; validation always goes
#: through the live registries, so late registrations are fully usable.
ALGORITHMS = alg_base.registered_algorithms()
PROBLEMS = tuple(sorted(problems_mod.PROBLEMS))

#: Async algorithms divide server iterations among workers when costing
#: (paper §V.A.1 — the Perfect Computer Assumption).  Kept as a back-compat
#: view; the runner reads the Algorithm class's `asynchronous` flag.
ASYNC_ALGORITHMS = frozenset(
    name for name, cls in alg_base.ALGORITHMS.items() if cls.asynchronous)

#: Back-compat alias — the registry itself lives in `repro.data.synth`.
GENERATORS = synth.GENERATORS


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One named dataset of a sweep: generator + kwargs + split policy."""
    generator: str                       # key in synth.GENERATORS
    kwargs: Dict = dataclasses.field(default_factory=dict)
    seed: int = 0                        # PRNGKey for the generator
    shuffle_split: bool = True           # False: keep sampling-sequence order
    variant: Optional[str] = None        # diversity: "high" | "mid" | "low"

    def validate(self):
        synth.get_generator(self.generator)   # raises KeyError if unknown
        if self.variant not in (None, "high", "mid", "low"):
            raise ValueError(f"bad diversity variant {self.variant!r}")


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One (algorithm, problem, dataset) cell of the sweep grid."""
    algorithm: str                       # key in the Algorithm registry
    dataset: str                         # key into SweepSpec.datasets
    kwargs: Dict = dataclasses.field(default_factory=dict)  # e.g. gamma
    predict: bool = False                # run the theory-side m_max predictor
    predict_rows: int = 0                # rows of X fed to it (0 = all)
    problem: str = "logistic"            # key in the Problem registry
    #: disambiguator for specs that place the same (algorithm, problem,
    #: dataset) cell at several hyperparameter points (the critical_params
    #: knob grids); None keeps every legacy key byte-identical
    label: Optional[str] = None

    @property
    def key(self) -> str:
        # legacy "<algorithm>/<dataset>" for the paper's logistic jobs, so
        # every existing JSON/CSV consumer keeps its keys; non-default
        # problems are spelled out
        algo = (self.algorithm if self.label is None
                else f"{self.algorithm}[{self.label}]")
        if self.problem == "logistic":
            return f"{algo}/{self.dataset}"
        return f"{algo}+{self.problem}/{self.dataset}"

    def validate(self):
        alg_base.get_algorithm(self.algorithm)     # raises KeyError
        problems_mod.get_problem(self.problem)     # raises KeyError


@dataclasses.dataclass(frozen=True)
class EpsilonSpec:
    """Cost readout: eps = probe-run loss after ``frac`` of the budget."""
    probe_m: int = 2
    frac: float = 0.7


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    name: str
    description: str = ""
    ms: Tuple[int, ...] = (1, 2, 4, 8)
    iters: int = 1000
    eval_every: int = 100
    datasets: Dict[str, DatasetSpec] = dataclasses.field(default_factory=dict)
    jobs: Tuple[JobSpec, ...] = ()
    epsilon: Optional[EpsilonSpec] = None
    measure_csim: int = 0                # Eq. 3 range; 0 = skip
    csim_rows: int = 400                 # rows used for the C_sim estimate
    characters_rows: int = 0             # §IV summary rows; 0 = default cap
    split_seed: int = 0                  # key for shuffled splits
    n_seeds: int = 1                     # seed replicates per job (vmapped)
    #: EXECUTION-ONLY (never part of result identity — see
    #: EXECUTION_ONLY_FIELDS): device mesh request resolved by
    #: `repro.distributed.get_mesh` — None = unsharded, "auto" = every
    #: available XLA device, int = that many.  The CLI's ``--devices``
    #: overrides it per run without touching the spec.
    devices: Optional[Union[int, str]] = None

    # -- validation ---------------------------------------------------------
    def validate(self) -> "SweepSpec":
        if not self.jobs:
            raise ValueError(f"spec {self.name!r} has no jobs")
        if self.devices is not None and self.devices != "auto" and (
                not isinstance(self.devices, int) or self.devices < 1):
            raise ValueError(f"spec {self.name!r}: devices={self.devices!r} "
                             f"must be None, 'auto', or a positive int")
        if len(set(self.ms)) != len(self.ms) or any(m < 1 for m in self.ms):
            raise ValueError(f"spec {self.name!r}: bad worker grid {self.ms}")
        if self.iters < self.eval_every or self.eval_every < 1:
            raise ValueError(f"spec {self.name!r}: iters={self.iters} "
                             f"eval_every={self.eval_every}")
        if self.n_seeds < 1:
            raise ValueError(f"spec {self.name!r}: n_seeds={self.n_seeds} "
                             f"must be >= 1")
        if self.epsilon is not None:
            if self.epsilon.probe_m not in self.ms:
                raise ValueError(
                    f"spec {self.name!r}: epsilon probe_m="
                    f"{self.epsilon.probe_m} must be in ms={self.ms}")
            if not 0.0 < self.epsilon.frac < 1.0:
                raise ValueError(f"spec {self.name!r}: epsilon frac="
                                 f"{self.epsilon.frac} must be in (0, 1)")
        for ds in self.datasets.values():
            ds.validate()
        for job in self.jobs:
            job.validate()
            if job.dataset not in self.datasets:
                raise KeyError(f"job {job.key!r} references unknown dataset")
        keys = [job.key for job in self.jobs]
        if len(set(keys)) != len(keys):
            dupes = sorted({k for k in keys if keys.count(k) > 1})
            raise ValueError(
                f"spec {self.name!r}: duplicate job keys {dupes} — jobs "
                f"sharing a (algorithm, problem, dataset) cell need "
                f"distinct JobSpec.label values")
        return self

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "SweepSpec":
        d = dict(d)
        d["ms"] = tuple(d["ms"])
        d["datasets"] = {k: DatasetSpec(**v) for k, v in d["datasets"].items()}
        d["jobs"] = tuple(JobSpec(**j) for j in d["jobs"])
        if d.get("epsilon") is not None:
            d["epsilon"] = EpsilonSpec(**d["epsilon"])
        return cls(**d).validate()


def _source_token(obj) -> str:
    """Stable-ish content token for a registered callable/class: a hash of
    its source (falls back to the qualified name for sourceless objects,
    e.g. classes defined in a REPL)."""
    try:
        src = inspect.getsource(obj)
    except (OSError, TypeError):
        src = getattr(obj, "__qualname__", repr(obj))
    return hashlib.sha256(src.encode()).hexdigest()[:16]


def registry_signature(spec: SweepSpec) -> Dict[str, str]:
    """Source tokens for every registry entry the spec references — part of
    the cache fingerprint, so editing (or re-registering) an Algorithm,
    Problem, or generator invalidates exactly the sweeps that used it.

    Wrapper generators that delegate to another registered generator name
    it via a ``base`` kwarg (e.g. ``label_noise``); the base's source is
    folded in too, so editing the base orphans the wrapper's sweeps."""
    sig = {}
    for job in spec.jobs:
        sig[f"algorithm:{job.algorithm}"] = _source_token(
            alg_base.get_algorithm(job.algorithm))
        sig[f"problem:{job.problem}"] = _source_token(
            problems_mod.get_problem(job.problem))
    for ds in spec.datasets.values():
        name, kwargs = ds.generator, ds.kwargs
        while f"generator:{name}" not in sig:
            sig[f"generator:{name}"] = _source_token(
                synth.get_generator(name))
            base = kwargs.get("base") if isinstance(kwargs, dict) else None
            if not (isinstance(base, str) and base in synth.GENERATORS):
                break
            name, kwargs = base, {}
    return sig


def computational_dict(spec: SweepSpec) -> Dict:
    """``spec.to_dict()`` minus `EXECUTION_ONLY_FIELDS` — the dict that
    describes *what* a sweep computes, with no trace of where it runs.
    Both the fingerprint and the persisted artifact's ``spec`` entry use
    this one helper, keeping the two byte-consistent by construction."""
    d = spec.to_dict()
    for field in EXECUTION_ONLY_FIELDS:
        d.pop(field, None)
    # an unset job label is identity-neutral: dropping it keeps every
    # pre-label spec's fingerprint (and cached artifact) byte-identical
    for job in d["jobs"]:
        if job.get("label") is None:
            job.pop("label", None)
    return d


def fingerprint(spec: SweepSpec) -> str:
    """Content hash of a spec (plus the engine version and the sources of
    the registry entries it references) — the cache key.

    Hashes `computational_dict`, i.e. execution-only fields (``devices``)
    never enter: *where* a sweep runs never changes *what* it computes
    (the mesh-invariance contract, docs/distributed.md), so a sweep cached
    on one mesh is a hit on any other."""
    payload = json.dumps({"engine_version": ENGINE_VERSION,
                          "registries": registry_signature(spec),
                          "spec": computational_dict(spec)},
                         sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()


def build_dataset(ds: DatasetSpec) -> synth.Dataset:
    """Materialize a DatasetSpec into a concrete `synth.Dataset`."""
    ds.validate()
    key = jax.random.PRNGKey(ds.seed)
    base = synth.get_generator(ds.generator)(key, **ds.kwargs)
    if ds.variant is not None:
        high, mid, low = synth.make_diversity_variants(base)
        base = {"high": high, "mid": mid, "low": low}[ds.variant]
    return base


def split_dataset(ds_spec: DatasetSpec, data: synth.Dataset, split_seed: int):
    """70/20 split per the spec's policy (shuffled unless sequence-ordered;
    the 10% held-out test tail stays untouched, see `Dataset.split`)."""
    if ds_spec.shuffle_split:
        return data.split(key=jax.random.PRNGKey(split_seed))
    return data.split()
