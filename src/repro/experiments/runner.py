"""Sweep runner: SweepSpec -> datasets -> generic engine -> scalability.

`run_sweep` is the one entry point every benchmark, example, and the CLI
share.  For each job it

  1. materializes the job's dataset (`spec.build_dataset`) and splits it
     70/20 per the spec's shuffle policy,
  2. runs the worker-count grid through `engine.run_algorithm_sweep`,
     which dispatches through the Algorithm x Problem registries (any
     registered pair runs with zero edits here),
  3. if the spec declares an epsilon readout, derives epsilon from the
     probe-m curve, converts curves to per-worker costs (§V.A.1; whether
     costs divide by m is the Algorithm class's `asynchronous` flag), and
     computes gain growth + the measured upper bound m_max (§V.B),
  4. if the job requests it, runs the theory-side predictor selected by
     the Algorithm class's `predictor` kind on the raw dataset characters,
     yielding the measured-vs-predicted m_max comparison the paper is
     about.

Every dataset self-reports its measured §IV characters (variance,
sparsity, diversity, LS) into ``result["datasets"][name]["characters"]``
— capped at `DEFAULT_CHARACTERS_ROWS` rows unless the spec asks for more
via ``characters_rows``.

Specs with ``n_seeds > 1`` replicate every curve over a vmapped seed
batch (see `engine.sweep`); the scalar epsilon/cost/m_max readouts here
stay seed-0 (every legacy key is unchanged) and the full per-seed block
lands in ``job["losses_seeds"]`` — `repro.analysis.stats` turns it into
mean/CI curves, seed-replicated costs, and bootstrap m_max
distributions.

Results are plain JSON-serializable dicts (curves as a row-per-m list of
lists; use `curves_by_m` for {m: curve} access) and are stored in the
content-hashed artifact cache — re-running an unchanged spec is a disk
read.  The fresh/cached distinction is reported in ``result["cache"]``
and the resolved device mesh in ``result["execution"]``; both are
attached after loading and never persisted (`cache.VOLATILE_KEYS`), so
artifacts are byte-identical whichever mesh computed them.

Fault tolerance (docs/robustness.md): every finished job is appended to a
crash journal (`repro.resilience.journal`) next to the artifact, so a
sweep killed mid-run resumes from the completed jobs and still produces a
byte-identical artifact; jobs that raise or diverge are retried with
backoff (``max_retries``) and carry a structured ``status`` field
("ok" / "retried:N" / "diverged" / "failed") instead of poisoning the
epsilon/cost/predictor readouts — unhealthy jobs keep their curves (or a
structured error stub) but are excluded from every derived quantity (see
`job_is_healthy`).
"""

from __future__ import annotations

import inspect
import math
import time
import warnings
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.analysis import fit as fit_mod
from repro.core import metrics as MX
from repro.core import scalability as SC
from repro.core.algorithms import base as alg_base
from repro.distributed import mesh as dist_mesh
from repro.experiments import cache as artifact_cache
from repro.experiments import engine
from repro.experiments import spec as spec_mod
from repro.experiments.spec import SweepSpec
from repro.resilience import journal as journal_mod
from repro.telemetry import metrics, trace
from repro.telemetry.recorder import publish as _flight

#: theory-side m_max predictor per Algorithm.predictor kind — the
#: vectorized `repro.analysis.fit` scans (the scalar while-loops in
#: `core.scalability` remain the parity oracles)
_PREDICTORS = {
    "hogwild": fit_mod.predict_hogwild_mmax,
    "sync": fit_mod.predict_sync_mmax,
    "dadm": fit_mod.predict_dadm_mmax,
    "momentum": fit_mod.predict_momentum_mmax,
    "local_sgd": fit_mod.predict_local_sgd_mmax,
    "svrg": fit_mod.predict_svrg_mmax,
}


def _predict(predictor: str, X, job_kwargs: Dict) -> Dict:
    """Run the theory-side predictor, forwarding exactly the job
    hyperparameters its signature accepts (momentum's beta, local SGD's
    sync_every, async-SVRG's anchor_every) — the critical-parameter specs
    sweep those knobs, and the prediction must move with them."""
    fn = _PREDICTORS[predictor]
    accepted = inspect.signature(fn).parameters
    hints = {k: v for k, v in job_kwargs.items() if k in accepted}
    return fn(X, **hints)

#: row cap for the always-on dataset-characters report (the §IV indices are
#: O(rows^2)-ish through the LS scans; specs override via characters_rows)
DEFAULT_CHARACTERS_ROWS = 512

#: process-wide count of sweeps actually *computed* (cache hits and
#: dedup-follower waits don't increment) — tests and the service bench
#: read it to prove single-flight dedup executes exactly one sweep.
#: Registry-backed (PR 9): increments are locked, so exact deltas hold
#: under the service's concurrent probes; the module-level
#: ``SWEEP_COMPUTES`` read stays source-compatible via ``__getattr__``.
_SWEEP_COMPUTES = metrics.counter(
    "repro_sweep_computes_total",
    help="sweeps actually computed (cache hits / dedup waits excluded)")
_DEDUP_LEADER = metrics.counter(
    "repro_sweep_dedup_leader_total",
    help="single-flight leases won (this caller computed for the group)")
_DEDUP_WAITER = metrics.counter(
    "repro_sweep_dedup_waiter_total",
    help="single-flight waits (this caller blocked on a leader's compute)")
_JOB_RETRIES = metrics.counter(
    "repro_sweep_job_retries_total",
    help="job attempts beyond the first (raised or non-finite curves)")
_JOURNAL_APPENDS = metrics.counter(
    "repro_journal_appends_total",
    help="finished jobs appended to a crash journal")
_JOURNAL_REPLAYS = metrics.counter(
    "repro_journal_replays_total",
    help="jobs replayed from a crash journal instead of recomputed")


def __getattr__(name):
    # PEP 562 read alias for the legacy module global (see engine.py)
    if name == "SWEEP_COMPUTES":
        return _SWEEP_COMPUTES.value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


#: process-wide single-flight table for `run_sweep(dedup=True)` callers
_INFLIGHT = artifact_cache.InFlightTable()


def curves_by_m(job_result: Dict) -> Dict[int, List[float]]:
    """{worker count: convergence curve} view of a job result."""
    return {int(m): list(row) for m, row in
            zip(job_result["ms"], job_result["losses"])}


def _epsilon_from_probe(job_result: Dict, eps_spec) -> float:
    """Paper Table II policy: epsilon is the loss the probe_m-worker run
    reaches after `frac` of its eval budget — reachable by every setting,
    discriminative between them."""
    curve = curves_by_m(job_result)[eps_spec.probe_m]
    # frac == 1.0 would index one past the end; clamp to the last eval
    idx = min(int(len(curve) * eps_spec.frac), len(curve) - 1)
    return float(curve[idx])


def job_is_healthy(job_result: Dict) -> bool:
    """True when the job's curves are trustworthy inputs for readouts,
    fits, and reports.  "ok" and "retried:N" (succeeded after transient
    failure) are healthy; "diverged" and "failed" are not.  Artifacts
    from before the status field default to healthy."""
    status = str(job_result.get("status", "ok"))
    return status == "ok" or status.startswith("retried")


def _finite(job_result: Dict) -> bool:
    return bool(np.isfinite(
        job_result.get("losses_seeds", job_result["losses"])).all())


def _run_job_with_retries(spec: SweepSpec, job, tr, te, dmesh, use_vmap: bool,
                          max_retries: int, retry_backoff_s: float,
                          verbose: bool):
    """Run one job with bounded retry-with-backoff; returns
    ``(job_result, status)``.  The engine is deterministic, so retries
    target transient infrastructure failures (OOM, interrupted device
    pools), not numerics — a curve that diverges twice is reported as
    "diverged" with its curves intact, and a job whose every attempt
    raised becomes a structured "failed" stub instead of killing the
    sweep."""
    last_exc: Optional[BaseException] = None
    jr: Optional[Dict] = None
    for attempt in range(max_retries + 1):
        if attempt:
            _JOB_RETRIES.inc()
            _flight("job_retried", sweep=spec.name, job=job.key,
                    attempt=attempt + 1)
            if retry_backoff_s > 0:
                time.sleep(retry_backoff_s * (2 ** (attempt - 1)))
        try:
            jr = engine.run_algorithm_sweep(
                job.algorithm, tr, te, spec.ms, iters=spec.iters,
                eval_every=spec.eval_every, use_vmap=use_vmap,
                problem=job.problem, n_seeds=spec.n_seeds, mesh=dmesh,
                **job.kwargs)
        except Exception as exc:  # noqa: BLE001 — one job must not kill the sweep
            last_exc = exc
            if verbose:
                print(f"[{spec.name}] {job.key}: attempt {attempt + 1} "
                      f"raised {type(exc).__name__}: {exc}")
            continue
        if _finite(jr):
            return jr, ("ok" if attempt == 0 else f"retried:{attempt}")
        if verbose:
            print(f"[{spec.name}] {job.key}: attempt {attempt + 1} "
                  f"produced non-finite curves")
    if jr is not None:
        return jr, "diverged"
    return ({"algorithm": job.algorithm, "problem": job.problem,
             "error": f"{type(last_exc).__name__}: {last_exc}"}, "failed")


def _cost_readout(job_result: Dict, epsilon: float, asynchronous: bool):
    iters = job_result["iters"]
    costs = []
    for m, losses in zip(job_result["ms"], job_result["losses"]):
        c = SC.cost_per_worker(
            {"losses": losses, "eval_every": job_result["eval_every"],
             "m": m}, epsilon, asynchronous=asynchronous)
        costs.append(float(c) if math.isfinite(c) else float(iters))
    gg = SC.gain_growth_from_costs(costs)
    bound = SC.measured_upper_bound(job_result["ms"][:-1], gg)
    return costs, gg, bound


def run_sweep(spec: SweepSpec, *, use_cache: bool = True, force: bool = False,
              cache_dir: Optional[str] = None, use_vmap: bool = True,
              verbose: bool = False, mesh: "dist_mesh.MeshLike" = None,
              journal: bool = True, max_retries: int = 1,
              retry_backoff_s: float = 0.25, dedup: bool = False,
              cache_cap: Optional[int] = None) -> Dict:
    """Execute (or fetch) the full sweep a spec describes.

    ``mesh`` (or, when absent, the spec's execution-only ``devices``
    field) shards every job's batched grid over a device mesh via
    `repro.distributed` — results and cache keys are mesh-invariant, so
    the mesh only changes where the arithmetic runs.  The resolved mesh
    is reported in ``result["execution"]`` (attached after load/store,
    never persisted — see `cache.VOLATILE_KEYS`).

    ``journal=True`` (with ``use_cache``) appends every finished job to a
    crash journal beside the artifact and, on a re-run after a crash,
    replays journaled jobs instead of recomputing them — the resumed
    artifact is byte-identical to an uninterrupted run's.  ``max_retries``
    bounds the retry-with-backoff loop for jobs that raise or produce
    non-finite curves (see `_run_job_with_retries`).

    ``dedup=True`` (with ``use_cache``) routes the call through a
    process-wide single-flight table: concurrent callers sharing this
    spec's fingerprint elect one *leader* that computes and stores the
    artifact while the rest block, then load the leader's bytes from the
    cache — N identical concurrent requests execute exactly one sweep
    (`SWEEP_COMPUTES` counts real executions; `repro.service` sets this
    for every escalation).  ``cache_cap`` forwards to
    `cache.store(max_artifacts=...)` for LRU-bounded artifact dirs.
    """
    spec.validate()
    cache_dir = cache_dir or artifact_cache.DEFAULT_CACHE_DIR
    fp = spec_mod.fingerprint(spec)

    leased = False
    while use_cache and not force:
        hit = artifact_cache.load(cache_dir, spec.name, fp)
        if hit is not None:
            if leased:
                _INFLIGHT.release(fp)
            hit["cache"] = {"hit": True,
                            "path": artifact_cache.artifact_path(
                                cache_dir, spec.name, fp)}
            # a hit executes nothing, so the mesh request is never
            # resolved — an artifact cached elsewhere must serve even on
            # a host that cannot satisfy the spec's `devices` ask
            hit["execution"] = {"devices": len(jax.devices()),
                                "sharded": False,
                                "backend": jax.default_backend()}
            return hit
        if not dedup or leased:
            break
        if _INFLIGHT.lease(fp):
            # leader: re-check the cache once (a prior leader may have
            # stored between our miss and the lease), then compute
            leased = True
            _DEDUP_LEADER.inc()
            continue
        # follower: block until the leader releases, then re-check the
        # cache — on leader success that's a hit; on leader failure the
        # loop retries the lease (one follower takes over)
        _DEDUP_WAITER.inc()
        with trace.span("dedup_wait", fingerprint=fp[:12]):
            _INFLIGHT.wait(fp)

    try:
        return _compute_sweep(
            spec, fp, cache_dir, use_cache=use_cache, force=force,
            use_vmap=use_vmap, verbose=verbose, mesh=mesh, journal=journal,
            max_retries=max_retries, retry_backoff_s=retry_backoff_s,
            cache_cap=cache_cap)
    finally:
        if leased:
            # success or failure, wake every dedup waiter: on success
            # they hit the stored artifact; on failure one takes over
            _INFLIGHT.release(fp)


def _compute_sweep(spec: SweepSpec, fp: str, cache_dir: str, *,
                   use_cache: bool, force: bool, use_vmap: bool,
                   verbose: bool, mesh, journal: bool, max_retries: int,
                   retry_backoff_s: float,
                   cache_cap: Optional[int]) -> Dict:
    """The cache-miss path of `run_sweep`: journal replay, job execution,
    readouts, artifact store.  Split out so the dedup lease in
    `run_sweep` wraps exactly one compute in try/finally.  The whole
    compute runs under a root ``sweep`` span — its children (datasets,
    per-job grids, journal/cache IO) are the phase breakdown the report
    and ``--trace`` surface."""
    with trace.span("sweep", spec=spec.name, fingerprint=fp[:12],
                    jobs=len(spec.jobs)):
        return _compute_sweep_inner(
            spec, fp, cache_dir, use_cache=use_cache, force=force,
            use_vmap=use_vmap, verbose=verbose, mesh=mesh, journal=journal,
            max_retries=max_retries, retry_backoff_s=retry_backoff_s,
            cache_cap=cache_cap)


def _compute_sweep_inner(spec: SweepSpec, fp: str, cache_dir: str, *,
                         use_cache: bool, force: bool, use_vmap: bool,
                         verbose: bool, mesh, journal: bool,
                         max_retries: int, retry_backoff_s: float,
                         cache_cap: Optional[int]) -> Dict:
    _SWEEP_COMPUTES.inc()

    jpath = journal_mod.journal_path(cache_dir, spec.name, fp)
    journaled: Dict[str, Dict] = {}
    if use_cache and journal and not force:
        with trace.span("journal_read"):
            journaled = journal_mod.read_entries(jpath, fp)
        if verbose and journaled:
            print(f"[{spec.name}] resuming: {len(journaled)} job(s) "
                  f"replayed from crash journal {jpath}")

    # flight-recorder progress events (docs/observability.md): in-memory
    # only, so a mid-sweep GET /flight shows per-job progress without
    # touching the computation or the artifact bytes
    _flight("sweep_started", sweep=spec.name, fingerprint=fp[:12],
            jobs=len(spec.jobs), replayed=len(journaled))

    dmesh = dist_mesh.resolve(mesh if mesh is not None else spec.devices)
    execution = {
        "devices": dmesh.n_devices if dmesh is not None else 1,
        "sharded": dmesh is not None and dmesh.n_devices > 1 and use_vmap,
        "backend": jax.default_backend(),
    }

    # perf_counter is monotonic — wall-clock (time.time) steps under NTP
    # corrections and corrupted elapsed_s; the value is volatile
    # (cache.VOLATILE_KEYS) so the switch cannot change artifact bytes
    t0 = time.perf_counter()
    # the persisted spec dict is exactly the fingerprinted one: two
    # requests differing only in execution fields share a fingerprint,
    # so the artifact they race to write must be byte-identical too
    result: Dict = {"name": spec.name,
                    "spec": spec_mod.computational_dict(spec),
                    "datasets": {}, "jobs": {}}

    with trace.span("datasets", count=len(spec.datasets)):
        datasets = {name: spec_mod.build_dataset(ds)
                    for name, ds in spec.datasets.items()}
        splits = {name: spec_mod.split_dataset(spec.datasets[name], data,
                                               spec.split_seed)
                  for name, data in datasets.items()}

        for name, data in datasets.items():
            info: Dict = {"n": int(data.X.shape[0]),
                          "d": int(data.X.shape[1])}
            if spec.measure_csim > 0:
                info["csim"] = MX.csim(data.X[:spec.csim_rows],
                                       spec.measure_csim)
            # every dataset self-reports its §IV characters into the result
            rows = spec.characters_rows or DEFAULT_CHARACTERS_ROWS
            info["characters"] = MX.summarize(data.X[:rows])
            result["datasets"][name] = info

    for job in spec.jobs:
        if job.key in journaled:
            # crash-journal replay: the entry already carries readouts,
            # predictions, and status — a JSON round-trip of exactly what
            # an uninterrupted run would have put here
            if verbose:
                print(f"[{spec.name}] {job.key}: resumed from journal")
            _JOURNAL_REPLAYS.inc()
            _flight("job_replayed", sweep=spec.name, job=job.key)
            result["jobs"][job.key] = journaled[job.key]
            continue
        if verbose:
            print(f"[{spec.name}] sweep {job.key} over m={list(spec.ms)}")
        alg_cls = alg_base.get_algorithm(job.algorithm)
        tr, te = splits[job.dataset]
        _flight("job_started", sweep=spec.name, job=job.key,
                algorithm=job.algorithm, dataset=job.dataset)
        with trace.span("job", key=job.key, algorithm=job.algorithm,
                        dataset=job.dataset):
            jr, status = _run_job_with_retries(
                spec, job, tr, te, dmesh, use_vmap,
                max_retries, retry_backoff_s, verbose)
        jr["dataset"] = job.dataset
        jr["status"] = status
        if status != "ok":
            # "retried:N" -> job_retried already fired per attempt; the
            # terminal unhealthy states get their own event kinds
            if status in ("diverged", "failed"):
                _flight(f"job_{status}", sweep=spec.name, job=job.key)
        if status == "diverged":
            # usually a step size tuned for another objective's curvature
            # (e.g. logistic gamma on ridge); surface it loudly — the
            # curves are kept but every readout below skips this job
            warnings.warn(
                f"job {job.key!r}: non-finite loss curve — the step size "
                f"is likely unstable for problem {job.problem!r} on this "
                f"dataset; tune the job kwargs (see the problem_generality "
                f"spec for per-problem gammas)", RuntimeWarning,
                stacklevel=2)
        elif status == "failed":
            warnings.warn(
                f"job {job.key!r}: failed after {max_retries + 1} "
                f"attempt(s) — {jr['error']}; a structured stub is cached "
                f"in its place", RuntimeWarning, stacklevel=2)
        healthy = job_is_healthy(jr)

        with trace.span("readout", key=job.key):
            if spec.epsilon is not None and healthy:
                eps = _epsilon_from_probe(jr, spec.epsilon)
                costs, gg, bound = _cost_readout(
                    jr, eps, asynchronous=alg_cls.asynchronous)
                jr.update(epsilon=eps, costs=costs, gain_growth=gg,
                          measured_m_max=int(bound))

            if job.predict and healthy:
                X = datasets[job.dataset].X
                if job.predict_rows > 0:
                    X = X[:job.predict_rows]
                jr["predicted"] = _predict(alg_cls.predictor, X, job.kwargs)

        result["jobs"][job.key] = jr
        _flight("job_stored", sweep=spec.name, job=job.key, status=status,
                healthy=healthy)
        if use_cache and journal:
            with trace.span("journal_append", key=job.key):
                journal_mod.append_entry(jpath, fp, job.key, jr)
            _JOURNAL_APPENDS.inc()

    result["elapsed_s"] = time.perf_counter() - t0
    path = None
    if use_cache:
        with trace.span("store"):
            path = artifact_cache.store(cache_dir, spec.name, fp, result,
                                        max_artifacts=cache_cap)
            if journal:
                # the artifact now supersedes the journal
                journal_mod.consume(jpath)
    result["cache"] = {"hit": False, "path": path}
    result["execution"] = execution
    _flight("sweep_stored", sweep=spec.name, fingerprint=fp[:12],
            elapsed_s=round(result["elapsed_s"], 3), path=path)
    return result
