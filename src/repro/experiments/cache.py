"""Content-hashed on-disk artifact cache for sweep results.

Layout: ``<cache_dir>/<spec-name>-<fingerprint16>.json`` where the
fingerprint is `spec.fingerprint(spec)` — a sha256 over the canonical spec
dict plus ``ENGINE_VERSION``.  Any change to the spec (grid, iters, dataset
kwargs, epsilon policy, ...) or to the engine version lands on a different
file, so a hit is always safe to reuse and repeated sweeps are free.

Artifacts are **mesh-independent**: the fingerprint strips execution-only
spec fields (`spec.EXECUTION_ONLY_FIELDS`) and :func:`store` strips the
volatile per-run keys (`VOLATILE_KEYS`: the ``cache`` hit info and the
``execution`` mesh report the runner attaches) before writing — so a sweep
computed on an 8-device mesh writes the same artifact, under the same key,
as the single-device run, and either one serves the other's lookups
(tested in tests/test_distributed.py).

The default directory is ``results/sweep_cache`` (override with the
``REPRO_SWEEP_CACHE`` environment variable or the ``cache_dir`` argument).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Optional

DEFAULT_CACHE_DIR = os.environ.get(
    "REPRO_SWEEP_CACHE", os.path.join("results", "sweep_cache"))

#: result keys describing one concrete run, not the computation — never
#: persisted, re-attached fresh by the runner after every load/store
VOLATILE_KEYS = ("cache", "execution")


def artifact_path(cache_dir: str, name: str, fp: str) -> str:
    return os.path.join(cache_dir, f"{name}-{fp[:16]}.json")


def load(cache_dir: str, name: str, fp: str) -> Optional[Dict]:
    """Return the cached payload, or None on miss / unreadable artifact."""
    path = artifact_path(cache_dir, name, fp)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if payload.get("fingerprint") != fp:      # stale / truncated artifact
        return None
    return payload


def store(cache_dir: str, name: str, fp: str, payload: Dict) -> str:
    """Atomically write the payload; returns the artifact path.
    Volatile per-run keys (`VOLATILE_KEYS`) are stripped so the artifact
    bytes do not depend on which mesh computed them."""
    os.makedirs(cache_dir, exist_ok=True)
    path = artifact_path(cache_dir, name, fp)
    payload = {k: v for k, v in payload.items() if k not in VOLATILE_KEYS}
    payload["fingerprint"] = fp
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, default=float)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path
