"""Content-hashed on-disk artifact cache for sweep results.

Layout: ``<cache_dir>/<spec-name>-<fingerprint16>.json`` where the
fingerprint is `spec.fingerprint(spec)` — a sha256 over the canonical spec
dict plus ``ENGINE_VERSION``.  Any change to the spec (grid, iters, dataset
kwargs, epsilon policy, ...) or to the engine version lands on a different
file, so a hit is always safe to reuse and repeated sweeps are free.

Artifacts are **mesh-independent**: the fingerprint strips execution-only
spec fields (`spec.EXECUTION_ONLY_FIELDS`) and :func:`store` strips the
volatile per-run keys (`VOLATILE_KEYS`: the ``cache`` hit info, the
``execution`` mesh report, and the wall-clock ``elapsed_s`` the runner
attaches) before writing — so a sweep computed on an 8-device mesh writes
the same artifact, byte for byte, as the single-device run (and as a
journal-resumed run, see docs/robustness.md), and either one serves the
other's lookups (tested in tests/test_distributed.py).

**Integrity** (docs/robustness.md): :func:`store` embeds a sha256
``checksum`` of the canonical payload serialization; :func:`load`
verifies it and **quarantines** artifacts that fail — bit-rotted or
hand-mutated files are renamed to ``<path>.corrupt`` with a warning
instead of being silently treated as a cache miss (or worse, served).
Pre-checksum artifacts (no ``checksum`` key) still load unverified, so
existing caches keep serving.

The default directory is ``results/sweep_cache`` (override with the
``REPRO_SWEEP_CACHE`` environment variable or the ``cache_dir`` argument).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import warnings
from typing import Dict, Optional

DEFAULT_CACHE_DIR = os.environ.get(
    "REPRO_SWEEP_CACHE", os.path.join("results", "sweep_cache"))

#: result keys describing one concrete run, not the computation — never
#: persisted, re-attached fresh by the runner after every load/store
VOLATILE_KEYS = ("cache", "execution", "elapsed_s")


def artifact_path(cache_dir: str, name: str, fp: str) -> str:
    return os.path.join(cache_dir, f"{name}-{fp[:16]}.json")


def _payload_checksum(payload: Dict) -> str:
    """sha256 of the canonical (sorted-key) serialization, ``checksum``
    excluded.  JSON floats round-trip via shortest repr, so a parsed
    payload re-serializes to the same canonical bytes — verification
    after `json.load` is exact."""
    body = {k: v for k, v in payload.items() if k != "checksum"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True, default=float).encode()).hexdigest()


def _quarantine(path: str, reason: str) -> None:
    corrupt = path + ".corrupt"
    try:
        os.replace(path, corrupt)
    except OSError:
        corrupt = path                      # couldn't move; report in place
    warnings.warn(
        f"sweep artifact {path} failed integrity verification ({reason}); "
        f"quarantined to {corrupt} — the sweep will recompute",
        RuntimeWarning, stacklevel=3)


def load(cache_dir: str, name: str, fp: str) -> Optional[Dict]:
    """Return the cached payload, or None on miss.  Unparsable or
    checksum-mismatching artifacts are quarantined (see module docs)."""
    path = artifact_path(cache_dir, name, fp)
    try:
        with open(path) as f:
            raw = f.read()
    except OSError:
        return None
    try:
        payload = json.loads(raw)
    except json.JSONDecodeError:
        _quarantine(path, "not parseable as JSON — truncated write?")
        return None
    if payload.get("fingerprint") != fp:      # foreign / stale artifact
        return None
    if "checksum" in payload and (
            payload["checksum"] != _payload_checksum(payload)):
        _quarantine(path, "payload checksum mismatch — bit rot or a "
                          "hand-edited artifact")
        return None
    return payload


def store(cache_dir: str, name: str, fp: str, payload: Dict) -> str:
    """Atomically write the payload; returns the artifact path.
    Volatile per-run keys (`VOLATILE_KEYS`) are stripped so the artifact
    bytes do not depend on which mesh computed them (or how long it
    took); a payload checksum is embedded for `load` to verify."""
    os.makedirs(cache_dir, exist_ok=True)
    path = artifact_path(cache_dir, name, fp)
    payload = {k: v for k, v in payload.items() if k not in VOLATILE_KEYS}
    payload["fingerprint"] = fp
    payload["checksum"] = _payload_checksum(payload)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, default=float)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path
