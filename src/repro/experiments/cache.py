"""Content-hashed on-disk artifact cache for sweep results.

Layout: ``<cache_dir>/<spec-name>-<fingerprint16>.json`` where the
fingerprint is `spec.fingerprint(spec)` — a sha256 over the canonical spec
dict plus ``ENGINE_VERSION``.  Any change to the spec (grid, iters, dataset
kwargs, epsilon policy, ...) or to the engine version lands on a different
file, so a hit is always safe to reuse and repeated sweeps are free.

Artifacts are **mesh-independent**: the fingerprint strips execution-only
spec fields (`spec.EXECUTION_ONLY_FIELDS`) and :func:`store` strips the
volatile per-run keys (`VOLATILE_KEYS`: the ``cache`` hit info, the
``execution`` mesh report, and the wall-clock ``elapsed_s`` the runner
attaches) before writing — so a sweep computed on an 8-device mesh writes
the same artifact, byte for byte, as the single-device run (and as a
journal-resumed run, see docs/robustness.md), and either one serves the
other's lookups (tested in tests/test_distributed.py).

**Integrity** (docs/robustness.md): :func:`store` embeds a sha256
``checksum`` of the canonical payload serialization; :func:`load`
verifies it and **quarantines** artifacts that fail — bit-rotted or
hand-mutated files are renamed to ``<path>.corrupt`` with a warning
instead of being silently treated as a cache miss (or worse, served).
Pre-checksum artifacts (no ``checksum`` key) still load unverified, so
existing caches keep serving.

**Size cap** (for long-lived consumers like `repro.service`): pass
``max_artifacts`` to :func:`store` — or set the ``REPRO_SWEEP_CACHE_CAP``
environment variable — and the directory is held to that many artifacts
with least-recently-*used* eviction (:func:`load` bumps an artifact's
mtime on every hit, so recency means traffic, not write order).  The
first eviction raises a one-shot ``RuntimeWarning``; artifacts are
content-addressed and deterministic, so an evicted sweep that gets
requested again simply recomputes into byte-identical bytes (checksum-
verified, pinned in tests/test_experiments.py).

**In-flight dedup** (:class:`InFlightTable`): concurrent callers racing
to compute the same fingerprint collapse into one execution — the first
caller leases the fingerprint and computes; the rest wait and then load
the freshly stored artifact.  `runner.run_sweep(dedup=True)` is the
consumer; `repro.service` routes every escalated sweep through it.

The default directory is ``results/sweep_cache`` (override with the
``REPRO_SWEEP_CACHE`` environment variable or the ``cache_dir`` argument).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import warnings
from typing import Dict, List, Optional

from repro.telemetry import metrics

DEFAULT_CACHE_DIR = os.environ.get(
    "REPRO_SWEEP_CACHE", os.path.join("results", "sweep_cache"))

_HITS = metrics.counter("repro_cache_hits_total",
                        help="artifact cache lookups served from disk")
_MISSES = metrics.counter("repro_cache_misses_total",
                          help="artifact cache lookups that missed")
_EVICTIONS = metrics.counter("repro_cache_evictions_total",
                             help="artifacts evicted by the LRU cap")
_QUARANTINES = metrics.counter(
    "repro_cache_quarantines_total",
    help="artifacts quarantined after failing integrity verification")
_STORES = metrics.counter("repro_cache_stores_total",
                          help="artifacts written (atomic replace)")

#: default artifact-count cap applied by `store` (0 / unset = unbounded,
#: the pre-cap behavior; long-lived services should set a cap)
DEFAULT_CACHE_CAP: Optional[int] = (
    int(os.environ.get("REPRO_SWEEP_CACHE_CAP", "0")) or None)

#: result keys describing one concrete run, not the computation — never
#: persisted, re-attached fresh by the runner after every load/store
VOLATILE_KEYS = ("cache", "execution", "elapsed_s")


def artifact_path(cache_dir: str, name: str, fp: str) -> str:
    return os.path.join(cache_dir, f"{name}-{fp[:16]}.json")


def _payload_checksum(payload: Dict) -> str:
    """sha256 of the canonical (sorted-key) serialization, ``checksum``
    excluded.  JSON floats round-trip via shortest repr, so a parsed
    payload re-serializes to the same canonical bytes — verification
    after `json.load` is exact."""
    body = {k: v for k, v in payload.items() if k != "checksum"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True, default=float).encode()).hexdigest()


def _quarantine(path: str, reason: str) -> None:
    corrupt = path + ".corrupt"
    _QUARANTINES.inc()
    try:
        os.replace(path, corrupt)
    except OSError:
        corrupt = path                      # couldn't move; report in place
    warnings.warn(
        f"sweep artifact {path} failed integrity verification ({reason}); "
        f"quarantined to {corrupt} — the sweep will recompute",
        RuntimeWarning, stacklevel=3)


def load(cache_dir: str, name: str, fp: str) -> Optional[Dict]:
    """Return the cached payload, or None on miss.  Unparsable or
    checksum-mismatching artifacts are quarantined (see module docs).
    A hit bumps the artifact's mtime, so LRU eviction (`enforce_cap`)
    tracks use, not write order."""
    path = artifact_path(cache_dir, name, fp)
    try:
        with open(path) as f:
            raw = f.read()
    except OSError:
        _MISSES.inc()
        return None
    try:
        payload = json.loads(raw)
    except json.JSONDecodeError:
        _quarantine(path, "not parseable as JSON — truncated write?")
        _MISSES.inc()
        return None
    if payload.get("fingerprint") != fp:      # foreign / stale artifact
        _MISSES.inc()
        return None
    if "checksum" in payload and (
            payload["checksum"] != _payload_checksum(payload)):
        _quarantine(path, "payload checksum mismatch — bit rot or a "
                          "hand-edited artifact")
        _MISSES.inc()
        return None
    try:
        os.utime(path, None)                  # recency = last use
    except OSError:
        pass
    _HITS.inc()
    return payload


def list_artifacts(cache_dir: str) -> List[str]:
    """Paths of every artifact in the cache directory, least-recently-used
    first (quarantined ``.corrupt`` files and write temps excluded)."""
    try:
        names = os.listdir(cache_dir)
    except OSError:
        return []
    paths = [os.path.join(cache_dir, n) for n in names
             if n.endswith(".json")]
    def mtime(p):
        try:
            return os.stat(p).st_mtime
        except OSError:
            return 0.0
    return sorted(paths, key=mtime)


_EVICTION_WARNED = False


def enforce_cap(cache_dir: str, max_artifacts: int,
                keep: Optional[str] = None) -> List[str]:
    """Evict least-recently-used artifacts until at most ``max_artifacts``
    remain; returns the evicted paths.  ``keep`` (the artifact just
    stored) is never evicted.  The first eviction of the process warns
    once — a service whose working set exceeds its cache cap is
    recomputing sweeps it could have kept."""
    global _EVICTION_WARNED
    evicted: List[str] = []
    arts = list_artifacts(cache_dir)
    excess = len(arts) - int(max_artifacts)
    for path in arts:
        if excess <= 0:
            break
        if keep is not None and os.path.abspath(path) == \
                os.path.abspath(keep):
            continue
        try:
            os.unlink(path)
        except OSError:
            continue
        evicted.append(path)
        _EVICTIONS.inc()
        excess -= 1
    if evicted and not _EVICTION_WARNED:
        _EVICTION_WARNED = True
        warnings.warn(
            f"sweep cache {cache_dir} exceeded its cap of "
            f"{max_artifacts} artifact(s); evicted {len(evicted)} "
            f"least-recently-used (first: {evicted[0]}).  Evicted sweeps "
            f"recompute to byte-identical artifacts on the next request; "
            f"raise the cap (REPRO_SWEEP_CACHE_CAP / max_artifacts) if "
            f"this working set should stay resident.  [warned once]",
            RuntimeWarning, stacklevel=3)
    return evicted


def store(cache_dir: str, name: str, fp: str, payload: Dict,
          max_artifacts: Optional[int] = None) -> str:
    """Atomically write the payload; returns the artifact path.
    Volatile per-run keys (`VOLATILE_KEYS`) are stripped so the artifact
    bytes do not depend on which mesh computed them (or how long it
    took); a payload checksum is embedded for `load` to verify.
    ``max_artifacts`` (default: `DEFAULT_CACHE_CAP`) bounds the directory
    with LRU eviction after the write."""
    os.makedirs(cache_dir, exist_ok=True)
    path = artifact_path(cache_dir, name, fp)
    payload = {k: v for k, v in payload.items() if k not in VOLATILE_KEYS}
    payload["fingerprint"] = fp
    payload["checksum"] = _payload_checksum(payload)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, default=float)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    _STORES.inc()
    cap = max_artifacts if max_artifacts is not None else DEFAULT_CACHE_CAP
    if cap is not None and cap > 0:
        enforce_cap(cache_dir, cap, keep=path)
    return path


# ---------------------------------------------------------------------------
# in-flight dedup (single-flight execution per fingerprint)
# ---------------------------------------------------------------------------

class InFlightTable:
    """Single-flight table keyed by sweep fingerprint.

    The first caller to :meth:`lease` a fingerprint becomes its *leader*
    (computes and stores the artifact); concurrent callers see ``False``,
    :meth:`wait`, then re-check the artifact cache — the leader's stored
    bytes serve every waiter, so N identical concurrent requests execute
    exactly one sweep and every waiter reads the identical artifact.  A
    leader that fails releases without storing; one waiter then takes
    over the lease (graceful retry, never a deadlock)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: Dict[str, threading.Event] = {}

    def lease(self, fp: str) -> bool:
        """True -> caller is the leader for ``fp`` and must `release`."""
        with self._lock:
            if fp in self._events:
                return False
            self._events[fp] = threading.Event()
            return True

    def wait(self, fp: str, timeout: Optional[float] = None) -> bool:
        """Block until ``fp``'s leader releases (True), or timeout
        (False).  Returns immediately when nothing is in flight."""
        with self._lock:
            ev = self._events.get(fp)
        if ev is None:
            return True
        return ev.wait(timeout)

    def release(self, fp: str) -> None:
        """Leader done (artifact stored, or the attempt failed): wake
        every waiter and free the lease."""
        with self._lock:
            ev = self._events.pop(fp, None)
        if ev is not None:
            ev.set()

    @property
    def n_inflight(self) -> int:
        with self._lock:
            return len(self._events)
