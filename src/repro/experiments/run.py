"""CLI for the sweep engine — reproduce any paper figure from a spec name.

  PYTHONPATH=src python -m repro.experiments.run --list
  PYTHONPATH=src python -m repro.experiments.run --spec upper_bound --quick
  PYTHONPATH=src python -m repro.experiments.run --spec variance_sparsity \\
      --quick --iters 100 --n 300          # smoke-scale override
  PYTHONPATH=src python -m repro.experiments.run --spec diversity \\
      --quick --problem hinge              # same grid, hinge objective

``--list`` enumerates the registered specs AND the live Algorithm /
Problem / dataset-generator registries — anything listed is addressable
from a spec with no engine edits.  ``--problem`` re-points every job of
the chosen spec at another registered objective — but keeps each job's
kwargs, so a step size tuned for the spec's original objective may not
suit the new one's curvature (ridge on wide-range features wants a much
smaller gamma than Eq. 4 — see the ``problem_generality`` spec); the
runner warns if a curve goes non-finite.

``--devices`` (default ``auto``) shards every job's batched grid over a
device mesh (`repro.distributed`); the resolved mesh is reported at
startup.  Execution-only: curves and cache keys are identical on any
mesh size, so a sweep computed on 1 device is a cache hit on 8.

Repeated runs of an unchanged spec are served from the artifact cache
(--force recomputes, --no-cache bypasses it).  --json writes the full
result payload; the stdout report ends with the measured-vs-predicted
m_max comparison whenever the spec produces both sides.

``--trace out.json`` records the run as nested spans (sweep -> job ->
bucket -> lower/compile/execute, journal and cache IO) and writes
Chrome-trace / Perfetto JSON — load it at https://ui.perfetto.dev or
summarize with ``python -m repro.telemetry --summarize out.json``.
``--metrics`` dumps the process metrics registry (Prometheus text) after
the run.  ``--serve PORT`` additionally exposes the run's telemetry over
HTTP *while it executes* — ``GET /metrics`` (Prometheus text),
``/healthz``, ``/flight`` (the flight recorder's per-job progress
events; tail it with ``python -m repro.telemetry --watch URL``), and
``/trace`` (live span JSON when ``--trace`` is also on).  All three are
observational: the sweep executes the same code and the artifact bytes
are identical with or without them (docs/observability.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.core import problems as problems_mod
from repro.core.algorithms import base as alg_base
from repro.data import synth
from repro.distributed import get_mesh
from repro.experiments import registry, runner
from repro.telemetry import metrics as metrics_mod
from repro.telemetry import trace


def _print_report(result: dict) -> None:
    spec = result["spec"]
    print("=" * 72)
    print(f"sweep {result['name']}: {spec['description']}")
    line = (f"  m grid={list(spec['ms'])}  iters={spec['iters']}  "
            f"eval_every={spec['eval_every']}")
    if spec.get("n_seeds", 1) > 1:
        line += (f"  seeds={spec['n_seeds']} (stats: "
                 f"python -m repro.analysis.report)")
    print(line)
    print("=" * 72)

    for name, info in result["datasets"].items():
        line = f"dataset {name:18s} n={info['n']} d={info['d']}"
        if "csim" in info:
            line += f"  C_sim={info['csim']:.2f}"
        if "characters" in info:
            c = info["characters"]
            line += (f"  var={c['mean_feature_variance']:.3f} "
                     f"sparsity={c['sparsity']:.3f} "
                     f"div={c['diversity_ratio']:.2f}")
        print(line)

    print()
    comparisons = []
    for key, jr in result["jobs"].items():
        curves = runner.curves_by_m(jr)
        finals = "  ".join(f"m{m}={c[-1]:.4f}" for m, c in curves.items())
        print(f"{key:28s} final loss: {finals}")
        if "costs" in jr:
            costs = "  ".join(f"m{m}={c:.0f}"
                              for m, c in zip(jr["ms"], jr["costs"]))
            print(f"{'':28s} cost/worker (eps={jr['epsilon']:.4f}): {costs}")
            print(f"{'':28s} measured m_max = {jr['measured_m_max']}")
        if "predicted" in jr:
            pm = jr["predicted"]["predicted_m_max"]
            print(f"{'':28s} predicted m_max = {pm}")
        if "measured_m_max" in jr and "predicted" in jr:
            comparisons.append((key, jr["measured_m_max"],
                                jr["predicted"]["predicted_m_max"]))

    if comparisons:
        print()
        print("measured vs predicted scalability upper bound (core claim):")
        for key, meas, pred in comparisons:
            print(f"  {key:28s} measured={meas:<6d} predicted={pred}")

    cache = result.get("cache", {})
    exe = result.get("execution", {})
    if cache.get("hit"):
        src = "cache hit"
    else:
        src = f"computed in {result.get('elapsed_s', 0.0):.1f}s"
        if exe.get("sharded"):
            src += f" sharded over {exe['devices']} devices"
    print(f"\n[{src}] artifact: {cache.get('path')}")


def _print_registries() -> None:
    print(get_mesh().describe())
    print("\nregistered sweep specs:")
    for name in registry.SPEC_IDS:
        spec = registry.get_spec(name, quick=True)
        print(f"  {name:20s} {spec.description}")
    print("\nregistered algorithms (core.algorithms):")
    for name in sorted(alg_base.ALGORITHMS):
        cls = alg_base.ALGORITHMS[name]
        flags = []
        if cls.asynchronous:
            flags.append("async")
        flags.append("flat" if cls.force_flat
                     else ("bucketed" if cls.bucketed_default else "flat-default"))
        print(f"  {name:20s} predictor={cls.predictor:8s} "
              f"[{', '.join(flags)}]")
    print("\nregistered problems (core.problems):")
    for name in sorted(problems_mod.PROBLEMS):
        doc = (problems_mod.PROBLEMS[name].__doc__ or "").split("\n")[0]
        print(f"  {name:20s} {doc}")
    print("\nregistered dataset generators (data.synth):")
    for name in sorted(synth.GENERATORS):
        doc = (synth.GENERATORS[name].__doc__ or "").split("\n")[0]
        print(f"  {name:20s} {doc}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.run",
        description="run a registered scalability sweep")
    ap.add_argument("--spec", help=f"spec name; one of {registry.SPEC_IDS}")
    ap.add_argument("--list", action="store_true",
                    help="list registered specs, algorithms, problems, and "
                         "dataset generators, then exit")
    ap.add_argument("--problem",
                    help="re-point every job of the spec at this registered "
                         "problem (e.g. ridge, hinge); job kwargs are kept, "
                         "so curvature-mismatched step sizes may need "
                         "retuning (the runner warns on non-finite curves)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-scale iteration counts")
    ap.add_argument("--iters", type=int, help="override iteration budget")
    ap.add_argument("--n", type=int, help="override dataset size")
    ap.add_argument("--seeds", type=int,
                    help="override the spec's n_seeds (seed replicates per "
                         "job, vmapped in one trace; see repro.analysis)")
    ap.add_argument("--force", action="store_true",
                    help="recompute even on a cache hit")
    ap.add_argument("--no-cache", action="store_true",
                    help="neither read nor write the artifact cache")
    ap.add_argument("--cache-dir", help="artifact cache directory")
    ap.add_argument("--devices", default="auto",
                    help="device mesh for sharded execution: 'auto' (all "
                         "available XLA devices, the default) or an int; "
                         "execution-only — results and cache keys are "
                         "mesh-invariant (see docs/distributed.md).  On "
                         "CPU, create virtual devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--seq", action="store_true",
                    help="sequential per-m loop instead of the vmapped grid "
                         "(never sharded)")
    ap.add_argument("--json", help="also write the full result to this path")
    ap.add_argument("--trace", metavar="TRACE_JSON",
                    help="record the run as spans and write Chrome-trace / "
                         "Perfetto JSON here (observational only — "
                         "artifact bytes are unchanged)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the process metrics registry (Prometheus "
                         "text) after the run")
    ap.add_argument("--serve", metavar="PORT", type=int, default=None,
                    help="expose /metrics /healthz /flight /trace over HTTP "
                         "on this port while the sweep runs (0 = ephemeral; "
                         "observational only — artifact bytes are "
                         "unchanged)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        _print_registries()
        return 0
    if not args.spec:
        ap.error("--spec is required (or --list)")

    spec = registry.get_spec(args.spec, quick=args.quick,
                             iters=args.iters, n=args.n, seeds=args.seeds)
    if args.problem:
        problems_mod.get_problem(args.problem)    # fail fast if unknown
        spec = dataclasses.replace(spec, jobs=tuple(
            dataclasses.replace(j, problem=args.problem)
            for j in spec.jobs)).validate()
    devices = args.devices
    if devices != "auto":
        try:
            devices = int(devices)
        except ValueError:
            ap.error(f"--devices must be an int or 'auto', got {devices!r}")
    # startup mesh report — best-effort: an over-subscribed request (e.g.
    # --devices 8 on a 1-device host) clamps with a warning, and an
    # otherwise-invalid one (--devices 0) must still serve cached
    # artifacts, so the runner resolves the mesh only on a miss
    try:
        print(get_mesh(devices).describe())
    except ValueError as e:
        print(f"mesh: not resolvable here ({e}); cached artifacts still "
              f"serve, a fresh compute will fail")
    # the tracer brackets run_sweep tightly, so the root "sweep" span
    # attributes ~all of the traced wall-clock (the >=95% coverage gate
    # in CI's traced smoke); a cache hit traces only the lookup
    if args.trace:
        trace.start()
    server = None
    if args.serve is not None:
        # metrics-only observability plane: no advisor behind it, so
        # probe endpoints answer 503; /metrics /flight /trace watch THIS
        # process's sweep (import here keeps the plain CLI http-free)
        from repro.service.http import ServiceServer
        server = ServiceServer(None, port=args.serve).start()
        print(f"observability plane at {server.url} (GET /metrics "
              f"/healthz /flight /trace; watch: python -m repro.telemetry "
              f"--watch {server.url})", flush=True)
    try:
        result = runner.run_sweep(spec, use_cache=not args.no_cache,
                                  force=args.force, cache_dir=args.cache_dir,
                                  use_vmap=not args.seq, verbose=args.verbose,
                                  mesh=devices)
    finally:
        if server is not None:
            server.stop()
        if args.trace:
            trace.stop()
            trace.export(args.trace)
            print(f"wrote trace {args.trace} (load at "
                  f"https://ui.perfetto.dev, or: python -m repro.telemetry "
                  f"--summarize {args.trace})")
    _print_report(result)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=1, default=float)
        print(f"wrote {args.json}")
    if args.metrics:
        print()
        print(metrics_mod.REGISTRY.render_prometheus(prefix="repro_"),
              end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
