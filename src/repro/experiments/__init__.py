"""repro.experiments — the unified scalability-sweep engine.

This package turns the paper's experiments (worker-count m x dataset
character x algorithm x objective) into declarative, cacheable sweeps:
`spec` defines the :class:`SweepSpec` language and dataset materialization,
`registry` names one spec per paper figure/table, `engine` runs any
registered `Algorithm` on any registered `Problem` over the whole worker
grid as bucketed vmapped simulations (`engine.sweep` is the generic entry
point), `runner.run_sweep` orchestrates a spec end to end with
content-hashed artifact caching, and ``python -m repro.experiments.run``
is the CLI that reproduces any figure from a spec name.  The legacy
`benchmarks/paper_*.py` scripts are thin adapters over this package.
Specs with ``n_seeds > 1`` replicate every curve over a vmapped seed
batch; `repro.analysis` consumes the replicate blocks (bootstrap CIs,
scaling-law fits, ``python -m repro.analysis.report``).

Extending it is registration, not engine surgery: a new optimizer is an
`Algorithm` dataclass (`repro.core.algorithms.base.register_algorithm`), a
new objective a `Problem` dataclass (`repro.core.problems.
register_problem`), a new dataset scenario a decorated generator
(`repro.data.synth.register_generator`).  See docs/architecture.md for
the <=30-line recipes.
"""

from repro.experiments.registry import SPEC_IDS, get_spec
from repro.experiments.runner import curves_by_m, run_sweep
from repro.experiments.spec import (ALGORITHMS, PROBLEMS, DatasetSpec,
                                    EpsilonSpec, JobSpec, SweepSpec,
                                    fingerprint, registry_signature)

__all__ = ["SPEC_IDS", "get_spec", "run_sweep", "curves_by_m", "ALGORITHMS",
           "PROBLEMS", "DatasetSpec", "EpsilonSpec", "JobSpec", "SweepSpec",
           "fingerprint", "registry_signature"]
