"""repro.experiments — the unified scalability-sweep engine.

This package turns the paper's experiments (worker-count m x dataset
character x algorithm) into declarative, cacheable sweeps: `spec` defines
the :class:`SweepSpec` language and dataset materialization, `registry`
names one spec per paper figure/table, `engine` runs all four algorithms
(Hogwild! included) over the whole worker grid as bucketed vmapped
simulations, `runner.run_sweep` orchestrates a spec end to
end with content-hashed artifact caching, and ``python -m
repro.experiments.run`` is the CLI that reproduces any figure from a spec
name.  The legacy `benchmarks/paper_*.py` scripts are thin adapters over
this package.  See docs/architecture.md.
"""

from repro.experiments.registry import SPEC_IDS, get_spec
from repro.experiments.runner import curves_by_m, run_sweep
from repro.experiments.spec import (ALGORITHMS, DatasetSpec, EpsilonSpec,
                                    JobSpec, SweepSpec, fingerprint)

__all__ = ["SPEC_IDS", "get_spec", "run_sweep", "curves_by_m", "ALGORITHMS",
           "DatasetSpec", "EpsilonSpec", "JobSpec", "SweepSpec",
           "fingerprint"]
