"""Shared helpers for the paper-experiment benchmarks."""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0] if jax.tree.leaves(out)
                          else out)
    return out, (time.time() - t0) * 1e6


def emit(name, us_per_call, derived):
    """The bench contract: ``name,us_per_call,derived`` CSV on stdout."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def loss_gap(curve_a, curve_b):
    """Mean gap between two convergence curves (paper's 'gap' read-out)."""
    n = min(len(curve_a), len(curve_b))
    return float(np.mean(np.array(curve_a[:n]) - np.array(curve_b[:n])))
