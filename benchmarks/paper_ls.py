"""Paper Figures 7-10 — LS_A(D, S) (local-similarity) experiment.

Sequences built by mutating 10% (small C_sim => LOW local distance) vs 90%
(large C_sim) of features per step (§VII.A), fed in sequence order.
NOTE paper semantics: LARGE C_sim (= large local L0 distance = neighbors
DIFFER more) => better scalability.  Read-outs follow §VII.D.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, loss_gap, save_json
from repro.core import metrics as MX
from repro.core.algorithms import (run_dadm, run_ecd_psgd, run_hogwild,
                                   run_minibatch)
from repro.data import synth

MS = [1, 4, 8]


def run(iters=1200, n=2400, quick=False):
    if quick:
        iters, n = 500, 1000
    key = jax.random.PRNGKey(0)
    # paper: dense for mini-batch (28) / ECD-PSGD (1000 -> scaled 200);
    # sparse for Hogwild!/DADM
    variants = {
        "small_ls_dense": synth.make_ls_sequence(key, n=n, d=28,
                                                 mutate_frac=0.1),
        "large_ls_dense": synth.make_ls_sequence(key, n=n, d=28,
                                                 mutate_frac=0.9),
        "small_ls_sparse": synth.make_ls_sequence(key, n=n, d=200,
                                                  mutate_frac=0.1,
                                                  density=0.05, lo=0, hi=1),
        "large_ls_sparse": synth.make_ls_sequence(key, n=n, d=200,
                                                  mutate_frac=0.9,
                                                  density=0.05, lo=0, hi=1),
    }
    out = {"csim": {k: MX.csim_ref(v.X[:400], 8)
                    for k, v in variants.items()}}
    t0 = time.time()

    def curves_for(runner, ds, kwname):
        tr, te = ds.split()          # NO shuffle: sequence order is the point
        res = {}
        for m in MS:
            r = runner(tr, te, iters=iters, eval_every=iters // 8,
                       **{kwname: m})
            res[m] = [float(x) for x in r["losses"]]
        return res

    # fig 7: mini-batch on dense LS variants
    for tag in ("small_ls_dense", "large_ls_dense"):
        out[f"minibatch/{tag}"] = curves_for(run_minibatch, variants[tag],
                                             "batch_size")
        out[f"ecd_psgd/{tag}"] = curves_for(run_ecd_psgd, variants[tag], "m")
    # fig 9/10: hogwild + dadm on sparse LS variants
    for tag in ("small_ls_sparse", "large_ls_sparse"):
        out[f"hogwild/{tag}"] = curves_for(run_hogwild, variants[tag], "m")
        out[f"dadm/{tag}"] = curves_for(run_dadm, variants[tag], "m")

    us = (time.time() - t0) * 1e6 / (len(MS) * 8)
    save_json("paper_ls", out)

    g_small = loss_gap(out["minibatch/small_ls_dense"][1],
                       out["minibatch/small_ls_dense"][8])
    g_large = loss_gap(out["minibatch/large_ls_dense"][1],
                       out["minibatch/large_ls_dense"][8])
    emit("fig7_minibatch_ls_gap", us,
         f"large_ls={g_large:.4f};small_ls={g_small:.4f};"
         f"claim_large_gt_small={g_large > g_small};"
         f"csim_small={out['csim']['small_ls_dense']:.2f};"
         f"csim_large={out['csim']['large_ls_dense']:.2f}")
    h_small = abs(loss_gap(out["hogwild/small_ls_sparse"][1],
                           out["hogwild/small_ls_sparse"][8]))
    h_large = abs(loss_gap(out["hogwild/large_ls_sparse"][1],
                           out["hogwild/large_ls_sparse"][8]))
    emit("fig9_hogwild_ls_gap", us,
         f"large_ls={h_large:.4f};small_ls={h_small:.4f};"
         f"claim_large_lt_small={h_large < h_small}")
    return out


if __name__ == "__main__":
    run()
