"""Paper Figures 7-10 — LS_A(D, S) (local-similarity) experiment.

Thin adapter over `repro.experiments` (spec: ``ls``): sequences built by
mutating 10% (small C_sim => LOW local distance) vs 90% (large C_sim) of
features per step (§VII.A), fed in sequence order (no shuffle) through the
vmapped engine.
NOTE paper semantics: LARGE C_sim (= large local L0 distance = neighbors
DIFFER more) => better scalability.  Read-outs follow §VII.D.
"""

from __future__ import annotations

from benchmarks.common import emit, loss_gap, save_json
from repro.experiments import curves_by_m, get_spec, run_sweep


def run(iters=1200, n=2400, quick=False):
    spec = (get_spec("ls", quick=True) if quick
            else get_spec("ls", iters=iters, n=n))
    # benchmarks measure: always recompute (the cache serves CLI/library use)
    res = run_sweep(spec, force=True)

    out = {"csim": {k: res["datasets"][k]["csim"]
                    for k in res["datasets"]}}
    for key, jr in res["jobs"].items():          # key is "algo/tag" already
        out[key] = curves_by_m(jr)
    us = res["elapsed_s"] * 1e6 / (len(spec.ms) * len(res["jobs"]))
    save_json("paper_ls", out)

    g_small = loss_gap(out["minibatch/small_ls_dense"][1],
                       out["minibatch/small_ls_dense"][8])
    g_large = loss_gap(out["minibatch/large_ls_dense"][1],
                       out["minibatch/large_ls_dense"][8])
    emit("fig7_minibatch_ls_gap", us,
         f"large_ls={g_large:.4f};small_ls={g_small:.4f};"
         f"claim_large_gt_small={g_large > g_small};"
         f"csim_small={out['csim']['small_ls_dense']:.2f};"
         f"csim_large={out['csim']['large_ls_dense']:.2f}")
    h_small = abs(loss_gap(out["hogwild/small_ls_sparse"][1],
                           out["hogwild/small_ls_sparse"][8]))
    h_large = abs(loss_gap(out["hogwild/large_ls_sparse"][1],
                           out["hogwild/large_ls_sparse"][8]))
    emit("fig9_hogwild_ls_gap", us,
         f"large_ls={h_large:.4f};small_ls={h_small:.4f};"
         f"claim_large_lt_small={h_large < h_small}")
    return out


if __name__ == "__main__":
    run()
