"""Kernel microbenchmarks (interpret-mode on CPU: relative numbers only —
the BlockSpec tiling is for TPU; derived column reports bytes or flops)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.metrics import csim_ref
from repro.kernels import ops, ref


def _time(fn, n=3):
    fn()                                   # compile
    t0 = time.time()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run(quick=False):
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, KV, D))
    v = jax.random.normal(key, (B, S, KV, D))
    us = _time(lambda: ops.flash_attention(q, k, v, bq=128, bk=128))
    flops = 4 * B * S * S / 2 * H * D
    emit("kernel_flash_attention_256", us, f"flops={flops:.2e}")
    us_ref = _time(lambda: ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)))
    emit("kernel_flash_attention_ref_256", us_ref, f"flops={flops:.2e}")

    X = jax.random.normal(key, (512, 256))
    us = _time(lambda: ops.csim(X, 4))
    emit("kernel_csim_512x256_r4", us, f"bytes={X.size * 4 * 4:.2e}")
    us_ref = _time(lambda: csim_ref(X, 4))
    emit("kernel_csim_ref_512x256_r4", us_ref, f"bytes={X.size * 4 * 4:.2e}")

    x = jax.random.normal(key, (1024, 512))
    us = _time(lambda: ops.quantize_stochastic(x, key, bits=8)[0])
    emit("kernel_quantize_1024x512", us, f"bytes={x.size * 4:.2e}")

    g = jnp.ones((512,))
    xr = jax.random.normal(key, (2048, 512))
    us = _time(lambda: ops.rmsnorm(xr, g))
    emit("kernel_rmsnorm_2048x512", us, f"bytes={xr.size * 4 * 2:.2e}")


if __name__ == "__main__":
    run()
