"""Paper Figure 6 — sample-diversity experiment.

real_sim / real_sim2 / real_sim4 duplication variants on DADM and mini-batch
SGD; higher diversity => larger parallel gap (better scalability).
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, loss_gap, save_json
from repro.core.algorithms import run_dadm, run_minibatch
from repro.data import synth

MS = [1, 4, 16]


def run(iters=800, n=1600, quick=False):
    if quick:
        iters, n = 400, 800
    key = jax.random.PRNGKey(0)
    base = synth.make_realsim_like(key, n=n, d=300, density=0.05)
    high, mid, low = synth.make_diversity_variants(base)
    out = {}
    t0 = time.time()
    for name, ds in [("high", high), ("mid", mid), ("low", low)]:
        tr, te = ds.split(key=key)
        for algo, runner, kwname in [("dadm", run_dadm, "m"),
                                     ("minibatch", run_minibatch,
                                      "batch_size")]:
            curves = {}
            for m in MS:
                r = runner(tr, te, iters=iters, eval_every=iters // 8,
                           **{kwname: m})
                curves[m] = [float(x) for x in r["losses"]]
            out[f"{name}/{algo}"] = {
                "curves": curves,
                "gap_1_16": loss_gap(curves[1], curves[16]),
            }
    us = (time.time() - t0) * 1e6 / (len(MS) * 6)
    save_json("paper_diversity", out)
    gaps = {k: out[f"{k}/dadm"]["gap_1_16"] for k in ("high", "mid", "low")}
    emit("fig6_dadm_diversity_gaps", us,
         f"high={gaps['high']:.4f};mid={gaps['mid']:.4f};"
         f"low={gaps['low']:.4f};"
         f"claim_monotone={gaps['high'] >= gaps['mid'] >= gaps['low']}")
    return out


if __name__ == "__main__":
    run()
