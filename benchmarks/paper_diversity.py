"""Paper Figure 6 — sample-diversity experiment.

Thin adapter over `repro.experiments` (spec: ``diversity``): the
real_sim / real_sim2 / real_sim4 duplication variants run on DADM and
mini-batch SGD through the vmapped engine; higher diversity => larger
parallel gap (better scalability).
"""

from __future__ import annotations

from benchmarks.common import emit, loss_gap, save_json
from repro.experiments import curves_by_m, get_spec, run_sweep


def run(iters=800, n=1600, quick=False):
    spec = (get_spec("diversity", quick=True) if quick
            else get_spec("diversity", iters=iters, n=n))
    # benchmarks measure: always recompute (the cache serves CLI/library use)
    res = run_sweep(spec, force=True)

    out = {}
    for key, jr in res["jobs"].items():
        algo, variant = key.split("/", 1)
        curves = curves_by_m(jr)
        out[f"{variant}/{algo}"] = {
            "curves": curves,
            "gap_1_16": loss_gap(curves[1], curves[16]),
        }
    us = res["elapsed_s"] * 1e6 / (len(spec.ms) * len(res["jobs"]))
    save_json("paper_diversity", out)
    gaps = {k: out[f"{k}/dadm"]["gap_1_16"] for k in ("high", "mid", "low")}
    emit("fig6_dadm_diversity_gaps", us,
         f"high={gaps['high']:.4f};mid={gaps['mid']:.4f};"
         f"low={gaps['low']:.4f};"
         f"claim_monotone={gaps['high'] >= gaps['mid'] >= gaps['low']}")
    return out


if __name__ == "__main__":
    run()
