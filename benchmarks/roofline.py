"""Roofline table (EXPERIMENTS.md §Roofline): three terms per
(arch x shape) on the single-pod mesh, from the dry-run JSON + the analytic
FLOP model (HLO flops under-count scan trip counts; both are reported).

Reads results/dryrun_1pod.json if present (produced by
``python -m repro.launch.dryrun --all --json results/dryrun_1pod.json``);
otherwise emits analytic-only terms.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit, save_json
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, pair_supported

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def run(dryrun_json="results/dryrun_1pod.json", quick=False):
    from repro.launch.analytic import model_bytes, model_flops
    from repro.launch.dryrun import arch_for_pair

    hlo = {}
    if os.path.exists(dryrun_json):
        with open(dryrun_json) as f:
            hlo = json.load(f)

    table = {}
    archs = ARCH_IDS[:3] if quick else ARCH_IDS
    for arch in archs:
        for shape_name, shape in INPUT_SHAPES.items():
            ok, reason = pair_supported(arch, shape_name)
            key = f"{arch}|{shape_name}"
            if not ok:
                table[key] = {"status": "skipped", "reason": reason}
                continue
            cfg = arch_for_pair(arch, shape_name)
            mf = model_flops(cfg, shape)
            mb = model_bytes(cfg, shape)
            compute_t = mf["model_flops"] / (CHIPS * PEAK_FLOPS)
            memory_t = mb / (CHIPS * HBM_BW)
            row = {
                "status": "ok",
                "params_total": mf["params_total"],
                "params_active": mf["params_active"],
                "model_flops": mf["model_flops"],
                "model_bytes_min": mb,
                "compute_term_s": compute_t,
                "memory_term_s_analytic": memory_t,
            }
            h = hlo.get(f"{arch}|{shape_name}|1pod_16x16", {})
            if h.get("status") == "ok":
                row.update({
                    "hlo_flops_per_device": h["flops_per_device"],
                    "hlo_bytes_per_device": h["bytes_per_device"],
                    "collective_bytes_per_device":
                        h["collective_bytes_per_device"],
                    "memory_term_s": h["memory_term_s"],
                    "collective_term_s": h["collective_term_s"],
                    "temp_bytes": h.get("temp_size_in_bytes"),
                    "arg_bytes": h.get("argument_size_in_bytes"),
                    "useful_flops_ratio":
                        mf["model_flops"] / CHIPS
                        / max(h["flops_per_device"], 1.0),
                })
                terms = {"compute": compute_t,
                         "memory": h["memory_term_s"],
                         "collective": h["collective_term_s"]}
                row["dominant_term"] = max(terms, key=terms.get)
            else:
                terms = {"compute": compute_t, "memory": memory_t}
                row["dominant_term"] = max(terms, key=terms.get)
            table[key] = row
            emit(f"roofline_{arch}_{shape_name}", 0.0,
                 f"compute={compute_t:.4f}s;"
                 f"memory={row.get('memory_term_s', memory_t):.4f}s;"
                 f"collective={row.get('collective_term_s', 0.0):.4f}s;"
                 f"dominant={row['dominant_term']}")
    save_json("roofline", table)
    return table


if __name__ == "__main__":
    run()
