"""Paper Table II — scalability upper-bound experiment.

Iterations-per-worker to reach a fixed epsilon, for m in {2,4,8,16,24},
on each algorithm's best-performing dataset (Hogwild!: the 70%-density
simulated set whose bound is reachable; mini-batch/ECD-PSGD: dense;
DADM: 1/8-subsampled sparse, per §VII.E).  The upper bound is the m where
cost stops decreasing (gain growth <= 0) — plus the theory-side predictions
from the dataset characters.
"""

from __future__ import annotations

import math
import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.core import scalability as SC
from repro.core.algorithms import (run_dadm, run_ecd_psgd, run_hogwild,
                                   run_minibatch)
from repro.data import synth

MS = [2, 4, 8, 16, 24]


def run(iters=3000, quick=False):
    if quick:
        iters = 1200
    key = jax.random.PRNGKey(0)
    ub = synth.make_upper_bound_dataset(key, n=4000, d=400, density=0.7)
    dense = synth.make_higgs_like(key, n=4000, d=28)
    sparse8 = synth.make_realsim_like(key, n=1000, d=300, density=0.05)
    out = {"costs": {}, "upper_bounds": {}, "predicted": {}}
    t0 = time.time()

    def eps_for(runner, ds, kwname, frac=0.7, **kw):
        """epsilon = the loss the 2-worker run reaches after `frac` of its
        budget — reachable by all settings, discriminative between them."""
        tr, te = ds.split(key=key)
        probe = runner(tr, te, iters=iters, eval_every=iters // 20,
                       **{kwname: 2}, **kw)
        losses = np.array(probe["losses"])
        eps = float(losses[int(len(losses) * frac)])
        return (tr, te), eps

    jobs = [
        ("hogwild", run_hogwild, ub, "m", True, {"gamma": 0.05}),
        ("minibatch", run_minibatch, dense, "batch_size", False, {}),
        ("ecd_psgd", run_ecd_psgd, dense, "m", False, {}),
        ("dadm", run_dadm, sparse8, "m", False, {}),
    ]
    for name, runner, ds, kwname, is_async, kw in jobs:
        (tr, te), eps = eps_for(runner, ds, kwname, **kw)
        costs = []
        for m in MS:
            r = runner(tr, te, iters=iters, eval_every=iters // 20,
                       **{kwname: m}, **kw)
            c = SC.cost_per_worker(r, eps, asynchronous=is_async)
            costs.append(c if math.isfinite(c) else float(iters))
        gg = SC.gain_growth_from_costs(costs)
        bound = SC.measured_upper_bound(MS[:-1], gg)
        out["costs"][name] = dict(zip(map(str, MS), costs))
        out["upper_bounds"][name] = bound
    out["predicted"]["hogwild_on_ub"] = SC.predict_hogwild_mmax(ub.X)
    out["predicted"]["sync_on_dense"] = SC.predict_sync_mmax(dense.X)
    out["predicted"]["dadm_on_sparse8"] = SC.predict_dadm_mmax(sparse8.X[:600])
    us = (time.time() - t0) * 1e6 / (len(MS) * len(jobs))
    save_json("paper_upper_bound", out)
    for name in out["costs"]:
        costs = list(out["costs"][name].values())
        emit(f"tableII_{name}_cost_per_worker", us,
             ";".join(f"m{m}={c:.0f}" for m, c in zip(MS, costs))
             + f";bound_at_m={out['upper_bounds'][name]}")
    return out


if __name__ == "__main__":
    run()
