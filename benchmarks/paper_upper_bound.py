"""Paper Table II — scalability upper-bound experiment.

Thin adapter over `repro.experiments` (spec: ``upper_bound``): iterations
per worker to reach a fixed epsilon, for m in {2,4,8,16,24}, on each
algorithm's best-performing dataset (Hogwild!: the 70%-density simulated
set whose bound is reachable; mini-batch/ECD-PSGD: dense; DADM:
1/8-subsampled sparse, per §VII.E).  The epsilon schedule, cost/gain-growth
bookkeeping and the theory-side predictions all live in the engine now;
this module reshapes its result into the legacy JSON/CSV contract.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.experiments import get_spec, run_sweep

# engine job key -> legacy (algorithm, predicted-entry) naming
_JOBS = {
    "hogwild/ub": ("hogwild", "hogwild_on_ub"),
    "minibatch/dense": ("minibatch", "sync_on_dense"),
    "ecd_psgd/dense": ("ecd_psgd", None),
    "dadm/sparse8": ("dadm", "dadm_on_sparse8"),
}


def run(iters=3000, quick=False):
    spec = (get_spec("upper_bound", quick=True) if quick
            else get_spec("upper_bound", iters=iters))
    # benchmarks measure: always recompute (the cache serves CLI/library use)
    res = run_sweep(spec, force=True)

    out = {"costs": {}, "upper_bounds": {}, "predicted": {}}
    for key, (name, pred_key) in _JOBS.items():
        jr = res["jobs"][key]
        out["costs"][name] = dict(zip(map(str, jr["ms"]), jr["costs"]))
        out["upper_bounds"][name] = jr["measured_m_max"]
        if pred_key is not None:
            out["predicted"][pred_key] = jr["predicted"]
    us = res["elapsed_s"] * 1e6 / (len(spec.ms) * len(_JOBS))
    save_json("paper_upper_bound", out)
    for name in out["costs"]:
        costs = list(out["costs"][name].values())
        emit(f"tableII_{name}_cost_per_worker", us,
             ";".join(f"m{m}={c:.0f}" for m, c in zip(spec.ms, costs))
             + f";bound_at_m={out['upper_bounds'][name]}")
    return out


if __name__ == "__main__":
    run()
