"""Paper Figures 3/4/5 — feature-variance & sparsity experiment.

Thin adapter over `repro.experiments` (spec: ``variance_sparsity``): the
dense/high-variance (HIGGS-like) vs sparse/low-variance (real-sim-like)
m-sweep runs through the vmapped engine; this module only reshapes the
sweep result into the legacy JSON payload and CSV emit contract.
Read-outs (paper §VII):
  * mini-batch & ECD-PSGD: larger gap between worker counts = better
    parallel effect -> expected LARGE on dense, ~zero on sparse.
  * Hogwild!: smaller gap = better -> expected SMALL on sparse.
"""

from __future__ import annotations

from benchmarks.common import emit, loss_gap, save_json
from repro.experiments import curves_by_m, get_spec, run_sweep


def run(iters=1500, n=2000, quick=False):
    spec = (get_spec("variance_sparsity", quick=True) if quick
            else get_spec("variance_sparsity", iters=iters, n=n))
    # benchmarks measure: always recompute (the cache serves CLI/library use)
    res = run_sweep(spec, force=True)

    out = {}
    for key, jr in res["jobs"].items():
        algo, ds_name = key.split("/", 1)
        curves = curves_by_m(jr)
        out[f"{ds_name}/{algo}"] = {"curves": curves,
                                    "gap_1_8": loss_gap(curves[1], curves[8])}
    us = res["elapsed_s"] * 1e6 / (len(spec.ms) * len(res["jobs"]))
    save_json("paper_variance_sparsity", out)

    # paper-claim read-outs
    mb_dense = out["higgs_like/minibatch"]["gap_1_8"]
    mb_sparse = out["realsim_like/minibatch"]["gap_1_8"]
    hw_dense = abs(out["higgs_like/hogwild"]["gap_1_8"])
    hw_sparse = abs(out["realsim_like/hogwild"]["gap_1_8"])
    emit("fig3_minibatch_gap_dense_vs_sparse", us,
         f"dense={mb_dense:.4f};sparse={mb_sparse:.4f};"
         f"claim_dense_gt_sparse={mb_dense > mb_sparse}")
    emit("fig5_hogwild_gap_sparse_vs_dense", us,
         f"dense={hw_dense:.4f};sparse={hw_sparse:.4f};"
         f"claim_sparse_lt_dense={hw_sparse < hw_dense}")
    ecd_dense = out["higgs_like/ecd_psgd"]["gap_1_8"]
    ecd_sparse = out["realsim_like/ecd_psgd"]["gap_1_8"]
    emit("fig4_ecdpsgd_gap_dense_vs_sparse", us,
         f"dense={ecd_dense:.4f};sparse={ecd_sparse:.4f};"
         f"claim_dense_gt_sparse={ecd_dense > ecd_sparse}")
    return out


if __name__ == "__main__":
    run()
