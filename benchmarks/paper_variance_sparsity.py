"""Paper Figures 3/4/5 — feature-variance & sparsity experiment.

Dense/high-variance (HIGGS-like) vs sparse/low-variance (real-sim-like)
datasets on mini-batch SGD, ECD-PSGD and Hogwild!, m in {1,2,4,8}.
Read-outs (paper §VII):
  * mini-batch & ECD-PSGD: larger gap between worker counts = better
    parallel effect -> expected LARGE on dense, ~zero on sparse.
  * Hogwild!: smaller gap = better -> expected SMALL on sparse.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, loss_gap, save_json
from repro.core.algorithms import run_ecd_psgd, run_hogwild, run_minibatch
from repro.data import synth

MS = [1, 2, 4, 8]


def run(iters=1500, n=2000, quick=False):
    if quick:
        iters, n = 600, 1000
    key = jax.random.PRNGKey(0)
    dense = synth.make_higgs_like(key, n=n, d=28).split(key=key)
    sparse = synth.make_realsim_like(key, n=n, d=400, density=0.05
                                     ).split(key=key)
    out = {}
    t0 = time.time()
    for ds_name, (tr, te) in [("higgs_like", dense), ("realsim_like", sparse)]:
        for algo, runner, kwname in [
                ("minibatch", run_minibatch, "batch_size"),
                ("ecd_psgd", run_ecd_psgd, "m"),
                ("hogwild", run_hogwild, "m")]:
            curves = {}
            for m in MS:
                r = runner(tr, te, iters=iters, eval_every=iters // 10,
                           **{kwname: m})
                curves[m] = [float(x) for x in r["losses"]]
            gap_1_8 = loss_gap(curves[1], curves[8])
            out[f"{ds_name}/{algo}"] = {"curves": curves, "gap_1_8": gap_1_8}
    us = (time.time() - t0) * 1e6 / (len(MS) * 6)
    save_json("paper_variance_sparsity", out)

    # paper-claim read-outs
    mb_dense = out["higgs_like/minibatch"]["gap_1_8"]
    mb_sparse = out["realsim_like/minibatch"]["gap_1_8"]
    hw_dense = abs(out["higgs_like/hogwild"]["gap_1_8"])
    hw_sparse = abs(out["realsim_like/hogwild"]["gap_1_8"])
    emit("fig3_minibatch_gap_dense_vs_sparse", us,
         f"dense={mb_dense:.4f};sparse={mb_sparse:.4f};"
         f"claim_dense_gt_sparse={mb_dense > mb_sparse}")
    emit("fig5_hogwild_gap_sparse_vs_dense", us,
         f"dense={hw_dense:.4f};sparse={hw_sparse:.4f};"
         f"claim_sparse_lt_dense={hw_sparse < hw_dense}")
    ecd_dense = out["higgs_like/ecd_psgd"]["gap_1_8"]
    ecd_sparse = out["realsim_like/ecd_psgd"]["gap_1_8"]
    emit("fig4_ecdpsgd_gap_dense_vs_sparse", us,
         f"dense={ecd_dense:.4f};sparse={ecd_sparse:.4f};"
         f"claim_dense_gt_sparse={ecd_dense > ecd_sparse}")
    return out


if __name__ == "__main__":
    run()
