"""Benchmark harness — one entry per paper table/figure + kernels + roofline.

``PYTHONPATH=src python -m benchmarks.run``          (quick mode, CI-friendly)
``PYTHONPATH=src python -m benchmarks.run --full``   (paper-scale iterations)

Prints ``name,us_per_call,derived`` CSV per the bench contract; full curves
land in results/*.json.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale iteration counts")
    ap.add_argument("--only", help="run a single benchmark module")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (kernel_bench, paper_diversity, paper_ls,
                            paper_upper_bound, paper_variance_sparsity,
                            roofline)
    benches = [
        ("paper_variance_sparsity",                                # Figs 3-5
         lambda: paper_variance_sparsity.run(quick=quick)),
        ("paper_diversity", lambda: paper_diversity.run(quick=quick)),  # Fig 6
        ("paper_ls", lambda: paper_ls.run(quick=quick)),           # Figs 7-10
        ("paper_upper_bound",                                      # Table II
         lambda: paper_upper_bound.run(quick=quick)),
        ("kernel_bench", lambda: kernel_bench.run(quick=quick)),   # kernels/
        ("roofline", lambda: roofline.run()),                      # §Roofline
    ]
    failed = 0
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ===")
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed += 1
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
