"""Bench-trajectory regression gate (CI: every push).

Parses every BENCH_N.json in the repo root into one time series
(`repro.analysis.trajectory`), applies the trajectory gates (newest
engine_default and telemetry tax within a noise band of the last anchor
that measured them), rewrites docs/bench_history.md, and exits non-zero
on regression.

  PYTHONPATH=src python scripts/bench_check.py
  PYTHONPATH=src python scripts/bench_check.py --band 1.5 --no-write
"""

from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import trajectory  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate on the BENCH_*.json perf trajectory")
    ap.add_argument("--root", default=REPO_ROOT,
                    help="directory holding BENCH_N.json (default: repo "
                         "root)")
    ap.add_argument("--band", type=float, default=2.0,
                    help="regression gate: newest/previous anchor ratio "
                         "limit (default 2.0 — the shared-container noise "
                         "band, see docs/observability.md)")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT, "docs",
                                                  "bench_history.md"),
                    help="markdown history to (re)write")
    ap.add_argument("--no-write", action="store_true",
                    help="check only; leave the history file untouched")
    args = ap.parse_args(argv)

    points = trajectory.load_trajectory(args.root)
    if not points:
        print(f"error: no BENCH_N.json under {args.root}", file=sys.stderr)
        return 2
    verdict = trajectory.check_regression(points, band=args.band)

    print(f"bench trajectory: {len(points)} anchor(s), "
          f"BENCH_{points[0]['pr']}..BENCH_{points[-1]['pr']}")
    for c in verdict["checks"]:
        mark = "PASS" if c["ok"] else "FAIL"
        print(f"  [{mark}] {c['name']}: {c['detail']}")

    if not args.no_write:
        md = trajectory.render_history(points, verdict)
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}")

    return 0 if verdict["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
