#!/usr/bin/env python
"""Engine wall-clock benchmark — emits BENCH_4.json (perf-trajectory anchor).

ENGINE_VERSION 4 adds the seed axis: `sweep(..., n_seeds=k)` replicates
every grid member over k independent draw sequences vmapped *inside* the
same single trace.  The claims to verify are (a) the seed batch costs no
extra compiles — `engine.JIT_CALLS` stays at 1 per algorithm on a flat
grid whether n_seeds is 1 or 8 — and (b) the vmapped seed batch beats
re-running the sweep once per seed (which pays the compile + dispatch
chain k times).  The **seed_axis** section measures exactly that:
seeds x m grid wall-clock, vmapped vs looped, with measured compile
counts.  The ENGINE_VERSION-3 sections are retained unchanged (the
single-seed path is bit-identical, so they double as a no-regression
check against BENCH_3, embedded for comparison).

Three measurements, chosen to isolate what the ENGINE_VERSION-2 rewrite
changed relative to PR 1 (all still tracked):

1. **main** — the full 4-algorithm sweep over a *fine* worker grid
   (m = 1..32, the paper's m_max-detection regime) on the dense
   higgs-like dataset, in four engine configurations:

     pr1             the PR-1 engine: flat vmapped grids for the
                     synchronous algorithms + *sequential* legacy
                     Hogwild! — one jit compile per m, because m was a
                     `static_argname` there (S compiles total)
     sequential      the masked kernels run once per m in a Python loop
                     (the equivalence-test reference path)
     vmap_flat       everything vmapped (Hogwild! included, one compile
                     for the whole grid), flat padding to max(ms)
     engine_default  the shipped ENGINE_VERSION-2 defaults: vmapped
                     everything, bucketed padding for mini-batch and
                     ECD-PSGD, flat for DADM/Hogwild!

   The headline `speedup_vs_pr1` compares engine_default against pr1;
   the dominant term is Hogwild!'s compile count dropping from S to 1.

2. **characters** — the §IV dataset-characters pipeline: PR-1's
   Python-unrolled `csim_ref` + per-batch `ls_sync_ref` vs the fused
   `lax.scan` pipeline.

3. **bucketing_regime** — ECD-PSGD (the most m-scaled sweeper: its
   quantization work grows with the padded worker axis) on a *wide*
   sparse grid at runtime-dominated scale, flat vs bucketed padding —
   the regime bucketing exists for.  On compile-dominated toy runs
   bucketing loses (extra compiles per bucket); this entry tracks the
   crossover honestly.

jit caches are cleared between configurations so every timing includes
its own compiles, as a cold run would.  Results land in BENCH_4.json at
the repo root so the perf trajectory is tracked from this PR onward.

Usage:  PYTHONPATH=src python scripts/bench_engine.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax

from repro.core import metrics as MX
from repro.data import synth
from repro.experiments import engine
from repro.experiments import run_sweep
from repro.experiments.spec import (DatasetSpec, JobSpec, SweepSpec,
                                    ENGINE_VERSION)

ALGOS = ("minibatch", "ecd_psgd", "dadm", "hogwild")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def time_configuration(tr, te, ms, iters, eval_every, *, use_vmap, bucketed,
                       hogwild_legacy):
    """Wall-clock one full 4-algorithm sweep, cold (fresh jit caches).
    Returns (seconds, jit compile count) — every jit the engine dispatches
    here is compiled exactly once, so JIT_CALLS is the compile count."""
    jax.clear_caches()
    jits0 = engine.JIT_CALLS
    t0 = time.perf_counter()
    for algo in ALGOS:
        uv = False if (algo == "hogwild" and hogwild_legacy) else use_vmap
        engine.run_algorithm_sweep(algo, tr, te, ms, iters=iters,
                                   eval_every=eval_every, use_vmap=uv,
                                   bucketed=bucketed)
    return time.perf_counter() - t0, engine.JIT_CALLS - jits0


def time_characters(X, rng, batch_size):
    """PR-1 characters implementations vs the fused pipeline."""
    jax.clear_caches()
    t0 = time.perf_counter()
    MX.csim_ref(X, rng)
    MX.ls_sync_ref(X, batch_size)
    ref = time.perf_counter() - t0
    jax.clear_caches()
    t0 = time.perf_counter()
    MX.csim(X, rng)
    MX.ls_sync(X, batch_size)
    fused = time.perf_counter() - t0
    return ref, fused


def time_bucketing_regime(ms, iters, eval_every, n, d):
    """ECD-PSGD flat vs bucketed on a wide sparse grid (runtime regime)."""
    ds = synth.make_realsim_like(jax.random.PRNGKey(1), n=n, d=d,
                                 density=0.05)
    tr, te = ds.split(key=jax.random.PRNGKey(1))
    out = {}
    for label, bucketed in (("flat", False), ("bucketed", True)):
        jax.clear_caches()
        t0 = time.perf_counter()
        engine.run_algorithm_sweep("ecd_psgd", tr, te, ms, iters=iters,
                                   eval_every=eval_every, bucketed=bucketed)
        out[label] = time.perf_counter() - t0
    return out


def time_seed_axis(tr, te, ms, iters, eval_every, n_seeds):
    """seeds x m grid: one vmapped trace vs a Python loop over seeds.

    Both paths produce the same replicate curves (looped seed s uses
    fold_in(key, s), the vmapped batch's exact per-seed keys); the
    vmapped path pays ONE compile per algorithm (flat grids) regardless
    of n_seeds, while the loop re-enters the engine per seed — each entry
    builds a fresh jit wrapper, so it pays the trace + compile + dispatch
    chain every time, exactly what a pre-seed-axis caller replicating by
    hand would pay.
    """
    out = {}
    for algo in ("minibatch", "hogwild"):
        jax.clear_caches()
        jits0 = engine.JIT_CALLS
        t0 = time.perf_counter()
        engine.run_algorithm_sweep(algo, tr, te, ms, iters=iters,
                                   eval_every=eval_every, bucketed=False,
                                   n_seeds=n_seeds)
        vmapped = time.perf_counter() - t0
        vmapped_jits = engine.JIT_CALLS - jits0
        jax.clear_caches()
        jits0 = engine.JIT_CALLS
        t0 = time.perf_counter()
        key = jax.random.PRNGKey(0)
        for s in range(n_seeds):
            engine.run_algorithm_sweep(
                algo, tr, te, ms, iters=iters, eval_every=eval_every,
                bucketed=False,
                key=key if s == 0 else jax.random.fold_in(key, s))
        looped = time.perf_counter() - t0
        out[algo] = {"vmapped_s": vmapped, "looped_s": looped,
                     "speedup": looped / max(vmapped, 1e-9),
                     "jit_compiles_vmapped": vmapped_jits,
                     "jit_compiles_looped": engine.JIT_CALLS - jits0}
    return out


def time_cache_roundtrip(ms, iters, eval_every, n, d):
    """Fresh vs cached `run_sweep` through the artifact cache."""
    spec = SweepSpec(
        name="bench_engine", description="BENCH_2 cache round-trip",
        ms=tuple(ms), iters=iters, eval_every=eval_every,
        datasets={"d0": DatasetSpec("higgs_like", {"n": n, "d": d})},
        jobs=tuple(JobSpec(a, "d0") for a in ALGOS)).validate()
    with tempfile.TemporaryDirectory() as cache_dir:
        t0 = time.perf_counter()
        r1 = run_sweep(spec, cache_dir=cache_dir)
        fresh = time.perf_counter() - t0
        t0 = time.perf_counter()
        r2 = run_sweep(spec, cache_dir=cache_dir)
        cached = time.perf_counter() - t0
    assert r1["cache"]["hit"] is False and r2["cache"]["hit"] is True
    return fresh, cached


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=1500)
    p.add_argument("--d", type=int, default=28)
    p.add_argument("--iters", type=int, default=4000)
    p.add_argument("--eval-every", type=int, default=400)
    p.add_argument("--m-max", type=int, default=32,
                   help="main grid is every integer 1..m_max")
    p.add_argument("--quick", action="store_true",
                   help="small sizes for a fast smoke of the bench itself")
    p.add_argument("--seeds", type=int, default=8,
                   help="seed replicates for the seed_axis section")
    p.add_argument("--out", default=None,
                   help="output path (default: BENCH_4.json at the repo "
                        "root; quick mode defaults elsewhere so a smoke "
                        "never overwrites the committed perf anchor)")
    args = p.parse_args(argv)
    if args.quick:
        args.n, args.d, args.iters, args.eval_every = 300, 12, 400, 100
        args.m_max = 8
        args.seeds = min(args.seeds, 4)
    if args.out is None:
        args.out = (os.path.join(tempfile.gettempdir(), "BENCH_4.quick.json")
                    if args.quick else os.path.join(ROOT, "BENCH_4.json"))
    ms = list(range(1, args.m_max + 1))

    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=args.n, d=args.d)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    kw = dict(ms=ms, iters=args.iters, eval_every=args.eval_every)

    configs = {
        "pr1": dict(use_vmap=True, bucketed=False, hogwild_legacy=True),
        "sequential": dict(use_vmap=False, bucketed=False,
                           hogwild_legacy=True),
        "vmap_flat": dict(use_vmap=True, bucketed=False,
                          hogwild_legacy=False),
        # bucketed=None -> per-sweeper defaults (the shipped config)
        "engine_default": dict(use_vmap=True, bucketed=None,
                               hogwild_legacy=False),
    }
    timings, jit_counts = {}, {}
    for name, cfg in configs.items():
        timings[name], jit_counts[name] = time_configuration(
            tr, te, **kw, **cfg)
        print(f"{name:>15}: {timings[name]:7.2f} s "
              f"({jit_counts[name]} compiles)")

    chars_ref, chars_fused = time_characters(
        ds.X[:min(400, args.n)], rng=args.m_max, batch_size=args.m_max)
    print(f"{'chars ref':>15}: {chars_ref:7.2f} s")
    print(f"{'chars fused':>15}: {chars_fused:7.2f} s")

    seed_axis = time_seed_axis(tr, te, ms, args.iters, args.eval_every,
                               args.seeds)
    for algo, r in seed_axis.items():
        print(f"{algo + ' seeds':>15}: vmapped {r['vmapped_s']:6.2f} s "
              f"({r['jit_compiles_vmapped']} compiles)  looped "
              f"{r['looped_s']:6.2f} s ({r['jit_compiles_looped']} "
              f"compiles)  {r['speedup']:.2f}x")

    if args.quick:
        bucket_cfg = dict(ms=[1, 2, 4, 8], iters=300, eval_every=100,
                          n=200, d=40)
    else:
        bucket_cfg = dict(ms=[1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64],
                          iters=5000, eval_every=500, n=800, d=400)
    regime = time_bucketing_regime(**bucket_cfg)
    print(f"{'ecd flat':>15}: {regime['flat']:7.2f} s")
    print(f"{'ecd bucketed':>15}: {regime['bucketed']:7.2f} s")

    fresh, cached = time_cache_roundtrip(ms, args.iters, args.eval_every,
                                         args.n, args.d)
    print(f"{'cache fresh':>15}: {fresh:7.2f} s")
    print(f"{'cache hit':>15}: {cached:7.2f} s")

    speedup = (timings["pr1"] + chars_ref) / (timings["engine_default"]
                                              + chars_fused)
    # embed the PR-3 anchor for the within-noise comparison, if present
    # (the single-seed path is bit-identical to ENGINE_VERSION 3)
    vs_bench3 = None
    b3_path = os.path.join(ROOT, "BENCH_3.json")
    if not args.quick and os.path.exists(b3_path):
        with open(b3_path) as f:
            b3 = json.load(f)["main"]["wall_clock_s"]
        vs_bench3 = {
            "bench3_wall_clock_s": b3,
            "ratio_engine_default": timings["engine_default"]
            / max(b3["engine_default"], 1e-9),
        }

    payload = {
        "bench": "engine_sweep",
        "engine_version": ENGINE_VERSION,
        "backend": jax.default_backend(),
        "quick": args.quick,
        "speedup_vs_pr1": speedup,
        "main": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "eval_every": args.eval_every,
                       "ms": f"1..{args.m_max}"},
            "wall_clock_s": timings,
            "jit_compiles": jit_counts,
            "hogwild_compiles": {"pr1": len(ms), "vmap": 1},
        },
        "characters": {
            "config": {"rows": min(400, args.n), "rng": args.m_max,
                       "batch_size": args.m_max},
            "ref_s": chars_ref, "fused_s": chars_fused,
            "speedup": chars_ref / max(chars_fused, 1e-9),
        },
        "bucketing_regime": {
            "config": bucket_cfg,
            "wall_clock_s": regime,
            "speedup": regime["flat"] / max(regime["bucketed"], 1e-9),
            "buckets": [{"ms": [bucket_cfg["ms"][i] for i in pos],
                         "m_pad": m_pad}
                        for pos, m_pad in engine._buckets(bucket_cfg["ms"])],
        },
        "seed_axis": {
            "config": {"ms": f"1..{args.m_max}", "n_seeds": args.seeds,
                       "iters": args.iters, "bucketed": False},
            "results": seed_axis,
        },
        "cache_roundtrip_s": {"fresh": fresh, "cached": cached,
                              "speedup": fresh / max(cached, 1e-9)},
        "vs_bench3": vs_bench3,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"speedup vs PR-1 engine: {speedup:.2f}x -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
