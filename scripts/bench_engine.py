#!/usr/bin/env python
"""Engine wall-clock benchmark — emits BENCH_10.json (perf-trajectory anchor).

PR 10 adds the live observability plane (`repro.service.http`,
docs/observability.md): an HTTP transport serving the advisor plus
``GET /metrics`` / ``/healthz`` / ``/flight`` / ``/trace``, and the
always-on flight recorder the sweep publishes per-job progress events
into.  The **observability** section measures its costs: the per-publish
flight-recorder cost in isolation (a lock + deque append — the only new
always-on work on the sweep path, a handful per sweep), the
``/metrics`` and ``/flight`` scrape latencies against a live server, and
the end-to-end tax of running the full engine_default sweep *while a
scraper polls both endpoints* vs unobserved (warm jit caches, fresh
cache dir per run, interleaved and min-reduced — same protocol as the
resilience/telemetry sections).  The claim: the plane is observational —
scraping reads registry/recorder state beside the sweep, so the
concurrent-scrape tax stays within noise, and artifact bytes are
identical either way (tests/test_http.py).  The **vs_bench9** block
embeds BENCH_9's engine_default wall-clock for the non-regression
comparison; `scripts/bench_check.py` additionally gates the whole
BENCH_2..10 trajectory (docs/bench_history.md).

PR 9 adds `repro.telemetry` (docs/observability.md): span tracing plus a
process metrics registry, instrumented through the engine, runner,
distributed, and service layers.  The contract is *zero overhead when
disabled*: with no tracer installed every `trace.span(...)` returns one
shared no-op object, and the engine dispatches the jitted grid exactly
as before (the AOT lower/compile/execute split only happens under an
active tracer).  The **telemetry** section measures that contract: the
full engine_default sweep through `run_sweep` with tracing off vs on
(warm jit caches, fresh cache dir per run, off/on *interleaved* and
min-reduced over repeats so a slow system phase hits both labels), plus
the per-span record cost isolated.  The claims: disabled overhead < 1%
(the acceptance gate — the off path must stay within noise of the
**vs_bench8** anchor below), and the enabled tax is bounded and
reported honestly (the traced path re-lowers each bucket once to split
compile from execute, so it pays roughly one extra trace per bucket).
The **vs_bench8** block embeds BENCH_8's engine_default wall-clock for
the non-regression comparison: telemetry is observational only —
artifact bytes are identical on/off (tests/test_telemetry.py), so the
original 4-algorithm sweep must stay within noise.

PR 8 adds the advisor service (`repro.service`, docs/service.md).  The
**service** section measures its three claims on this container: (a)
*batched vs looped probe latency* — N dataset-character probes through
the slot-batched front end (one masked-batch jitted call) against N
sequential `from_dataset` calls, warm; (b) *dedup hit behavior* — N
concurrent forced escalations sharing one SweepSpec fingerprint, with
the executed-sweep count read off `runner.SWEEP_COMPUTES` (the claim is
exactly 1); (c) the *analytic-tier answer fraction* on a mixed workload
(raw high-confidence probes + spec-carrying forced escalations) — the
early-exit rate that keeps heavy traffic off the sweep engine.  The
**vs_bench7** block embeds BENCH_7's engine_default wall-clock for the
non-regression comparison: the service is a new layer over the engine
(`run_sweep` gained dedup/cache-cap paths that are no-ops by default),
so the original 4-algorithm sweep must stay within noise.

PR 7 adds crash-safe sweep execution (`repro.resilience`): the runner
journals every completed job to an fsync'd sidecar so a killed sweep
resumes from the last finished job, plus bounded retries and per-job
health status.  The **resilience** section measures what that safety
costs on the hot path: the full engine_default sweep through `run_sweep`
with journaling on vs off (warm jit caches, fresh cache dir per run, min
over repeats), where the on-path pays one append+fsync per job plus one
journal probe and unlink per sweep.  The claim: overhead < 2% of the
sweep wall-clock.  The **vs_bench6** block embeds BENCH_6's
engine_default wall-clock for the non-regression comparison — the fault
path is dormant unless a job opts in (`fault=None` compiles the
unchanged pipelines), so the original 4-algorithm sweep must stay within
noise.

PR 6 registers three critical-parameter algorithms (momentum, local_sgd,
async_svrg) against the UNCHANGED ENGINE_VERSION-5 engine.  The
**new_algorithms** section times each of them through the same generic
sweep path the paper's four take (cold, fine worker grid) and records the
jit compile count — one compile per flat grid (or per bucket), exactly
like the incumbents, because nothing algorithm-specific leaks into the
engine.  The **vs_bench5** block embeds BENCH_5's engine_default
wall-clock for the non-regression comparison: the registration-only PR
must leave the original 4-algorithm sweep within noise.

ENGINE_VERSION 5 adds device-mesh sharded execution (`repro.distributed`):
each bucket's batched (m-grid x seed) simulation can be laid over every
available XLA device with mesh-invariant results.  The **distributed**
section measures the claims: the full engine_default sweep on 1 vs N
forced host devices (each count in its own subprocess — XLA locks the
device count at first init), the jit compile count per mesh size (must
stay 1 per bucket: sharding reuses the same jitted vmap, it never
re-traces per device), and the racing-mode sharded Hogwild!
(`repro.distributed.hogwild_shards`) against the sequential staleness
oracle at the same server-iteration budget.  Host-device CPU sharding
is real parallelism (one XLA executable slice per device) but only up
to the physical core count, and a single-device run already uses every
core through intra-op threads — so on this 2-core reference container
the expected sharded wall-clock is ~parity, and the stable measured
claims are the structural ones: compile count identical on every mesh
size, results mesh-invariant (the distributed config note records the
full reasoning).

ENGINE_VERSION 4's seed axis claims are retained: (a) the seed batch
costs no extra compiles — `engine.JIT_CALLS` stays at 1 per algorithm on
a flat grid whether n_seeds is 1 or 8 — and (b) the vmapped seed batch
beats re-running the sweep once per seed.  The **seed_axis** section
measures exactly that; the older sections are retained unchanged (the
single-seed single-device path is bit-identical, so they double as a
no-regression check against BENCH_4, embedded for comparison).

Three measurements, chosen to isolate what the ENGINE_VERSION-2 rewrite
changed relative to PR 1 (all still tracked):

1. **main** — the full 4-algorithm sweep over a *fine* worker grid
   (m = 1..32, the paper's m_max-detection regime) on the dense
   higgs-like dataset, in four engine configurations:

     pr1             the PR-1 engine: flat vmapped grids for the
                     synchronous algorithms + *sequential* legacy
                     Hogwild! — one jit compile per m, because m was a
                     `static_argname` there (S compiles total)
     sequential      the masked kernels run once per m in a Python loop
                     (the equivalence-test reference path)
     vmap_flat       everything vmapped (Hogwild! included, one compile
                     for the whole grid), flat padding to max(ms)
     engine_default  the shipped ENGINE_VERSION-2 defaults: vmapped
                     everything, bucketed padding for mini-batch and
                     ECD-PSGD, flat for DADM/Hogwild!

   The headline `speedup_vs_pr1` compares engine_default against pr1;
   the dominant term is Hogwild!'s compile count dropping from S to 1.

2. **characters** — the §IV dataset-characters pipeline: PR-1's
   Python-unrolled `csim_ref` + per-batch `ls_sync_ref` vs the fused
   `lax.scan` pipeline.

3. **bucketing_regime** — ECD-PSGD (the most m-scaled sweeper: its
   quantization work grows with the padded worker axis) on a *wide*
   sparse grid at runtime-dominated scale, flat vs bucketed padding —
   the regime bucketing exists for.  On compile-dominated toy runs
   bucketing loses (extra compiles per bucket); this entry tracks the
   crossover honestly.

jit caches are cleared between configurations so every timing includes
its own compiles, as a cold run would.  Results land in BENCH_10.json at
the repo root so the perf trajectory is tracked from this PR onward.

Usage:  PYTHONPATH=src python scripts/bench_engine.py [--quick]
        (--dist-worker N is internal: re-entered in a subprocess with N
        forced host devices for the distributed section)
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import jax

from repro.core import metrics as MX
from repro.data import synth
from repro.experiments import engine
from repro.experiments import run_sweep
from repro.experiments.spec import (DatasetSpec, JobSpec, SweepSpec,
                                    ENGINE_VERSION)

ALGOS = ("minibatch", "ecd_psgd", "dadm", "hogwild")
# the PR-6 critical-parameter registrations — benchmarked separately so
# the `main` section stays comparable against every earlier BENCH anchor
NEW_ALGOS = ("momentum", "local_sgd", "async_svrg")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def time_configuration(tr, te, ms, iters, eval_every, *, use_vmap, bucketed,
                       hogwild_legacy):
    """Wall-clock one full 4-algorithm sweep, cold (fresh jit caches).
    Returns (seconds, jit compile count) — every jit the engine dispatches
    here is compiled exactly once, so JIT_CALLS is the compile count."""
    jax.clear_caches()
    jits0 = engine.JIT_CALLS
    t0 = time.perf_counter()
    for algo in ALGOS:
        uv = False if (algo == "hogwild" and hogwild_legacy) else use_vmap
        engine.run_algorithm_sweep(algo, tr, te, ms, iters=iters,
                                   eval_every=eval_every, use_vmap=uv,
                                   bucketed=bucketed)
    return time.perf_counter() - t0, engine.JIT_CALLS - jits0


def time_new_algorithms(tr, te, ms, iters, eval_every):
    """Each PR-6 registration through the engine's shipped defaults, cold.

    Per-algorithm (not one lump) so a future regression is attributable;
    sequential reruns the same sweep with use_vmap=False.  The stable
    claim is the compile pattern matching the incumbents — 1 jit per
    bucket (momentum/local_sgd bucket by default, async_svrg is
    force_flat like hogwild -> exactly 1) — not a vmap speedup: at this
    compile-dominated scale the bucketed grids with per-worker state
    (local_sgd's replicas) can lose to the sequential loop, same
    crossover the bucketing_regime section tracks."""
    out = {}
    for algo in NEW_ALGOS:
        entry = {}
        for label, use_vmap in (("vmapped", True), ("sequential", False)):
            jax.clear_caches()
            jits0 = engine.JIT_CALLS
            t0 = time.perf_counter()
            engine.run_algorithm_sweep(algo, tr, te, ms, iters=iters,
                                       eval_every=eval_every,
                                       use_vmap=use_vmap)
            entry[label + "_s"] = time.perf_counter() - t0
            entry["jit_compiles_" + label] = engine.JIT_CALLS - jits0
        entry["speedup"] = entry["sequential_s"] / max(entry["vmapped_s"],
                                                       1e-9)
        out[algo] = entry
    return out


def time_characters(X, rng, batch_size):
    """PR-1 characters implementations vs the fused pipeline."""
    jax.clear_caches()
    t0 = time.perf_counter()
    MX.csim_ref(X, rng)
    MX.ls_sync_ref(X, batch_size)
    ref = time.perf_counter() - t0
    jax.clear_caches()
    t0 = time.perf_counter()
    MX.csim(X, rng)
    MX.ls_sync(X, batch_size)
    fused = time.perf_counter() - t0
    return ref, fused


def time_bucketing_regime(ms, iters, eval_every, n, d):
    """ECD-PSGD flat vs bucketed on a wide sparse grid (runtime regime)."""
    ds = synth.make_realsim_like(jax.random.PRNGKey(1), n=n, d=d,
                                 density=0.05)
    tr, te = ds.split(key=jax.random.PRNGKey(1))
    out = {}
    for label, bucketed in (("flat", False), ("bucketed", True)):
        jax.clear_caches()
        t0 = time.perf_counter()
        engine.run_algorithm_sweep("ecd_psgd", tr, te, ms, iters=iters,
                                   eval_every=eval_every, bucketed=bucketed)
        out[label] = time.perf_counter() - t0
    return out


def time_seed_axis(tr, te, ms, iters, eval_every, n_seeds):
    """seeds x m grid: one vmapped trace vs a Python loop over seeds.

    Both paths produce the same replicate curves (looped seed s uses
    fold_in(key, s), the vmapped batch's exact per-seed keys); the
    vmapped path pays ONE compile per algorithm (flat grids) regardless
    of n_seeds, while the loop re-enters the engine per seed — each entry
    builds a fresh jit wrapper, so it pays the trace + compile + dispatch
    chain every time, exactly what a pre-seed-axis caller replicating by
    hand would pay.
    """
    out = {}
    for algo in ("minibatch", "hogwild"):
        jax.clear_caches()
        jits0 = engine.JIT_CALLS
        t0 = time.perf_counter()
        engine.run_algorithm_sweep(algo, tr, te, ms, iters=iters,
                                   eval_every=eval_every, bucketed=False,
                                   n_seeds=n_seeds)
        vmapped = time.perf_counter() - t0
        vmapped_jits = engine.JIT_CALLS - jits0
        jax.clear_caches()
        jits0 = engine.JIT_CALLS
        t0 = time.perf_counter()
        key = jax.random.PRNGKey(0)
        for s in range(n_seeds):
            engine.run_algorithm_sweep(
                algo, tr, te, ms, iters=iters, eval_every=eval_every,
                bucketed=False,
                key=key if s == 0 else jax.random.fold_in(key, s))
        looped = time.perf_counter() - t0
        out[algo] = {"vmapped_s": vmapped, "looped_s": looped,
                     "speedup": looped / max(vmapped, 1e-9),
                     "jit_compiles_vmapped": vmapped_jits,
                     "jit_compiles_looped": engine.JIT_CALLS - jits0}
    return out


def dist_worker(args) -> int:
    """Subprocess body for the distributed section: time the full
    engine_default sweep and the racing Hogwild! under THIS process's
    forced device count, print one JSON line.  Runs after the parent set
    XLA_FLAGS, so jax sees exactly --dist-worker devices."""
    from repro.core.algorithms import run_hogwild
    from repro.distributed import (get_mesh, hogwild_shards,
                                   run_hogwild_sharded)

    dmesh = get_mesh()
    assert dmesh.n_devices == args.dist_worker, (
        f"XLA gave {dmesh.n_devices} devices, wanted {args.dist_worker}")
    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=args.n, d=args.d)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    ms = list(range(1, args.m_max + 1))

    jax.clear_caches()
    jits0 = engine.JIT_CALLS
    t0 = time.perf_counter()
    for algo in ALGOS:
        engine.run_algorithm_sweep(algo, tr, te, ms, iters=args.iters,
                                   eval_every=args.eval_every, mesh=dmesh)
    sweep_s = time.perf_counter() - t0
    compiles = engine.JIT_CALLS - jits0

    # the compute-dominated regime: wide features (d=400) make per-step
    # FLOPs dominate the scan's fixed per-iteration overhead, which is
    # what sharding can actually divide — the fine d=28 grid above is
    # overhead-bound (same 4000-step scan on every device) and is
    # expected NOT to speed up; this one is
    wide_iters = max(300, args.iters // 2)
    wide = synth.make_realsim_like(jax.random.PRNGKey(1), n=800, d=400,
                                   density=0.05)
    trw, tew = wide.split(key=jax.random.PRNGKey(1))
    jax.clear_caches()
    jits0 = engine.JIT_CALLS
    t0 = time.perf_counter()
    for algo in ALGOS:
        engine.run_algorithm_sweep(algo, trw, tew, ms, iters=wide_iters,
                                   eval_every=wide_iters // 5, mesh=dmesh)
    wide_s = time.perf_counter() - t0
    wide_compiles = engine.JIT_CALLS - jits0

    # racing Hogwild! throughput: m workers over the mesh vs the
    # sequential staleness oracle at the same server-iteration budget
    m = min(8, args.m_max)
    ev = m * max(1, args.eval_every // m)
    race_kw = dict(m=m, iters=args.iters, gamma=0.05, eval_every=ev)
    jax.clear_caches()
    race_jits0 = hogwild_shards.JIT_CALLS
    t0 = time.perf_counter()
    run_hogwild_sharded(tr, te, mesh=dmesh, **race_kw)
    race_s = time.perf_counter() - t0
    race_compiles = hogwild_shards.JIT_CALLS - race_jits0
    jax.clear_caches()
    t0 = time.perf_counter()
    run_hogwild(tr, te, **race_kw)
    oracle_s = time.perf_counter() - t0

    print(json.dumps({
        "devices": dmesh.n_devices,
        "engine_default_s": sweep_s,
        "jit_compiles": compiles,
        "wide_compute": {"n": 800, "d": 400, "iters": wide_iters,
                         "wall_clock_s": wide_s,
                         "jit_compiles": wide_compiles},
        "hogwild_race": {"m": m, "iters": args.iters, "race_s": race_s,
                         "jit_compiles": race_compiles,
                         "sequential_oracle_s": oracle_s,
                         "throughput_vs_oracle":
                             oracle_s / max(race_s, 1e-9)},
    }))
    return 0


def time_distributed(args, device_counts=(1, 8), repeats=2):
    """Spawn one subprocess per mesh size (the device count is locked at
    first jax init, so 1-vs-N cannot share a process).  Each mesh size
    runs ``repeats`` times and keeps the per-metric minimum — shared
    containers show large run-to-run noise, and the minimum is the least
    contaminated estimate of what the configuration can do."""
    out = {}
    for ndev in device_counts:
        env = {**os.environ,
               "XLA_FLAGS": f"--xla_force_host_platform_device_count={ndev}",
               "PYTHONPATH": "src" + (
                   os.pathsep + os.environ["PYTHONPATH"]
                   if os.environ.get("PYTHONPATH") else "")}
        cmd = [sys.executable, os.path.abspath(__file__),
               "--dist-worker", str(ndev),
               "--n", str(args.n), "--d", str(args.d),
               "--iters", str(args.iters),
               "--eval-every", str(args.eval_every),
               "--m-max", str(args.m_max)]
        best = None
        for _ in range(repeats):
            r = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                               text=True, timeout=1800)
            if r.returncode != 0:
                raise RuntimeError(f"dist worker ({ndev} devices) failed:\n"
                                   f"{r.stderr[-2000:]}")
            j = json.loads(r.stdout.strip().splitlines()[-1])
            if best is None:
                best = j
            else:
                best["engine_default_s"] = min(best["engine_default_s"],
                                               j["engine_default_s"])
                best["wide_compute"]["wall_clock_s"] = min(
                    best["wide_compute"]["wall_clock_s"],
                    j["wide_compute"]["wall_clock_s"])
                hb, hj = best["hogwild_race"], j["hogwild_race"]
                hb["race_s"] = min(hb["race_s"], hj["race_s"])
                hb["sequential_oracle_s"] = min(hb["sequential_oracle_s"],
                                                hj["sequential_oracle_s"])
                hb["throughput_vs_oracle"] = (
                    hb["sequential_oracle_s"] / max(hb["race_s"], 1e-9))
        best["repeats"] = repeats
        out[f"devices_{ndev}"] = best
    return out


def time_resilience(ms, iters, eval_every, n, d, repeats=5):
    """run_sweep with journaling on vs off: the crash-safety tax.

    Warm jit caches (one untimed warm-up run first), a fresh cache dir
    per timed run so every run is a real compute that stores its
    artifact.  The journal's whole on-path is ~4 fsync'd appends — sub-ms
    each, measured directly below as ``append_fsync_ms`` — so the
    end-to-end delta sits far below run-to-run dispatch noise on a
    shared container; the off/on runs are *interleaved* and min-reduced
    over ``repeats`` so a slow system phase hits both labels instead of
    biasing whichever ran second."""
    from repro.resilience import journal as journal_mod

    spec = SweepSpec(
        name="bench_resilience", description="journal overhead probe",
        ms=tuple(ms), iters=iters, eval_every=eval_every,
        datasets={"d0": DatasetSpec("higgs_like", {"n": n, "d": d})},
        jobs=tuple(JobSpec(a, "d0") for a in ALGOS)).validate()
    out = {"journal_off_s": float("inf"), "journal_on_s": float("inf")}
    with tempfile.TemporaryDirectory() as root:
        run_sweep(spec, cache_dir=os.path.join(root, "warm"), journal=False)
        for r in range(repeats):
            for label, journal in (("journal_off", False),
                                   ("journal_on", True)):
                t0 = time.perf_counter()
                run_sweep(spec, cache_dir=os.path.join(root,
                                                       f"{label}{r}"),
                          journal=journal)
                out[label + "_s"] = min(out[label + "_s"],
                                        time.perf_counter() - t0)
        # the journal's actual disk cost, isolated: one durable append of
        # a representative per-job entry on this filesystem
        jpath = os.path.join(root, "probe.jsonl")
        t0 = time.perf_counter()
        for i in range(50):
            journal_mod.append_entry(jpath, "f" * 64, f"k{i}",
                                     {"losses": [[0.5] * 10] * len(ms)})
        out["append_fsync_ms"] = (time.perf_counter() - t0) / 50 * 1000
    out["overhead_frac"] = (out["journal_on_s"]
                            / max(out["journal_off_s"], 1e-9) - 1.0)
    return out


def time_telemetry(ms, iters, eval_every, n, d, repeats=5):
    """run_sweep with tracing off vs on: the observability tax.

    Same protocol as the resilience section: warm jit caches (one
    untimed warm-up), a fresh cache dir per timed run so every run is a
    real compute, off/on interleaved and min-reduced over ``repeats``.
    The *off* label is the acceptance gate (disabled overhead < 1% — the
    no-op span path plus always-on counters must be free at sweep
    granularity); the *on* label reports the enabled tax honestly: the
    traced path re-lowers each bucket once to separate compile from
    execute, so it pays ~one extra trace per bucket plus per-span
    recording, measured in isolation as ``span_record_us``."""
    from repro.telemetry import trace

    spec = SweepSpec(
        name="bench_telemetry", description="telemetry overhead probe",
        ms=tuple(ms), iters=iters, eval_every=eval_every,
        datasets={"d0": DatasetSpec("higgs_like", {"n": n, "d": d})},
        jobs=tuple(JobSpec(a, "d0") for a in ALGOS)).validate()
    out = {"trace_off_s": float("inf"), "trace_on_s": float("inf")}
    with tempfile.TemporaryDirectory() as root:
        run_sweep(spec, cache_dir=os.path.join(root, "warm"))
        for r in range(repeats):
            for label, traced in (("trace_off", False), ("trace_on", True)):
                if traced:
                    trace.start()
                try:
                    t0 = time.perf_counter()
                    run_sweep(spec,
                              cache_dir=os.path.join(root, f"{label}{r}"))
                    out[label + "_s"] = min(out[label + "_s"],
                                            time.perf_counter() - t0)
                finally:
                    if traced:
                        tracer = trace.stop()
        out["spans_per_traced_sweep"] = len(tracer.events)
    # per-span record cost, isolated: enter/exit of an attributed span
    trace.start()
    t0 = time.perf_counter()
    for i in range(10000):
        with trace.span("probe", i=i):
            pass
    out["span_record_us"] = (time.perf_counter() - t0) / 10000 * 1e6
    trace.stop()
    t0 = time.perf_counter()
    for i in range(10000):
        with trace.span("probe", i=i):
            pass
    out["noop_span_us"] = (time.perf_counter() - t0) / 10000 * 1e6
    # disabled-vs-baseline lands in vs_bench8 (this whole section already
    # runs with telemetry "off" unless trace.start() is live); on-vs-off
    # is the honest enabled tax
    out["enabled_overhead_frac"] = (out["trace_on_s"]
                                    / max(out["trace_off_s"], 1e-9) - 1.0)
    return out


def time_observability(ms, iters, eval_every, n, d, repeats=3):
    """PR-10 live observability plane: publish cost, scrape latencies,
    and the concurrent-scrape tax on a real sweep.

    Three numbers: (a) the per-event flight-recorder publish cost in
    isolation — the only new always-on work on the sweep path (a lock +
    deque append, a handful per sweep); (b) ``GET /metrics`` and
    ``GET /flight`` latency against a live `ServiceServer` (warm, min
    over 50 requests — what one scrape costs an operator); (c) the full
    engine_default sweep through `run_sweep` unobserved vs with a
    scraper thread polling both endpoints every 50 ms, interleaved and
    min-reduced over ``repeats`` — the observational claim at sweep
    granularity (scrapes read registry/recorder state beside the sweep,
    never in it)."""
    import threading
    import urllib.request

    from repro.service.http import ServiceServer
    from repro.telemetry.recorder import FlightRecorder

    out = {}
    rec = FlightRecorder()
    t0 = time.perf_counter()
    for i in range(10000):
        rec.publish("bench", i=i, job="probe")
    out["publish_us"] = (time.perf_counter() - t0) / 10000 * 1e6

    spec = SweepSpec(
        name="bench_observability", description="scrape tax probe",
        ms=tuple(ms), iters=iters, eval_every=eval_every,
        datasets={"d0": DatasetSpec("higgs_like", {"n": n, "d": d})},
        jobs=tuple(JobSpec(a, "d0") for a in ALGOS)).validate()

    with ServiceServer(None) as server:
        for path in ("/metrics", "/flight"):
            url = server.url + path
            urllib.request.urlopen(url).read()      # warm
            best = float("inf")
            for _ in range(50):
                t0 = time.perf_counter()
                urllib.request.urlopen(url).read()
                best = min(best, time.perf_counter() - t0)
            out[path.strip("/") + "_scrape_ms"] = best * 1000

        with tempfile.TemporaryDirectory() as root:
            run_sweep(spec, cache_dir=os.path.join(root, "warm"))
            out["sweep_unobserved_s"] = float("inf")
            out["sweep_scraped_s"] = float("inf")
            for r in range(repeats):
                for label, scraped in (("sweep_unobserved", False),
                                       ("sweep_scraped", True)):
                    stop = threading.Event()

                    def _scraper():
                        # a real poller: full /metrics per scrape (how
                        # Prometheus reads it), /flight tailed by cursor
                        # (how --watch reads it)
                        since = 0
                        while not stop.wait(0.05):
                            urllib.request.urlopen(
                                server.url + "/metrics").read()
                            snap = json.load(urllib.request.urlopen(
                                f"{server.url}/flight?since={since}"))
                            since = snap.get("seq", since)

                    t = threading.Thread(target=_scraper, daemon=True)
                    if scraped:
                        t.start()
                    try:
                        t0 = time.perf_counter()
                        run_sweep(spec, cache_dir=os.path.join(
                            root, f"{label}{r}"))
                        out[label + "_s"] = min(out[label + "_s"],
                                                time.perf_counter() - t0)
                    finally:
                        stop.set()
                        if scraped:
                            t.join()
    out["scrape_overhead_frac"] = (out["sweep_scraped_s"]
                                   / max(out["sweep_unobserved_s"], 1e-9)
                                   - 1.0)
    return out


def time_cache_roundtrip(ms, iters, eval_every, n, d):
    """Fresh vs cached `run_sweep` through the artifact cache."""
    spec = SweepSpec(
        name="bench_engine", description="BENCH_2 cache round-trip",
        ms=tuple(ms), iters=iters, eval_every=eval_every,
        datasets={"d0": DatasetSpec("higgs_like", {"n": n, "d": d})},
        jobs=tuple(JobSpec(a, "d0") for a in ALGOS)).validate()
    with tempfile.TemporaryDirectory() as cache_dir:
        t0 = time.perf_counter()
        r1 = run_sweep(spec, cache_dir=cache_dir)
        fresh = time.perf_counter() - t0
        t0 = time.perf_counter()
        r2 = run_sweep(spec, cache_dir=cache_dir)
        cached = time.perf_counter() - t0
    assert r1["cache"]["hit"] is False and r2["cache"]["hit"] is True
    return fresh, cached


def time_service(n_probes, n, d, sweep_iters, sweep_eval_every):
    """PR-8 advisor service: batched vs looped probe latency, single-flight
    escalation dedup, and the analytic-tier early-exit fraction.

    Latency: N dataset-character probes through the slot-batched front
    end (one masked-batch jitted call for all resident slots) vs N
    sequential `ScalabilityAdvisor.from_dataset` calls, both warm (one
    untimed warm-up each) — the claim is the batched path amortizing
    per-probe dispatch, not a FLOP win.  Dedup: N threads force-escalate
    the same SweepSpec fingerprint concurrently; `runner.SWEEP_COMPUTES`
    must rise by exactly 1 (single-flight) and every waiter must get the
    one stored artifact.  Mixed workload: raw high-confidence probes
    answer at the analytic tier while spec-carrying forced escalations
    go to the measured tier — the recorded fraction is the traffic the
    service keeps off the sweep engine entirely."""
    import threading

    import numpy as np

    from repro.core.advisor import ScalabilityAdvisor
    from repro.experiments import runner as runner_mod
    from repro.service.api import AdvisorService, ProbeRequest

    keys = jax.random.split(jax.random.PRNGKey(42), n_probes)
    Xs = [np.asarray(synth.make_higgs_like(k, n=n, d=d).X) for k in keys]
    out = {"config": {"n_probes": n_probes, "n": n, "d": d,
                      "sweep_ms": [1, 2, 4], "sweep_iters": sweep_iters}}
    with tempfile.TemporaryDirectory() as cache_dir:
        svc = AdvisorService(cache_dir=cache_dir, sweep_ms=(1, 2, 4),
                             sweep_iters=sweep_iters,
                             sweep_eval_every=sweep_eval_every)
        adv = ScalabilityAdvisor()
        svc.probe(ProbeRequest(X=Xs[0]))     # warm the batched envelope
        adv.from_dataset(Xs[0])              # warm the scalar path
        t0 = time.perf_counter()
        batched_resp = svc.probe_batch([ProbeRequest(X=X) for X in Xs])
        batched = time.perf_counter() - t0
        assert all(r.status == "ok" for r in batched_resp)
        t0 = time.perf_counter()
        for X in Xs:
            adv.from_dataset(X)
        looped = time.perf_counter() - t0
        out["probe_latency"] = {
            "batched_s": batched, "looped_s": looped,
            "speedup": looped / max(batched, 1e-9)}

        # N concurrent forced escalations of one fingerprint -> ONE sweep
        before = runner_mod.SWEEP_COMPUTES
        responses = [None] * n_probes

        def _escalated(i):
            responses[i] = svc.probe(ProbeRequest(
                dataset=DatasetSpec("higgs_like", {"n": n, "d": d}),
                escalate=True))

        threads = [threading.Thread(target=_escalated, args=(i,))
                   for i in range(n_probes)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dedup_s = time.perf_counter() - t0
        computes = runner_mod.SWEEP_COMPUTES - before
        paths = {r.escalation["artifact_path"] for r in responses}
        assert computes == 1, f"dedup leak: {computes} sweeps for 1 fp"
        assert len(paths) == 1, f"waiters got {len(paths)} artifacts"
        assert all(r.status == "ok" and r.tier == "measured"
                   for r in responses)
        t0 = time.perf_counter()
        svc.probe(ProbeRequest(
            dataset=DatasetSpec("higgs_like", {"n": n, "d": d}),
            escalate=True))
        cached_probe = time.perf_counter() - t0
        out["dedup"] = {
            "concurrent_requests": n_probes, "sweep_computes": computes,
            "wall_clock_s": dedup_s,
            "per_request_s": dedup_s / max(n_probes, 1),
            "cached_probe_s": cached_probe}

        # mixed workload: raw probes exit at the analytic tier, the two
        # spec-carrying forced escalations share one fresh fingerprint
        # (first computes, second is a cache hit inside the same batch)
        reqs = [ProbeRequest(X=X) for X in Xs]
        reqs += [ProbeRequest(
            dataset=DatasetSpec("realsim_like",
                                {"n": n, "d": d, "density": 0.05}),
            escalate=True) for _ in range(2)]
        before = runner_mod.SWEEP_COMPUTES
        t0 = time.perf_counter()
        mixed_resp = svc.probe_batch(reqs)
        mixed_s = time.perf_counter() - t0
        analytic = sum(r.tier == "analytic" for r in mixed_resp)
        out["mixed_workload"] = {
            "requests": len(reqs),
            "analytic_tier_answers": analytic,
            "analytic_fraction": analytic / len(reqs),
            "sweep_computes": runner_mod.SWEEP_COMPUTES - before,
            "wall_clock_s": mixed_s}
    return out


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=1500)
    p.add_argument("--d", type=int, default=28)
    p.add_argument("--iters", type=int, default=4000)
    p.add_argument("--eval-every", type=int, default=400)
    p.add_argument("--m-max", type=int, default=32,
                   help="main grid is every integer 1..m_max")
    p.add_argument("--quick", action="store_true",
                   help="small sizes for a fast smoke of the bench itself")
    p.add_argument("--seeds", type=int, default=8,
                   help="seed replicates for the seed_axis section")
    p.add_argument("--dist-worker", type=int, default=None,
                   help="internal: run the distributed-section worker "
                        "under this forced host device count and exit")
    p.add_argument("--out", default=None,
                   help="output path (default: BENCH_10.json at the repo "
                        "root; quick mode defaults elsewhere so a smoke "
                        "never overwrites the committed perf anchor)")
    args = p.parse_args(argv)
    if args.dist_worker is not None:
        return dist_worker(args)
    if args.quick:
        args.n, args.d, args.iters, args.eval_every = 300, 12, 400, 100
        args.m_max = 8
        args.seeds = min(args.seeds, 4)
    if args.out is None:
        args.out = (os.path.join(tempfile.gettempdir(), "BENCH_10.quick.json")
                    if args.quick else os.path.join(ROOT, "BENCH_10.json"))
    ms = list(range(1, args.m_max + 1))

    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=args.n, d=args.d)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    kw = dict(ms=ms, iters=args.iters, eval_every=args.eval_every)

    configs = {
        "pr1": dict(use_vmap=True, bucketed=False, hogwild_legacy=True),
        "sequential": dict(use_vmap=False, bucketed=False,
                           hogwild_legacy=True),
        "vmap_flat": dict(use_vmap=True, bucketed=False,
                          hogwild_legacy=False),
        # bucketed=None -> per-sweeper defaults (the shipped config)
        "engine_default": dict(use_vmap=True, bucketed=None,
                               hogwild_legacy=False),
    }
    timings, jit_counts = {}, {}
    for name, cfg in configs.items():
        timings[name], jit_counts[name] = time_configuration(
            tr, te, **kw, **cfg)
        print(f"{name:>15}: {timings[name]:7.2f} s "
              f"({jit_counts[name]} compiles)")

    new_algos = time_new_algorithms(tr, te, ms, args.iters, args.eval_every)
    for algo, r in new_algos.items():
        print(f"{algo:>15}: vmapped {r['vmapped_s']:6.2f} s "
              f"({r['jit_compiles_vmapped']} compiles)  sequential "
              f"{r['sequential_s']:6.2f} s  {r['speedup']:.2f}x")

    chars_ref, chars_fused = time_characters(
        ds.X[:min(400, args.n)], rng=args.m_max, batch_size=args.m_max)
    print(f"{'chars ref':>15}: {chars_ref:7.2f} s")
    print(f"{'chars fused':>15}: {chars_fused:7.2f} s")

    seed_axis = time_seed_axis(tr, te, ms, args.iters, args.eval_every,
                               args.seeds)
    for algo, r in seed_axis.items():
        print(f"{algo + ' seeds':>15}: vmapped {r['vmapped_s']:6.2f} s "
              f"({r['jit_compiles_vmapped']} compiles)  looped "
              f"{r['looped_s']:6.2f} s ({r['jit_compiles_looped']} "
              f"compiles)  {r['speedup']:.2f}x")

    if args.quick:
        bucket_cfg = dict(ms=[1, 2, 4, 8], iters=300, eval_every=100,
                          n=200, d=40)
    else:
        bucket_cfg = dict(ms=[1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64],
                          iters=5000, eval_every=500, n=800, d=400)
    regime = time_bucketing_regime(**bucket_cfg)
    print(f"{'ecd flat':>15}: {regime['flat']:7.2f} s")
    print(f"{'ecd bucketed':>15}: {regime['bucketed']:7.2f} s")

    fresh, cached = time_cache_roundtrip(ms, args.iters, args.eval_every,
                                         args.n, args.d)
    print(f"{'cache fresh':>15}: {fresh:7.2f} s")
    print(f"{'cache hit':>15}: {cached:7.2f} s")

    resil = time_resilience(ms, args.iters, args.eval_every,
                            args.n, args.d)
    print(f"{'journal off':>15}: {resil['journal_off_s']:7.2f} s")
    print(f"{'journal on':>15}: {resil['journal_on_s']:7.2f} s "
          f"({resil['overhead_frac'] * 100:+.2f}% overhead)")

    tel = time_telemetry(ms, args.iters, args.eval_every, args.n, args.d)
    print(f"{'trace off':>15}: {tel['trace_off_s']:7.2f} s")
    print(f"{'trace on':>15}: {tel['trace_on_s']:7.2f} s "
          f"({tel['enabled_overhead_frac'] * 100:+.2f}% enabled tax, "
          f"{tel['spans_per_traced_sweep']} spans, "
          f"{tel['span_record_us']:.1f} us/span recorded, "
          f"{tel['noop_span_us']:.2f} us/span disabled)")

    obs = time_observability(ms, args.iters, args.eval_every,
                             args.n, args.d)
    print(f"{'obs publish':>15}: {obs['publish_us']:7.2f} us/event")
    print(f"{'obs scrape':>15}: /metrics {obs['metrics_scrape_ms']:.2f} ms "
          f"/flight {obs['flight_scrape_ms']:.2f} ms")
    print(f"{'obs sweep':>15}: unobserved {obs['sweep_unobserved_s']:.2f} s "
          f"scraped {obs['sweep_scraped_s']:.2f} s "
          f"({obs['scrape_overhead_frac'] * 100:+.2f}% tax)")

    if args.quick:
        svc_cfg = dict(n_probes=6, n=192, d=12, sweep_iters=120,
                       sweep_eval_every=20)
    else:
        svc_cfg = dict(n_probes=8, n=384, d=16, sweep_iters=400,
                       sweep_eval_every=40)
    service = time_service(**svc_cfg)
    lat, dd, mx = (service["probe_latency"], service["dedup"],
                   service["mixed_workload"])
    print(f"{'svc batched':>15}: {lat['batched_s']:7.3f} s  looped "
          f"{lat['looped_s']:7.3f} s  {lat['speedup']:.2f}x")
    print(f"{'svc dedup':>15}: {dd['concurrent_requests']} concurrent "
          f"escalations -> {dd['sweep_computes']} sweep in "
          f"{dd['wall_clock_s']:.2f} s (cached refetch "
          f"{dd['cached_probe_s'] * 1000:.0f} ms)")
    print(f"{'svc mixed':>15}: {mx['analytic_tier_answers']}/"
          f"{mx['requests']} answered analytically "
          f"({mx['sweep_computes']} sweeps)")

    # mesh sizes: 1, the physical core count (the only mesh that can win
    # on CPU — intra-op parallelism can't cross scan iterations, device
    # sharding of the element axis can), and 8 (CI's forced-device size;
    # oversubscribed when cores < 8, measuring the invariance-tool regime)
    counts = ((1, 8) if args.quick
              else tuple(sorted({1, os.cpu_count() or 1, 8})))
    dist = time_distributed(args, device_counts=counts)
    d1 = dist["devices_1"]
    for key in sorted(dist):
        e = dist[key]
        print(f"{key:>15}: fine {e['engine_default_s']:6.2f} s  wide "
              f"{e['wide_compute']['wall_clock_s']:6.2f} s "
              f"({e['jit_compiles']} compiles)  hogwild race "
              f"{e['hogwild_race']['race_s']:6.2f} s "
              f"({e['hogwild_race']['throughput_vs_oracle']:.2f}x oracle)")
    dist_summary = {
        key: {"speedup_fine_vs_1dev": d1["engine_default_s"]
              / max(dist[key]["engine_default_s"], 1e-9),
              "speedup_wide_vs_1dev":
                  d1["wide_compute"]["wall_clock_s"]
                  / max(dist[key]["wide_compute"]["wall_clock_s"], 1e-9),
              "jit_compiles": dist[key]["jit_compiles"]}
        for key in dist}

    speedup = (timings["pr1"] + chars_ref) / (timings["engine_default"]
                                              + chars_fused)
    # embed the PR-4 anchor for the within-noise comparison, if present
    # (the single-seed single-device path is bit-identical)
    vs_bench4 = None
    b4_path = os.path.join(ROOT, "BENCH_4.json")
    if not args.quick and os.path.exists(b4_path):
        with open(b4_path) as f:
            b4 = json.load(f)["main"]["wall_clock_s"]
        vs_bench4 = {
            "bench4_wall_clock_s": b4,
            "ratio_engine_default": timings["engine_default"]
            / max(b4["engine_default"], 1e-9),
        }
    # PR-6 non-regression: registration-only PR, the original 4-algorithm
    # engine_default sweep must stay within noise of the PR-5 anchor
    vs_bench5 = None
    b5_path = os.path.join(ROOT, "BENCH_5.json")
    if not args.quick and os.path.exists(b5_path):
        with open(b5_path) as f:
            b5 = json.load(f)["main"]["wall_clock_s"]
        vs_bench5 = {
            "bench5_wall_clock_s": b5,
            "ratio_engine_default": timings["engine_default"]
            / max(b5["engine_default"], 1e-9),
        }
    # PR-7 non-regression: the fault path is dormant unless a job opts
    # in, so the original sweep must stay within noise of the PR-6 anchor
    vs_bench6 = None
    b6_path = os.path.join(ROOT, "BENCH_6.json")
    if not args.quick and os.path.exists(b6_path):
        with open(b6_path) as f:
            b6 = json.load(f)["main"]["wall_clock_s"]
        vs_bench6 = {
            "bench6_wall_clock_s": b6,
            "ratio_engine_default": timings["engine_default"]
            / max(b6["engine_default"], 1e-9),
        }
    # PR-8 non-regression: the service is a new layer over the engine
    # (run_sweep's dedup/cache-cap paths are no-ops by default), so the
    # original sweep must stay within noise of the PR-7 anchor
    vs_bench7 = None
    b7_path = os.path.join(ROOT, "BENCH_7.json")
    if not args.quick and os.path.exists(b7_path):
        with open(b7_path) as f:
            b7 = json.load(f)["main"]["wall_clock_s"]
        vs_bench7 = {
            "bench7_wall_clock_s": b7,
            "ratio_engine_default": timings["engine_default"]
            / max(b7["engine_default"], 1e-9),
        }
    # PR-9 non-regression: telemetry disabled must be free — the no-op
    # span path and registry counters may not move the original sweep
    # out of noise vs the PR-8 anchor (acceptance: < 1% regression)
    vs_bench8 = None
    b8_path = os.path.join(ROOT, "BENCH_8.json")
    if not args.quick and os.path.exists(b8_path):
        with open(b8_path) as f:
            b8 = json.load(f)["main"]["wall_clock_s"]
        vs_bench8 = {
            "bench8_wall_clock_s": b8,
            "ratio_engine_default": timings["engine_default"]
            / max(b8["engine_default"], 1e-9),
        }
    # PR-10 non-regression: the observability plane is read-side only —
    # the sweep gained a handful of flight-recorder publishes (measured
    # in isolation as publish_us), so the original sweep must stay
    # within noise of the PR-9 anchor; bench_check.py additionally
    # gates the whole BENCH_2..10 trajectory
    vs_bench9 = None
    b9_path = os.path.join(ROOT, "BENCH_9.json")
    if not args.quick and os.path.exists(b9_path):
        with open(b9_path) as f:
            b9 = json.load(f)["main"]["wall_clock_s"]
        vs_bench9 = {
            "bench9_wall_clock_s": b9,
            "ratio_engine_default": timings["engine_default"]
            / max(b9["engine_default"], 1e-9),
        }

    payload = {
        "bench": "engine_sweep",
        "engine_version": ENGINE_VERSION,
        "backend": jax.default_backend(),
        "quick": args.quick,
        "speedup_vs_pr1": speedup,
        "main": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "eval_every": args.eval_every,
                       "ms": f"1..{args.m_max}"},
            "wall_clock_s": timings,
            "jit_compiles": jit_counts,
            "hogwild_compiles": {"pr1": len(ms), "vmap": 1},
        },
        "new_algorithms": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "eval_every": args.eval_every,
                       "ms": f"1..{args.m_max}",
                       "note": "PR-6 registrations on the unchanged "
                               "ENGINE_VERSION-5 engine, shipped defaults "
                               "(momentum/local_sgd bucketed, async_svrg "
                               "force_flat), cold per algorithm"},
            "results": new_algos,
        },
        "characters": {
            "config": {"rows": min(400, args.n), "rng": args.m_max,
                       "batch_size": args.m_max},
            "ref_s": chars_ref, "fused_s": chars_fused,
            "speedup": chars_ref / max(chars_fused, 1e-9),
        },
        "bucketing_regime": {
            "config": bucket_cfg,
            "wall_clock_s": regime,
            "speedup": regime["flat"] / max(regime["bucketed"], 1e-9),
            "buckets": [{"ms": [bucket_cfg["ms"][i] for i in pos],
                         "m_pad": m_pad}
                        for pos, m_pad in engine._buckets(bucket_cfg["ms"])],
        },
        "seed_axis": {
            "config": {"ms": f"1..{args.m_max}", "n_seeds": args.seeds,
                       "iters": args.iters, "bucketed": False},
            "results": seed_axis,
        },
        "distributed": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "ms": f"1..{args.m_max}",
                       "host_cores": os.cpu_count(),
                       "note": "forced host CPU devices, cold subprocess "
                               "per mesh size, min over repeats. "
                               "engine_default = fine d=28 grid, "
                               "wide_compute = d=400 grid (per-step "
                               "FLOPs dominate). Sharding divides the "
                               "element axis that intra-op threads "
                               "cannot (whole per-element scans run "
                               "concurrently), so speedup needs devices "
                               "<= physical cores AND compute-dominated "
                               "elements; this container has 2 shared "
                               "cores, where a 1-device run already "
                               "saturates memory bandwidth + both cores "
                               "via intra-op threads, so measured "
                               "sharding speedups are ~parity and noisy "
                               "(the mesh's value here is the "
                               "invariance contract + CI correctness; "
                               "real multi-chip meshes hit the same "
                               "code path).  Compile counts must stay "
                               "equal across mesh sizes: 1 jit per "
                               "bucket per mesh, sharded or not."},
            "per_mesh": dist,
            "summary": dist_summary,
        },
        "cache_roundtrip_s": {"fresh": fresh, "cached": cached,
                              "speedup": fresh / max(cached, 1e-9)},
        "resilience": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "ms": f"1..{args.m_max}",
                       "note": "run_sweep journal on vs off, warm jit "
                               "caches, fresh cache dir per run, "
                               "off/on interleaved and min-reduced "
                               "over 5 repeats; on-path cost = one "
                               "fsync'd append per job (measured "
                               "directly: append_fsync_ms) + one "
                               "journal probe and unlink per sweep "
                               "(target overhead < 2%)"},
            "results": resil,
        },
        "service": {
            "note": "advisor service (docs/service.md): batched front "
                    "end vs per-probe from_dataset loop (warm), N "
                    "concurrent same-fingerprint forced escalations "
                    "(single-flight: sweep_computes must be 1, every "
                    "waiter served the one stored artifact), and the "
                    "analytic-tier early-exit fraction on a mixed "
                    "raw+escalated workload",
            **service,
        },
        "telemetry": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "ms": f"1..{args.m_max}",
                       "note": "run_sweep traced on vs off, warm jit "
                               "caches, fresh cache dir per run, off/on "
                               "interleaved and min-reduced over 5 "
                               "repeats; the off label is the disabled "
                               "contract (no-op spans + counters, "
                               "gated < 1% vs_bench8), the on label is "
                               "the enabled tax (per-bucket AOT "
                               "re-lower for the compile/execute split "
                               "+ span recording, isolated as "
                               "span_record_us / noop_span_us)"},
            "results": tel,
        },
        "observability": {
            "config": {"dataset": "higgs_like", "n": args.n, "d": args.d,
                       "iters": args.iters, "ms": f"1..{args.m_max}",
                       "note": "PR-10 live observability plane: flight-"
                               "recorder publish cost isolated "
                               "(publish_us — the only new always-on "
                               "sweep-path work, a handful per sweep), "
                               "GET /metrics and /flight scrape latency "
                               "against a live ServiceServer (min over "
                               "50 warm requests), and the full "
                               "engine_default sweep unobserved vs with "
                               "a 50 ms scraper thread polling both "
                               "endpoints (warm jit caches, fresh cache "
                               "dir per run, interleaved, min over 3 "
                               "repeats) — the observational claim at "
                               "sweep granularity"},
            "results": obs,
        },
        "vs_bench4": vs_bench4,
        "vs_bench5": vs_bench5,
        "vs_bench6": vs_bench6,
        "vs_bench7": vs_bench7,
        "vs_bench8": vs_bench8,
        "vs_bench9": vs_bench9,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"speedup vs PR-1 engine: {speedup:.2f}x -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
