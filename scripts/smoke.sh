#!/usr/bin/env bash
# Smoke check: tier-1 tests + one engine sweep + the README quickstart
# commands as written.  ~10-15 min cold on CPU (sweeps are cached, so
# re-runs are much faster).  SMOKE_FULL=1 additionally runs the whole
# benchmark harness instead of a single representative entry.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# report the device mesh this smoke runs on (CI's smoke-mesh8 job forces
# 8 host devices via XLA_FLAGS; sweeps then take the sharded engine path)
python -c "from repro.distributed import get_mesh; print(get_mesh().describe())"

echo "== [1/5] test suite (quick loop: -m 'not slow') =="
# The full tier-1 suite (ROADMAP.md) is `python -m pytest -x -q` with no
# marker filter; the smoke loop skips @pytest.mark.slow sweep/subprocess
# tests to stay under ~2 minutes on this CPU container.  SMOKE_FULL=1
# runs everything.
if [ "${SMOKE_FULL:-0}" = "1" ]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

echo "== [2/5] sweep engine: registered specs =="
python -m repro.experiments.run --list

echo "== [3/5] sweep engine: Table II (upper_bound) quick =="
python -m repro.experiments.run --spec upper_bound --quick

echo "== [4/5] benchmark harness =="
if [ "${SMOKE_FULL:-0}" = "1" ]; then
    python -m benchmarks.run
else
    python -m benchmarks.run --only paper_diversity
fi

echo "== [5/5] end-to-end paper study (quick) =="
python examples/paper_scalability_study.py

echo "smoke OK"
