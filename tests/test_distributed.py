"""repro.distributed: mesh resolution, the grid partitioner, the
execution-only fingerprint contract, and — in 8-virtual-device
subprocesses — the mesh-invariance + cross-mesh cache contract and the
racing Hogwild! parity against the sequential staleness oracle."""

import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synth
from repro.distributed import (element_plan, get_mesh, pad_to_multiple,
                               resolve, run_grid_sharded)
from repro.distributed import mesh as mesh_mod
from repro.experiments import engine
from repro.experiments.spec import (DatasetSpec, JobSpec, SweepSpec,
                                    EXECUTION_ONLY_FIELDS, fingerprint)


# ---------------------------------------------------------------------------
# mesh resolution
# ---------------------------------------------------------------------------

def test_get_mesh_auto_and_overrides():
    auto = get_mesh()
    assert auto.n_devices == len(jax.devices())
    assert get_mesh("auto").n_devices == auto.n_devices
    one = get_mesh(1)
    assert one.n_devices == 1
    assert "fallback" in one.describe()
    assert resolve(None) is None                 # None = "not requested"
    assert resolve(one) is one                   # passthrough
    with pytest.raises(ValueError):
        get_mesh(0)
    # over-subscription clamps with a one-shot warning, never raises
    # (graceful degradation — results are mesh-invariant anyway)
    mesh_mod._CLAMP_WARNED = False
    with pytest.warns(RuntimeWarning, match="clamping"):
        clamped = get_mesh(len(jax.devices()) + 1)
    assert clamped.n_devices == len(jax.devices())
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second ask: silent (one-shot)
        assert get_mesh(len(jax.devices()) + 5).n_devices == len(
            jax.devices())
    mesh_mod._CLAMP_WARNED = False


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------

def test_pad_to_multiple():
    assert pad_to_multiple(5, 4) == 8
    assert pad_to_multiple(8, 4) == 8
    assert pad_to_multiple(1, 8) == 8


def test_element_plan_layout():
    # bucket positions (1, 3) of ms, 2 seeds, 4 devices: 4 real elements
    m_idx, s_idx, n_real = element_plan((1, 3), [1, 2, 4, 8], 2, 4)
    assert n_real == 4 and len(m_idx) == 4
    assert list(m_idx) == [2, 2, 8, 8] and list(s_idx) == [0, 1, 0, 1]
    # 3 members x 1 seed on 4 devices pads by repeating element 0
    m_idx, s_idx, n_real = element_plan((0, 1, 2), [1, 2, 4], 1, 4)
    assert n_real == 3 and len(m_idx) == 4
    assert list(m_idx) == [1, 2, 4, 1] and list(s_idx) == [0, 0, 0, 0]


def test_run_grid_sharded_matches_direct_eval():
    """The partitioner's pad/reshape/scatter bookkeeping, on a 1-device
    mesh with an analytic sim_elem (3 'evals' encoding m, s, m_pad)."""
    ms = [1, 2, 3, 4, 6, 8]
    dmesh = get_mesh(1)

    def make_sim_elem(m_pad):
        def sim_elem(m, s):
            return jnp.stack([m.astype(jnp.float32), s.astype(jnp.float32),
                              jnp.float32(m_pad)])
        return sim_elem

    for n_seeds in (1, 3):
        for buckets in (engine._buckets(ms),
                        [(tuple(range(len(ms))), max(ms))]):
            out = np.asarray(run_grid_sharded(
                make_sim_elem, ms, n_seeds, dmesh, buckets))
            pad_of = {i: m_pad for pos, m_pad in buckets for i in pos}
            if n_seeds == 1:
                assert out.shape == (len(ms), 3)
                for i, m in enumerate(ms):
                    assert list(out[i]) == [m, 0, pad_of[i]]
            else:
                assert out.shape == (len(ms), n_seeds, 3)
                for i, m in enumerate(ms):
                    for s in range(n_seeds):
                        assert list(out[i, s]) == [m, s, pad_of[i]]


# ---------------------------------------------------------------------------
# execution never enters result identity
# ---------------------------------------------------------------------------

def _tiny_spec(**over):
    base = dict(
        name="dist_tiny", description="distributed unit spec",
        ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 120, "d": 8})},
        jobs=(JobSpec("minibatch", "d0"),))
    base.update(over)
    return SweepSpec(**base).validate()


def test_fingerprint_excludes_devices():
    assert "devices" in EXECUTION_ONLY_FIELDS
    fps = {fingerprint(_tiny_spec(devices=d))
           for d in (None, 1, 8, "auto")}
    assert len(fps) == 1
    # ...but a computational field still splits the key
    assert fingerprint(_tiny_spec(iters=80)) not in fps


def test_spec_devices_validation_and_roundtrip():
    with pytest.raises(ValueError, match="devices"):
        _tiny_spec(devices=0)
    with pytest.raises(ValueError, match="devices"):
        _tiny_spec(devices="all")
    spec = _tiny_spec(devices="auto")
    assert SweepSpec.from_dict(spec.to_dict()) == spec
    # pre-ENGINE_VERSION-5 artifact spec dicts (no devices key) still load
    d = spec.to_dict()
    del d["devices"]
    assert SweepSpec.from_dict(d).devices is None


def test_cache_hit_served_without_resolving_devices(tmp_path):
    """An artifact cached anywhere must serve on a host that cannot
    satisfy the spec's `devices` ask — the mesh resolves only on a miss,
    and the persisted spec dict drops execution-only fields."""
    import json

    from repro.experiments import runner

    spec = _tiny_spec()
    r = runner.run_sweep(spec, cache_dir=str(tmp_path))
    assert r["cache"]["hit"] is False
    assert "devices" not in r["spec"]                # execution-only
    persisted = json.load(open(r["cache"]["path"]))
    assert "devices" not in persisted["spec"]
    # same fingerprint, but an unsatisfiable mesh request: must NOT raise
    big = dataclasses.replace(spec, devices=len(jax.devices()) + 7)
    r2 = runner.run_sweep(big, cache_dir=str(tmp_path))
    assert r2["cache"]["hit"] is True
    # ...and a fresh compute with that request degrades gracefully: the
    # mesh clamps to the host (one-shot warning) instead of raising
    mesh_mod._CLAMP_WARNED = False
    with pytest.warns(RuntimeWarning, match="clamping"):
        r3 = runner.run_sweep(big, cache_dir=str(tmp_path), force=True)
    mesh_mod._CLAMP_WARNED = False
    assert r3["cache"]["hit"] is False
    assert r3["execution"]["devices"] == len(jax.devices())


def test_sweep_hogwild_sharded_any_grid():
    """The racing-mode sweep aligns each m's eval cadence to its round
    boundaries, so grids with m not dividing eval_every just work and
    every row has the same number of evals."""
    from repro.distributed import sweep_hogwild_sharded

    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=150, d=8)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    r = sweep_hogwild_sharded(tr, te, [1, 2, 3], iters=120, eval_every=40)
    assert np.asarray(r["losses"]).shape == (3, 3)
    assert np.isfinite(r["losses"]).all()


def test_engine_single_device_mesh_is_bitexact():
    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=200, d=10)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    kw = dict(iters=60, eval_every=20)
    for algo in ("minibatch", "hogwild"):
        r0 = engine.run_algorithm_sweep(algo, tr, te, [1, 2, 4], **kw)
        r1 = engine.run_algorithm_sweep(algo, tr, te, [1, 2, 4], mesh=1,
                                        **kw)
        assert np.array_equal(np.asarray(r0["losses"]),
                              np.asarray(r1["losses"]))


# ---------------------------------------------------------------------------
# the contract, for real: 8 virtual host devices in a subprocess
# ---------------------------------------------------------------------------

def _run_sub(body, timeout):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, numpy as np
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], cwd=".",
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


MESH_INVARIANCE = """
    from repro.data import synth
    from repro.experiments import engine

    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=400, d=16)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    ms = [1, 2, 4, 8]
    # deterministic-arithmetic algorithms: sweep scale, with seed axis
    for algo, n_seeds, iters in (("minibatch", 3, 400), ("hogwild", 3, 400),
                                 ("dadm", 1, 400), ("ecd_psgd", 2, 60)):
        kw = dict(iters=iters, eval_every=iters // 4, n_seeds=n_seeds)
        r1 = engine.run_algorithm_sweep(algo, tr, te, ms, **kw)
        j0 = engine.JIT_CALLS
        r8 = engine.run_algorithm_sweep(algo, tr, te, ms, mesh=8, **kw)
        compiles = engine.JIT_CALLS - j0
        a = np.asarray(r1.get("losses_seeds", r1["losses"]))
        b = np.asarray(r8.get("losses_seeds", r8["losses"]))
        d = float(np.abs(a - b).max())
        assert d <= 1e-5, (algo, d)
        # one compile per bucket per mesh, seed axis included
        n_buckets = len(engine._buckets(ms)) if (
            engine.alg_base.get_algorithm(algo).bucketed_default
            and not engine.alg_base.get_algorithm(algo).force_flat) else 1
        assert compiles == n_buckets, (algo, compiles, n_buckets)
        print(algo, "invariant", d, "compiles", compiles)
    print("MESH_INVARIANCE_OK")
"""


CACHE_CROSS_MESH = """
    import tempfile, json, glob
    from repro.experiments import registry, runner

    spec = registry.get_spec("variance_sparsity", quick=True, iters=60,
                             n=200)
    with tempfile.TemporaryDirectory() as cd:
        r1 = runner.run_sweep(spec, cache_dir=cd, mesh=1)
        assert r1["cache"]["hit"] is False
        assert r1["execution"] == {"devices": 1, "sharded": False,
                                   "backend": "cpu"}
        art1 = open(r1["cache"]["path"]).read()
        r8 = runner.run_sweep(spec, cache_dir=cd, mesh=8)
        assert r8["cache"]["hit"] is True          # 1-device sweep serves 8
        assert r8["execution"]["devices"] == 8
    with tempfile.TemporaryDirectory() as cd:
        r8 = runner.run_sweep(spec, cache_dir=cd, mesh=8)
        assert r8["cache"]["hit"] is False and r8["execution"]["sharded"]
        art8 = open(r8["cache"]["path"]).read()
        r1 = runner.run_sweep(spec, cache_dir=cd, mesh=1)
        assert r1["cache"]["hit"] is True          # ...and vice versa
    p1, p8 = json.loads(art1), json.loads(art8)
    assert p1["fingerprint"] == p8["fingerprint"]
    # volatile keys never persist: artifacts carry no mesh trace at all
    assert "cache" not in p1 and "execution" not in p1
    assert "cache" not in p8 and "execution" not in p8
    assert "devices" not in p1["spec"] and "devices" not in p8["spec"]
    print("CACHE_CROSS_MESH_OK")
"""


HOGWILD_RACE = """
    from repro.data import synth
    from repro.core.algorithms import run_hogwild
    from repro.distributed import run_hogwild_sharded

    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=400, d=16)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    kw = dict(m=8, iters=1600, gamma=0.05, eval_every=200)
    oracle = np.asarray(run_hogwild(tr, te, **kw)["losses"])

    # m == devices, sync_every=1: the race IS the staleness recurrence —
    # every round's gradients read the last round boundary, exactly the
    # oracle's tau=(j%m)+1 structure, so curves match to summation order
    race = run_hogwild_sharded(tr, te, mesh=8, **kw)
    assert race["devices"] == 8
    d = float(np.abs(np.asarray(race["losses"]) - oracle).max())
    assert d <= 1e-5, d
    print("parity", d)

    # widening the sync window makes the shards genuinely race ahead on
    # stale parameters: trajectories must now DIVERGE from the oracle
    # (that is the point of the mode) while still optimizing
    stale = run_hogwild_sharded(tr, te, mesh=8, sync_every=4, **kw)
    sd = float(np.abs(np.asarray(stale["losses"]) - oracle).max())
    assert sd > 1e-4, sd
    assert np.isfinite(stale["losses"]).all()
    assert stale["losses"][-1] < stale["losses"][0]
    print("stale divergence", sd)

    # any m on any mesh: padded worker slots are inert
    odd = run_hogwild_sharded(tr, te, m=6, iters=600, gamma=0.05,
                              eval_every=60, mesh=8)
    assert np.isfinite(odd["losses"]).all()
    print("HOGWILD_RACE_OK")
"""


@pytest.mark.slow
def test_mesh_invariance_subprocess():
    """1 vs 8 host devices: identical curves (<=1e-5), 1 compile/bucket."""
    out = _run_sub(MESH_INVARIANCE, timeout=420)
    assert "MESH_INVARIANCE_OK" in out


@pytest.mark.slow
def test_cache_cross_mesh_subprocess():
    """A sweep cached on 1 device is a hit on 8 (and vice versa); the
    persisted artifacts share the fingerprint and carry no mesh trace."""
    out = _run_sub(CACHE_CROSS_MESH, timeout=420)
    assert "CACHE_CROSS_MESH_OK" in out


@pytest.mark.slow
def test_hogwild_race_subprocess():
    """Racing Hogwild!: parity with the oracle at m==D/sync_every=1,
    genuine divergence at a wider sync window."""
    out = _run_sub(HOGWILD_RACE, timeout=420)
    assert "HOGWILD_RACE_OK" in out


TRACED_RACE = """
    from repro.data import synth
    from repro.distributed import hogwild_shards, run_hogwild_sharded
    from repro.telemetry import metrics, trace
    from repro.telemetry.recorder import RECORDER

    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=400, d=16)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    kw = dict(m=8, iters=800, gamma=0.05, eval_every=200, sync_every=2,
              mesh=8)

    base = run_hogwild_sharded(tr, te, **kw)

    c0 = metrics.REGISTRY.counter(
        "repro_distributed_psum_rounds_total").value
    RECORDER.clear()
    trace.start()
    traced = run_hogwild_sharded(tr, te, **kw)
    tracer = trace.stop()

    # the observational contract under shard_map + donated buffers:
    # the traced lower/compile/execute split runs the same executable,
    # so the curves are exactly equal
    np.testing.assert_array_equal(np.asarray(traced["losses"]),
                                  np.asarray(base["losses"]))

    # the psum counter keeps its host-side accounting while traced
    delta = metrics.REGISTRY.counter(
        "repro_distributed_psum_rounds_total").value - c0
    assert delta == traced["psum_rounds"], (delta, traced["psum_rounds"])

    # the race span carries its AOT children inside its interval
    evs = tracer.events
    races = [e for e in evs if e["name"] == "race"]
    assert len(races) == 1
    r = races[0]
    assert r["args"]["m"] == 8 and r["args"]["devices"] == 8
    assert r["args"]["sync_every"] == 2
    inside = [e["name"] for e in evs
              if e is not r and e["ts"] >= r["ts"] - 1e-6
              and e["ts"] + e["dur"] <= r["ts"] + r["dur"] + 1e-6]
    for child in ("lower", "compile", "execute"):
        assert child in inside, (child, inside)

    # and the recorder mirrored both the span and the race event
    snap = RECORDER.snapshot()
    assert any(s["name"] == "race" for s in snap["spans"])
    race_events = [e for e in snap["events"] if e["kind"] == "race"]
    assert race_events and \\
        race_events[-1]["psum_rounds"] == traced["psum_rounds"]
    print("TRACED_RACE_OK")
"""


@pytest.mark.slow
def test_traced_race_subprocess():
    """Tracing the racing path on 8 virtual devices: exactly-equal
    curves, the race span's lower/compile/execute children, and live
    psum accounting — telemetry survives shard_map + donation."""
    out = _run_sub(TRACED_RACE, timeout=420)
    assert "TRACED_RACE_OK" in out
