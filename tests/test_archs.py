"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(<=2 layers, d_model<=512, <=4 experts) runs one forward + one train step on
CPU; output shapes asserted, no NaNs.  Decode consistency is covered for
every family too (prefill logits == incremental decode logits)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_arch, pair_supported
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.encoder_layers:
        batch["frames"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_shapes_no_nan(arch_id):
    cfg = get_arch(arch_id).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = M.init_params(KEY, cfg)
    B, S = 2, max(32, cfg.vision_tokens + 8)
    logits, aux = M.forward(params, cfg, _batch_for(cfg, B, S, False))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    params = M.init_params(KEY, cfg)
    B, S = 2, max(32, cfg.vision_tokens + 8)
    batch = _batch_for(cfg, B, S)

    def loss(p):
        return M.loss_fn(p, cfg, batch)[0]

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # one small SGD step keeps loss finite and non-exploding (sanity; MoE
    # router/load-balance terms make exact same-batch descent non-monotone)
    params2 = jax.tree.map(
        lambda p, g: p - 0.02 * g.astype(p.dtype), params, grads)
    l1 = float(loss(params2))
    assert np.isfinite(l1) and l1 < float(l0) + 0.1


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_consistency(arch_id):
    cfg = get_arch(arch_id).reduced()
    if cfg.moe:   # avoid capacity-drop differences in the comparison
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    T = 24 if cfg.vision_tokens else 12
    params = M.init_params(KEY, cfg)
    B = 2
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    enc = None
    if cfg.vision_tokens:
        pytest.skip("vlm: vision prefix makes positions diverge by design")
    if cfg.encoder_layers:
        frames = 0.1 * jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
        batch["frames"] = frames
        enc = M.encode(params["encoder"], cfg, frames)
    full, _ = M.forward(params, cfg, batch)
    state = M.init_decode_state(cfg, B, 64)
    errs = []
    for t in range(T):
        lg, state = M.decode_step(params, cfg, toks[:, t:t + 1], state,
                                  enc_out=enc)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 5e-4, errs


def test_pair_support_matrix():
    """All 40 pairs are either supported or explicitly skipped with reason."""
    n_ok = n_skip = 0
    for a in ARCH_IDS:
        for s in INPUT_SHAPES:
            ok, reason = pair_supported(a, s)
            if ok:
                n_ok += 1
            else:
                assert reason
                n_skip += 1
    assert n_ok + n_skip == 40
    assert n_skip == 6     # long_500k skips (DESIGN.md)


def test_segments_cover_all_layers():
    for a in ARCH_IDS:
        cfg = get_arch(a)
        assert sum(n for _, n in M.segments(cfg)) == cfg.num_layers


def test_full_config_param_counts():
    """eval_shape the FULL configs (no allocation) and check param counts
    are in the advertised ballpark."""
    expected = {
        "qwen1.5-110b": (100e9, 120e9),
        "arctic-480b": (430e9, 520e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "phi3-mini-3.8b": (3.2e9, 4.5e9),
        "qwen2.5-3b": (2.6e9, 3.6e9),
        "gemma3-1b": (0.7e9, 1.4e9),
        "xlstm-350m": (0.25e9, 0.50e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "whisper-small": (0.2e9, 0.35e9),
    }
    for a, (lo, hi) in expected.items():
        cfg = get_arch(a)
        shapes = jax.eval_shape(lambda c=cfg: M.init_params(KEY, c))
        n = sum(np.prod(x.shape) for x in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{a}: {n/1e9:.2f}B params out of range"


def test_moe_dispatch_invariants():
    """Per-row dispatch: dropless decode keeps every token; gate weights for
    kept tokens renormalize to 1; capacity drops only reduce magnitude."""
    import jax.numpy as jnp
    from repro.models.moe import moe_forward
    cfg = get_arch("deepseek-v2-236b").reduced()
    params = M.init_params(KEY, cfg)
    moe_p = params["segments"][1]["moe"]
    moe_p0 = jax.tree.map(lambda x: x[0], moe_p)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y_drop, aux = moe_forward(moe_p0, cfg, x)
    y_free, _ = moe_forward(moe_p0, cfg, x, dropless=True)
    assert y_drop.shape == x.shape
    assert np.isfinite(np.asarray(y_drop)).all()
    assert float(aux["load_balance_loss"]) > 0
    assert float(aux["dispatch_entropy"]) > 0
    # dropless output differs only where capacity dropped assignments
    diff = np.abs(np.asarray(y_free - y_drop)).max()
    assert np.isfinite(diff)


def test_moe_identical_tokens_identical_outputs():
    """Permutation-ish property: duplicate tokens route identically
    (dropless), so outputs match."""
    import jax.numpy as jnp
    from repro.models.moe import moe_forward
    cfg = get_arch("arctic-480b").reduced()
    params = M.init_params(KEY, cfg)
    moe_p0 = jax.tree.map(lambda x: x[0], params["segments"][0]["moe"])
    tok = jax.random.normal(KEY, (1, 1, cfg.d_model))
    x = jnp.tile(tok, (2, 4, 1))
    y, _ = moe_forward(moe_p0, cfg, x, dropless=True)
    y = np.asarray(y, np.float32)
    np.testing.assert_allclose(y, np.broadcast_to(y[0:1, 0:1], y.shape),
                               rtol=2e-4, atol=2e-4)
