"""Train-step factories, optimizers, checkpointing, gossip compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core.compression import (dequantize, quantize_error,
                                    quantize_stochastic)
from repro.models import model as M
from repro.optim import (adamw_init, adamw_update, momentum_init,
                         momentum_update, sgd_update, sgd_init)
from repro.train.checkpoint import restore_checkpoint, save_checkpoint

KEY = jax.random.PRNGKey(0)


def _quadratic_problem():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("init,update,kw", [
    (sgd_init, sgd_update, {"lr": 0.1}),
    (momentum_init, momentum_update, {"lr": 0.05}),
    (adamw_init, adamw_update, {"lr": 0.3, "weight_decay": 0.0}),
])
def test_optimizers_converge_quadratic(init, update, kw):
    params, loss, target = _quadratic_problem()
    state = init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = update(params, g, state, **kw)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_state_dtype_and_count():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = adamw_init(params)
    assert st["m"]["w"].dtype == jnp.float32
    p2, st2 = adamw_update(params, params, st, lr=1e-3)
    assert int(st2["count"]) == 1
    assert p2["w"].dtype == jnp.bfloat16


@pytest.mark.slow
def test_train_loop_reduces_loss():
    """End-to-end: reduced arch + HMM stream, loss must drop measurably."""
    from repro.launch.train import train_loop
    cfg = get_arch("qwen2.5-3b").reduced()
    _, hist = train_loop(cfg, steps=50, batch_size=4, seq_len=32, lr=2e-3,
                         log_every=1000)
    assert hist[-1] < hist[0] - 0.4, (hist[0], hist[-1])


@pytest.mark.slow
def test_stale_strategy_trains():
    from repro.launch.train import train_loop
    cfg = get_arch("gemma3-1b").reduced()
    _, hist = train_loop(cfg, steps=50, batch_size=4, seq_len=32, lr=2e-3,
                         strategy="stale", log_every=1000)
    assert hist[-1] < hist[0] - 0.25


def test_checkpoint_roundtrip():
    cfg = get_arch("xlstm-350m").reduced()
    params = M.init_params(KEY, cfg)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, {"params": params}, step=7)
        restored, step = restore_checkpoint(d, {"params": params})
        assert step == 7
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_quantize_compression_error_shrinks_with_bits():
    x = jax.random.normal(KEY, (256,))
    errs = []
    for bits in (4, 8, 16):
        e = quantize_error(x, KEY, bits=bits)
        errs.append(float(jnp.sqrt(jnp.mean(e ** 2))))
    assert errs[0] > errs[1] > errs[2]


@pytest.mark.slow
def test_microbatch_split_matches_full_grad():
    """Gradient accumulated over microbatches == full-batch gradient."""
    from repro.train.steps import _split_microbatches
    cfg = get_arch("qwen2.5-3b").reduced()
    params = M.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)}

    def loss(p, b):
        return M.loss_fn(p, cfg, b)[0]

    g_full = jax.grad(loss)(params, batch)
    mb = _split_microbatches(batch, 2)
    g1 = jax.grad(loss)(params, jax.tree.map(lambda x: x[0], mb))
    g2 = jax.grad(loss)(params, jax.tree.map(lambda x: x[1], mb))
    g_acc = jax.tree.map(lambda a, b: (a + b) / 2, g1, g2)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)
