"""Serving engine: generate loop, KV-cache semantics, sliding windows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models import model as M
from repro.models.attention import KVCache, init_kv_cache, gqa_decode, init_gqa
from repro.serve.engine import greedy_generate, init_serve_state, make_serve_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_greedy_generate_deterministic():
    cfg = get_arch("qwen2.5-3b").reduced()
    params = M.init_params(KEY, cfg)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = greedy_generate(params, cfg, prompts, steps=6)
    b = greedy_generate(params, cfg, prompts, steps=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
    assert int(a.max()) < cfg.vocab_size


def test_serve_step_interface():
    cfg = get_arch("gemma3-1b").reduced()
    params = M.init_params(KEY, cfg)
    serve = make_serve_step(cfg)
    state = init_serve_state(cfg, batch=2, max_len=64, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(4):
        tok_next, state = serve(params, state, tok)
        tok = tok_next[:, None]
    assert int(state["decode"]["position"]) == 4


def test_sliding_window_cache_is_ring_buffer():
    """After window+k tokens, the cache holds only the last `window` keys."""
    cfg = get_arch("gemma3-1b").reduced()
    window = 8
    p = init_gqa(KEY, cfg, jnp.float32)
    cache = init_kv_cache(cfg, batch=1, max_len=64, dtype=jnp.float32,
                          window=window)
    assert cache.k.shape[1] == window
    x = jax.random.normal(KEY, (1, 1, cfg.d_model))
    for t in range(window + 3):
        _, cache = gqa_decode(p, cfg, x, cache, jnp.int32(t))
    # oldest retained position is t - window + 1
    pos = np.asarray(cache.pos[0])
    assert pos.min() == (window + 3) - window
    assert int(cache.index) == window + 3


def test_decode_state_constant_size_for_ssm():
    """SSM decode state must not grow with max_len (the long_500k enabler)."""
    cfg = get_arch("xlstm-350m").reduced()
    s1 = M.init_decode_state(cfg, 2, 64)
    s2 = M.init_decode_state(cfg, 2, 4096)
    n1 = sum(x.size for x in jax.tree.leaves(s1["caches"]))
    n2 = sum(x.size for x in jax.tree.leaves(s2["caches"]))
    assert n1 == n2


@pytest.mark.slow
def test_whisper_serve_uses_encoder():
    cfg = get_arch("whisper-small").reduced()
    params = M.init_params(KEY, cfg)
    frames = 0.1 * jax.random.normal(KEY, (2, cfg.encoder_seq, cfg.d_model))
    enc = M.encode(params["encoder"], cfg, frames)
    out1 = greedy_generate(params, cfg,
                           jnp.zeros((2, 4), jnp.int32), 4, enc_out=enc)
    out2 = greedy_generate(params, cfg,
                           jnp.zeros((2, 4), jnp.int32), 4,
                           enc_out=enc * 5.0)
    # different audio -> (almost surely) different transcript
    assert not np.array_equal(np.asarray(out1), np.asarray(out2))
