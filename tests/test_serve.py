"""Serving engine: generate loop, KV-cache semantics, sliding windows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models import model as M
from repro.models.attention import KVCache, init_kv_cache, gqa_decode, init_gqa
from repro.serve.engine import (SlotDriver, greedy_generate, init_serve_state,
                               make_serve_step, mask_tree)

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_greedy_generate_deterministic():
    cfg = get_arch("qwen2.5-3b").reduced()
    params = M.init_params(KEY, cfg)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = greedy_generate(params, cfg, prompts, steps=6)
    b = greedy_generate(params, cfg, prompts, steps=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
    assert int(a.max()) < cfg.vocab_size


def test_serve_step_interface():
    cfg = get_arch("gemma3-1b").reduced()
    params = M.init_params(KEY, cfg)
    serve = make_serve_step(cfg)
    state = init_serve_state(cfg, batch=2, max_len=64, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(4):
        tok_next, state = serve(params, state, tok)
        tok = tok_next[:, None]
    assert int(state["decode"]["position"]) == 4


def test_sliding_window_cache_is_ring_buffer():
    """After window+k tokens, the cache holds only the last `window` keys."""
    cfg = get_arch("gemma3-1b").reduced()
    window = 8
    p = init_gqa(KEY, cfg, jnp.float32)
    cache = init_kv_cache(cfg, batch=1, max_len=64, dtype=jnp.float32,
                          window=window)
    assert cache.k.shape[1] == window
    x = jax.random.normal(KEY, (1, 1, cfg.d_model))
    for t in range(window + 3):
        _, cache = gqa_decode(p, cfg, x, cache, jnp.int32(t))
    # oldest retained position is t - window + 1
    pos = np.asarray(cache.pos[0])
    assert pos.min() == (window + 3) - window
    assert int(cache.index) == window + 3


def test_decode_state_constant_size_for_ssm():
    """SSM decode state must not grow with max_len (the long_500k enabler)."""
    cfg = get_arch("xlstm-350m").reduced()
    s1 = M.init_decode_state(cfg, 2, 64)
    s2 = M.init_decode_state(cfg, 2, 4096)
    n1 = sum(x.size for x in jax.tree.leaves(s1["caches"]))
    n2 = sum(x.size for x in jax.tree.leaves(s2["caches"]))
    assert n1 == n2


@pytest.mark.slow
def test_whisper_serve_uses_encoder():
    cfg = get_arch("whisper-small").reduced()
    params = M.init_params(KEY, cfg)
    frames = 0.1 * jax.random.normal(KEY, (2, cfg.encoder_seq, cfg.d_model))
    enc = M.encode(params["encoder"], cfg, frames)
    out1 = greedy_generate(params, cfg,
                           jnp.zeros((2, 4), jnp.int32), 4, enc_out=enc)
    out2 = greedy_generate(params, cfg,
                           jnp.zeros((2, 4), jnp.int32), 4,
                           enc_out=enc * 5.0)
    # different audio -> (almost surely) different transcript
    assert not np.array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# SlotDriver: the batched request driver (continuous-batching-lite)
# ---------------------------------------------------------------------------

def _counter_driver(n_slots):
    """Toy workload: slot i counts up by its own increment until it
    reaches its target — per-slot state that makes any cross-slot leak
    immediately visible."""
    init = {"x": jnp.zeros((n_slots,), jnp.float32),
            "inc": jnp.ones((n_slots,), jnp.float32),
            "target": jnp.full((n_slots,), 1e9, jnp.float32)}

    def step(state, active):
        new = dict(state, x=state["x"] + state["inc"])
        return new, new["x"] >= new["target"]

    return SlotDriver(step, init, n_slots)


def test_slot_driver_admit_step_finish():
    drv = _counter_driver(4)
    assert drv.n_active == 0 and drv.step() == []
    slot = drv.admit("a", {"x": 0.0, "inc": 2.0, "target": 6.0})
    assert slot == 0 and drv.n_active == 1
    finished = []
    for _ in range(5):
        finished.extend(drv.step())
        if finished:
            break
    (rid, out), = finished
    assert rid == "a"
    assert float(out["x"]) == 6.0                  # 3 steps of +2
    assert drv.n_active == 0                       # slot freed


def test_slot_driver_positions_and_active_masking():
    """Positions advance only for active slots; inactive slot state is
    bit-frozen across steps."""
    drv = _counter_driver(3)
    drv.admit("a", {"x": 0.0, "inc": 1.0, "target": 10.0})
    frozen_before = np.asarray(jax.device_get(drv.state["x"]))[1:]
    drv.step()
    drv.step()
    assert list(drv.positions) == [2, 0, 0]
    assert list(drv.active) == [True, False, False]
    frozen_after = np.asarray(jax.device_get(drv.state["x"]))[1:]
    np.testing.assert_array_equal(frozen_before, frozen_after)


def test_slot_driver_recycles_slots():
    """A freed slot is reused by the next admission and carries no state
    from its previous occupant."""
    drv = _counter_driver(2)
    drv.admit("short", {"x": 0.0, "inc": 5.0, "target": 5.0})
    (rid, out), = drv.step()
    assert rid == "short"
    slot = drv.admit("next", {"x": 0.0, "inc": 1.0, "target": 2.0})
    assert slot == 0                               # recycled
    outs = drv.run_to_completion()
    assert outs[0][0] == "next" and float(outs[0][1]["x"]) == 2.0


def test_slot_driver_neighbor_isolation():
    """A request's result is identical whether it runs alone or with
    neighbors admitted/finishing mid-flight — the PR's masking contract."""
    def run(with_neighbors):
        drv = _counter_driver(4)
        drv.admit("a", {"x": 1.0, "inc": 0.5, "target": 4.0})
        results = {}
        step_i = 0
        while drv.n_active or step_i == 0:
            if with_neighbors and step_i == 1:
                drv.admit("b", {"x": 0.0, "inc": 3.0, "target": 3.0})
                drv.admit("c", {"x": -2.0, "inc": 1.0, "target": 0.0})
            for rid, out in drv.step():
                results[rid] = np.asarray(out["x"])
            step_i += 1
            if step_i > 50:
                raise AssertionError("did not drain")
        return results

    alone = run(False)
    crowded = run(True)
    np.testing.assert_array_equal(alone["a"], crowded["a"])
    assert set(crowded) == {"a", "b", "c"}
    assert float(crowded["b"]) == 3.0
    assert float(crowded["c"]) == 0.0


def test_slot_driver_admit_when_full_returns_none():
    drv = _counter_driver(1)
    assert drv.admit("a", {"x": 0.0, "inc": 1.0, "target": 3.0}) == 0
    assert drv.admit("b", {"x": 0.0, "inc": 1.0, "target": 3.0}) is None


def test_slot_driver_validates_state_shape():
    with pytest.raises(ValueError):
        SlotDriver(lambda s, a: (s, a), {"x": jnp.zeros((3,))}, n_slots=4)
    with pytest.raises(ValueError):
        SlotDriver(lambda s, a: (s, a), {"x": jnp.zeros((1,))}, n_slots=0)


def test_mask_tree_selects_per_slot():
    active = jnp.asarray([True, False, True])
    new = {"a": jnp.arange(3.0), "b": jnp.ones((3, 2))}
    old = {"a": jnp.full((3,), -1.0), "b": jnp.zeros((3, 2))}
    out = mask_tree(active, new, old)
    np.testing.assert_array_equal(np.asarray(out["a"]), [0.0, -1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  [[1, 1], [0, 0], [1, 1]])
