"""repro.resilience: deterministic fault injection (FaultSpec streams wired
into Hogwild!, local SGD, and the racing shards) and engine fault tolerance
(crash journal + resume, retry/status accounting, checksummed artifacts).

The determinism contract under test (docs/robustness.md):

* a zero-rate FaultSpec is BIT-exact with ``fault=None`` on every wired
  algorithm — the fault path costs nothing when clean;
* a fixed fault seed makes faulted sweeps bit-reproducible, and the fault
  schedule is shared across seed replicates (environment, not experiment
  randomness);
* fault kwargs are computational: they split the artifact fingerprint;
* a sweep killed mid-run resumes from its crash journal and produces a
  byte-identical artifact; corrupted artifacts quarantine, diverged and
  failed jobs carry a ``status`` and stay out of every readout.
"""

import dataclasses
import json
import os
import signal
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import fit
from repro.data import synth
from repro.experiments import cache as artifact_cache
from repro.experiments import engine, runner
from repro.experiments.spec import (DatasetSpec, EpsilonSpec, JobSpec,
                                    SweepSpec, fingerprint)
from repro.resilience import FaultSpec, faults, journal

KEY = jax.random.PRNGKey(0)

#: drop + sign-flip at rates strong enough to visibly move curves
FAULT = {"drop_rate": 0.2, "corrupt_rate": 0.1,
         "corrupt_kind": "sign_flip", "seed": 3}


def _data(n=160, d=10):
    ds = synth.make_higgs_like(KEY, n=n, d=d)
    return ds.split(key=KEY)


def _tiny_spec(name="res_tiny", jobs=None, **over):
    base = dict(
        name=name, description="resilience test spec",
        ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 100, "d": 8})},
        jobs=jobs if jobs is not None else (JobSpec("minibatch", "d0"),))
    base.update(over)
    return SweepSpec(**base).validate()


# ---------------------------------------------------------------------------
# FaultSpec resolution and validation
# ---------------------------------------------------------------------------

def test_fault_spec_resolution():
    assert faults.resolve(None) is None
    spec = faults.resolve(FAULT)
    assert isinstance(spec, FaultSpec)
    assert spec.drop_rate == 0.2 and spec.seed == 3
    assert faults.resolve(spec) == spec           # passthrough, validated
    assert spec.to_dict()["corrupt_kind"] == "sign_flip"


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="drop_rate"):
        faults.resolve({"drop_rate": 1.5})
    with pytest.raises(ValueError, match="corrupt_rate"):
        faults.resolve(FaultSpec(corrupt_rate=-0.1))
    with pytest.raises(ValueError, match="corrupt_kind"):
        faults.resolve({"corrupt_rate": 0.1, "corrupt_kind": "bitrot"})
    with pytest.raises(ValueError, match="straggle_rounds"):
        faults.resolve(FaultSpec(straggle_rounds=0))
    with pytest.raises(ValueError):               # unknown field
        faults.resolve({"dropp_rate": 0.2})
    with pytest.raises(TypeError):
        faults.resolve("drop everything")


def test_fault_stream_is_seeded_and_shaped():
    spec = faults.resolve({"drop_rate": 0.5, "straggle_rate": 0.25,
                           "seed": 11})
    s1 = faults.make_stream(spec, (64, 4))
    s2 = faults.make_stream(spec, (64, 4))
    assert set(s1) == {"drop", "dup", "straggle", "corrupt"}
    for k in s1:
        assert s1[k].shape == (64, 4)
        np.testing.assert_array_equal(s1[k], s2[k])     # deterministic
    other = faults.make_stream(dataclasses.replace(spec, seed=12), (64, 4))
    assert not np.array_equal(s1["drop"], other["drop"])
    # zero-rate channels are exactly all-zero, rate channels roughly match
    assert float(np.asarray(s1["dup"]).sum()) == 0.0
    assert 0.3 < float(np.asarray(s1["drop"]).mean()) < 0.7


# ---------------------------------------------------------------------------
# zero-rate faults are bit-exact with fault=None (all three algorithms)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,kw", [("hogwild", {"gamma": 0.05}),
                                     ("local_sgd", {"gamma": 0.1})])
def test_zero_rate_bit_exact_engine(algo, kw):
    tr, te = _data()
    clean = engine.run_algorithm_sweep(algo, tr, te, [1, 2, 4], iters=60,
                                       eval_every=20, **kw)
    zero = engine.run_algorithm_sweep(algo, tr, te, [1, 2, 4], iters=60,
                                      eval_every=20, fault={}, **kw)
    np.testing.assert_array_equal(np.asarray(clean["losses"]),
                                  np.asarray(zero["losses"]))


def test_zero_rate_bit_exact_race():
    from repro.distributed import run_hogwild_sharded

    tr, te = _data(n=200, d=8)
    kw = dict(m=4, iters=400, eval_every=100, gamma=0.05, mesh=1)
    clean = run_hogwild_sharded(tr, te, **kw)
    zero = run_hogwild_sharded(tr, te, fault={}, **kw)
    np.testing.assert_array_equal(np.asarray(clean["losses"]),
                                  np.asarray(zero["losses"]))
    assert "fault" not in clean
    # a provided spec is recorded (resolved) even when every rate is zero —
    # the record says "a fault spec was requested", not "faults happened"
    assert zero["fault"]["drop_rate"] == 0.0
    assert zero["fault"]["corrupt_rate"] == 0.0


# ---------------------------------------------------------------------------
# faulted runs: reproducible, different from clean, finite, seed-shared
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,kw", [("hogwild", {"gamma": 0.05}),
                                     ("local_sgd", {"gamma": 0.1})])
def test_faulted_runs_reproducible_and_distinct(algo, kw):
    tr, te = _data()
    run = lambda f: engine.run_algorithm_sweep(       # noqa: E731
        algo, tr, te, [1, 2, 4], iters=60, eval_every=20, fault=f, **kw)
    a, b = run(FAULT), run(FAULT)
    np.testing.assert_array_equal(np.asarray(a["losses"]),
                                  np.asarray(b["losses"]))
    clean = run(None)
    assert not np.array_equal(np.asarray(a["losses"]),
                              np.asarray(clean["losses"]))
    assert np.isfinite(np.asarray(a["losses"])).all()
    reseeded = run({**FAULT, "seed": 99})
    assert not np.array_equal(np.asarray(a["losses"]),
                              np.asarray(reseeded["losses"]))


def test_fault_schedule_shared_across_seed_replicates():
    """Faults are environment, not experiment randomness: the engine's
    per-seed draw keys must not perturb the fault stream, so seed 0 of a
    multi-seed faulted run matches the single-seed faulted run (to the
    ~1-ulp fusion difference between the vmapped-over-seeds trace and the
    single trace) — while a different *fault* seed moves the curves by
    orders of magnitude more."""
    tr, te = _data()
    run = lambda **kw: engine.run_algorithm_sweep(     # noqa: E731
        "hogwild", tr, te, [1, 2], iters=60, eval_every=20, gamma=0.05, **kw)
    one = run(fault=FAULT)
    many = run(fault=FAULT, n_seeds=3)
    np.testing.assert_allclose(np.asarray(many["losses_seeds"])[:, 0],
                               np.asarray(one["losses"]), rtol=0, atol=1e-6)
    reseeded = run(fault={**FAULT, "seed": 99})
    assert np.abs(np.asarray(reseeded["losses"])
                  - np.asarray(one["losses"])).max() > 1e-4


def test_fingerprint_splits_on_fault_kwargs():
    def spec_with(fault):
        kw = {"gamma": 0.05}
        if fault is not None:
            kw["fault"] = fault
        return _tiny_spec(jobs=(JobSpec("hogwild", "d0", kw),))

    fps = [fingerprint(spec_with(f))
           for f in (None, FAULT, {**FAULT, "drop_rate": 0.3},
                     {**FAULT, "seed": 4})]
    assert len(set(fps)) == len(fps)              # all distinct
    assert fingerprint(spec_with(dict(FAULT))) == fps[1]   # equal spec, equal fp


# ---------------------------------------------------------------------------
# artifact checksums: quarantine on corruption, legacy artifacts still load
# ---------------------------------------------------------------------------

def test_cache_checksum_roundtrip_and_quarantine(tmp_path):
    spec = _tiny_spec(name="res_sum")
    res = runner.run_sweep(spec, cache_dir=str(tmp_path))
    path = res["cache"]["path"]
    fp = fingerprint(spec)
    payload = json.load(open(path))
    assert payload["checksum"] == artifact_cache._payload_checksum(payload)

    # hit serves normally while intact
    assert artifact_cache.load(str(tmp_path), spec.name, fp) is not None

    # hand-truncated artifact (torn write / bit rot): quarantined, miss
    raw = open(path).read()
    with open(path, "w") as f:
        f.write(raw[:len(raw) // 2])
    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert artifact_cache.load(str(tmp_path), spec.name, fp) is None
    assert os.path.exists(path + ".corrupt") and not os.path.exists(path)

    # the sweep recomputes and restores a healthy artifact
    res2 = runner.run_sweep(spec, cache_dir=str(tmp_path))
    assert res2["cache"]["hit"] is False
    assert runner.run_sweep(spec, cache_dir=str(tmp_path))["cache"]["hit"]


def test_cache_checksum_detects_mutation(tmp_path):
    spec = _tiny_spec(name="res_mut")
    res = runner.run_sweep(spec, cache_dir=str(tmp_path))
    path = res["cache"]["path"]
    payload = json.load(open(path))
    job = next(iter(payload["jobs"].values()))
    job["losses"][0][0] += 1e-9                   # a single flipped value
    with open(path, "w") as f:
        json.dump(payload, f)                     # checksum left stale
    with pytest.warns(RuntimeWarning, match="checksum mismatch"):
        assert artifact_cache.load(str(tmp_path), spec.name,
                                   fingerprint(spec)) is None
    assert os.path.exists(path + ".corrupt")


def test_cache_legacy_artifact_without_checksum_loads(tmp_path):
    spec = _tiny_spec(name="res_leg")
    res = runner.run_sweep(spec, cache_dir=str(tmp_path))
    path = res["cache"]["path"]
    payload = json.load(open(path))
    payload.pop("checksum")                       # pre-checksum artifact
    with open(path, "w") as f:
        json.dump(payload, f)
    hit = runner.run_sweep(spec, cache_dir=str(tmp_path))
    assert hit["cache"]["hit"] is True


# ---------------------------------------------------------------------------
# crash journal: torn lines, resume, byte-identical artifacts
# ---------------------------------------------------------------------------

def test_journal_read_skips_torn_and_foreign_entries(tmp_path):
    path = journal.journal_path(str(tmp_path), "j", "f" * 64)
    journal.append_entry(path, "f" * 64, "good", {"x": 1.5})
    journal.append_entry(path, "0" * 64, "foreign", {"x": 2})
    with open(path, "a") as f:
        f.write('{"fingerprint": "' + "f" * 64 + '", "key": "torn')
    entries = journal.read_entries(path, "f" * 64)
    assert entries == {"good": {"x": 1.5}}
    assert journal.read_entries("/nonexistent/journal", "f" * 64) == {}
    journal.consume(path)
    assert not os.path.exists(path)
    journal.consume(path)                          # idempotent


def test_journal_resume_is_byte_identical(tmp_path, monkeypatch):
    """Crash after job 1 of 2 (simulated with a KeyboardInterrupt, which
    the retry loop must NOT swallow), then re-run: only job 2 computes,
    and the final artifact is byte-identical to an uninterrupted run's."""
    spec = _tiny_spec(
        name="res_resume",
        jobs=(JobSpec("minibatch", "d0"),
              JobSpec("hogwild", "d0", {"gamma": 0.05})),
        epsilon=EpsilonSpec(probe_m=1, frac=0.7))
    a, b = str(tmp_path / "a"), str(tmp_path / "b")

    uninterrupted = runner.run_sweep(spec, cache_dir=a)
    golden = open(uninterrupted["cache"]["path"], "rb").read()

    real = engine.run_algorithm_sweep
    calls = []

    def crashing(*args, **kwargs):
        calls.append(args)
        if len(calls) == 2:
            raise KeyboardInterrupt("simulated SIGKILL")
        return real(*args, **kwargs)

    monkeypatch.setattr(engine, "run_algorithm_sweep", crashing)
    with pytest.raises(KeyboardInterrupt):
        runner.run_sweep(spec, cache_dir=b)
    jpath = journal.journal_path(b, spec.name, fingerprint(spec))
    assert os.path.exists(jpath)                  # job 1 journaled
    assert len(journal.read_entries(jpath, fingerprint(spec))) == 1

    counting = []

    def counted(*args, **kwargs):
        counting.append(args)
        return real(*args, **kwargs)

    monkeypatch.setattr(engine, "run_algorithm_sweep", counted)
    resumed = runner.run_sweep(spec, cache_dir=b)
    assert len(counting) == 1                     # only job 2 recomputed
    assert open(resumed["cache"]["path"], "rb").read() == golden
    assert not os.path.exists(jpath)              # consumed after store


def test_journal_disabled_or_uncached_writes_nothing(tmp_path):
    spec = _tiny_spec(name="res_noj")
    jpath = journal.journal_path(str(tmp_path), spec.name, fingerprint(spec))
    runner.run_sweep(spec, cache_dir=str(tmp_path), journal=False)
    runner.run_sweep(spec, use_cache=False, cache_dir=str(tmp_path))
    assert not os.path.exists(jpath)


# ---------------------------------------------------------------------------
# retry + status accounting, and unhealthy jobs staying out of readouts
# ---------------------------------------------------------------------------

def test_transient_failure_retries_to_ok(tmp_path, monkeypatch):
    spec = _tiny_spec(name="res_retry")
    real = engine.run_algorithm_sweep
    calls = []

    def flaky(*args, **kwargs):
        calls.append(args)
        if len(calls) == 1:
            raise RuntimeError("transient device loss")
        return real(*args, **kwargs)

    monkeypatch.setattr(engine, "run_algorithm_sweep", flaky)
    res = runner.run_sweep(spec, cache_dir=str(tmp_path),
                           retry_backoff_s=0.0)
    jr = res["jobs"]["minibatch/d0"]
    assert jr["status"] == "retried:1"
    assert runner.job_is_healthy(jr)
    assert np.isfinite(np.asarray(jr["losses"])).all()


def test_permanent_failure_becomes_structured_stub(tmp_path, monkeypatch):
    spec = _tiny_spec(name="res_fail", epsilon=EpsilonSpec(probe_m=1))

    def broken(*args, **kwargs):
        raise RuntimeError("device pool gone")

    monkeypatch.setattr(engine, "run_algorithm_sweep", broken)
    with pytest.warns(RuntimeWarning, match="failed after 2 attempt"):
        res = runner.run_sweep(spec, cache_dir=str(tmp_path),
                               retry_backoff_s=0.0)
    jr = res["jobs"]["minibatch/d0"]
    assert jr["status"] == "failed"
    assert "device pool gone" in jr["error"]
    assert not runner.job_is_healthy(jr)
    assert "losses" not in jr and "measured_m_max" not in jr
    # the stub is cached (and served) like any result
    assert runner.run_sweep(spec, cache_dir=str(tmp_path))["cache"]["hit"]


def test_diverged_job_excluded_from_readouts(tmp_path):
    """A diverged cell keeps its curves and a 'diverged' status but stays
    out of the epsilon/cost readout, the predictor, and the characters
    regression — its healthy neighbor's numbers are exactly what they are
    in a sweep without the bad job."""
    good = JobSpec("minibatch", "d0", predict=True)
    # ridge curvature on wide higgs-like features (d=28) blows up at this
    # step size — the same divergent cell test_protocols pins the warning on
    bad = JobSpec("minibatch", "wide", {"gamma": 0.1}, problem="ridge",
                  label="bad")
    eps = EpsilonSpec(probe_m=1, frac=0.7)
    mixed_spec = _tiny_spec(
        name="res_mixed", jobs=(good, bad), iters=120, epsilon=eps,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 100, "d": 8}),
                  "wide": DatasetSpec("higgs_like", {"n": 120, "d": 28})})
    clean_spec = _tiny_spec(name="res_clean", jobs=(good,), iters=120,
                            epsilon=eps)

    with pytest.warns(RuntimeWarning, match="non-finite"):
        mixed = runner.run_sweep(mixed_spec, cache_dir=str(tmp_path),
                                 retry_backoff_s=0.0)
    clean = runner.run_sweep(clean_spec, cache_dir=str(tmp_path))

    jr_bad = mixed["jobs"]["minibatch[bad]+ridge/wide"]
    assert jr_bad["status"] == "diverged"
    assert "losses" in jr_bad                     # curves kept for forensics
    assert "epsilon" not in jr_bad and "measured_m_max" not in jr_bad
    assert "predicted" not in jr_bad

    jr_good, jr_ref = mixed["jobs"]["minibatch/d0"], clean["jobs"]["minibatch/d0"]
    assert jr_good["status"] == "ok"
    assert jr_good["measured_m_max"] == jr_ref["measured_m_max"]
    assert jr_good["epsilon"] == jr_ref["epsilon"]

    points = fit.collect_character_points([mixed])
    assert [p["job"] for p in points] == ["minibatch/d0"]


def test_legacy_artifacts_default_to_healthy():
    assert runner.job_is_healthy({"losses": [[0.1]]})       # no status key
    assert runner.job_is_healthy({"status": "retried:2"})
    assert not runner.job_is_healthy({"status": "diverged"})
    assert not runner.job_is_healthy({"status": "failed"})


# ---------------------------------------------------------------------------
# the fault_tolerance spec + report section
# ---------------------------------------------------------------------------

def test_fault_tolerance_spec_registered():
    from repro.experiments.registry import get_spec

    spec = get_spec("fault_tolerance", quick=True)
    assert {j.algorithm for j in spec.jobs} == {"hogwild", "local_sgd"}
    rates = {j.kwargs["fault"]["straggle_rate"] for j in spec.jobs}
    assert 0.0 in rates and max(rates) == 0.5
    assert all(j.kwargs["fault"]["seed"] == 7 for j in spec.jobs)
    assert spec.epsilon.probe_m == 1              # serial probe: see builder


@pytest.mark.slow
def test_fault_tolerance_report_trend(tmp_path):
    """Acceptance: the rendered fault-tolerance section shows m_max
    degrading faster on the hi-variance character setting than on the
    duplicated lo-variance one, for both wired algorithms."""
    from repro.analysis import report, stats
    from repro.experiments.registry import get_spec

    spec = get_spec("fault_tolerance", quick=True)
    res = runner.run_sweep(spec, cache_dir=str(tmp_path))
    text = "\n".join(report.render_fault_tolerance(res))
    assert "Fault tolerance" in text and "hogwild" in text

    kept = {}
    for (algo, ds) in [("hogwild", "lo_char"), ("hogwild", "hi_char"),
                       ("local_sgd", "lo_char"), ("local_sgd", "hi_char")]:
        boots = {}
        for job in spec.jobs:
            if job.algorithm != algo or job.dataset != ds:
                continue
            rate = job.kwargs["fault"]["straggle_rate"]
            boots[rate] = stats.mmax_bootstrap(
                res["jobs"][job.key], probe_m=1, frac=0.7)["m_max"]
        kept[(algo, ds)] = boots[max(boots)] / boots[0.0]
    for algo in ("hogwild", "local_sgd"):
        assert kept[(algo, "hi_char")] < kept[(algo, "lo_char")], kept


# ---------------------------------------------------------------------------
# subprocess contracts: SIGKILL crash/resume, 8-device faulted parity
# ---------------------------------------------------------------------------

_SUB_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, numpy as np
"""


def _run_sub(body, timeout, check=True):
    script = textwrap.dedent(_SUB_PRELUDE) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], cwd=".",
                       capture_output=True, text=True, timeout=timeout)
    if check:
        assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r


KILL_RESUME_BODY = """
    import os, signal, sys
    from repro.experiments.spec import DatasetSpec, EpsilonSpec, JobSpec, SweepSpec
    from repro.experiments import engine, runner

    spec = SweepSpec(
        name="kr", ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 100, "d": 8})},
        jobs=(JobSpec("minibatch", "d0"),
              JobSpec("hogwild", "d0", {"gamma": 0.05}),
              JobSpec("local_sgd", "d0", {"gamma": 0.1})),
        epsilon=EpsilonSpec(probe_m=1, frac=0.7)).validate()

    cache_dir, mode = sys.argv[1], sys.argv[2]
    real = engine.run_algorithm_sweep
    calls = [0]
    def wrapper(*a, **k):
        calls[0] += 1
        if mode == "kill" and calls[0] == 2:
            os.kill(os.getpid(), signal.SIGKILL)   # job 1 journaled, die
        return real(*a, **k)
    engine.run_algorithm_sweep = wrapper
    res = runner.run_sweep(spec, cache_dir=cache_dir)
    print("CALLS", calls[0])
    print("PATH", res["cache"]["path"])
"""


@pytest.mark.slow
def test_sigkill_resume_byte_identical(tmp_path):
    """Kill a sweep with SIGKILL mid-job-2, re-run: the journal replays
    job 1, only jobs 2-3 recompute, and the artifact is byte-identical to
    an uninterrupted run's."""
    script = textwrap.dedent(_SUB_PRELUDE) + textwrap.dedent(KILL_RESUME_BODY)
    crashed_dir, control_dir = str(tmp_path / "c"), str(tmp_path / "u")

    r = subprocess.run([sys.executable, "-c", script, crashed_dir, "kill"],
                       cwd=".", capture_output=True, text=True, timeout=420)
    assert r.returncode == -signal.SIGKILL, (r.stdout, r.stderr)
    journals = [f for f in os.listdir(crashed_dir)
                if f.endswith(".journal.jsonl")]
    assert len(journals) == 1                     # the crash left a journal

    r = subprocess.run([sys.executable, "-c", script, crashed_dir, "run"],
                       cwd=".", capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "CALLS 2" in r.stdout                  # jobs 2-3 only
    resumed_path = r.stdout.split("PATH ")[1].strip()
    assert not any(f.endswith(".journal.jsonl")
                   for f in os.listdir(crashed_dir))

    r = subprocess.run([sys.executable, "-c", script, control_dir, "run"],
                       cwd=".", capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "CALLS 3" in r.stdout                  # uninterrupted: all jobs
    control_path = r.stdout.split("PATH ")[1].strip()

    assert open(resumed_path, "rb").read() == open(control_path, "rb").read()


FAULTED_PARITY_BODY = """
    from repro.data import synth
    from repro.experiments import engine
    from repro.distributed import run_hogwild_sharded

    assert len(jax.devices()) == 8
    ds = synth.make_higgs_like(jax.random.PRNGKey(0), n=400, d=16)
    tr, te = ds.split(key=jax.random.PRNGKey(0))
    FAULT = {"drop_rate": 0.25, "corrupt_rate": 0.1,
             "corrupt_kind": "sign_flip", "seed": 3}

    # racing dropped-delta vs the sequential fault oracle at m == D,
    # sync_every=1 (threefry streams are flat-identical at equal counts)
    m, iters, ev = 8, 1600, 200
    oracle = engine.run_algorithm_sweep(
        "hogwild", tr, te, [m], iters=iters, eval_every=ev,
        gamma=0.05, fault=FAULT)
    race = run_hogwild_sharded(tr, te, m=m, iters=iters, gamma=0.05,
                               eval_every=ev, mesh=8, fault=FAULT)
    d = float(np.abs(np.asarray(oracle["losses"][0])
                     - np.asarray(race["losses"])).max())
    print("parity", d)
    assert d <= 1e-5, d
    assert race["fault"]["drop_rate"] == 0.25     # spec recorded in result

    # faulted engine sweeps stay mesh-invariant
    ms = [1, 2, 4, 8]
    for algo, kw in (("hogwild", {"gamma": 0.05}),
                     ("local_sgd", {"gamma": 0.1})):
        r1 = engine.run_algorithm_sweep(algo, tr, te, ms, iters=400,
                                        eval_every=100, n_seeds=2,
                                        fault=FAULT, **kw)
        r8 = engine.run_algorithm_sweep(algo, tr, te, ms, iters=400,
                                        eval_every=100, n_seeds=2,
                                        fault=FAULT, mesh=8, **kw)
        d = float(np.abs(np.asarray(r1["losses_seeds"])
                         - np.asarray(r8["losses_seeds"])).max())
        print("invariance", algo, d)
        assert d <= 1e-5, (algo, d)
"""


@pytest.mark.slow
def test_faulted_race_parity_and_mesh_invariance_8dev():
    out = _run_sub(FAULTED_PARITY_BODY, timeout=420).stdout
    assert "parity" in out and out.count("invariance") == 2
