"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)
+ hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.compression import quantize_stochastic as quantize_oracle
from repro.core.metrics import csim_ref, l0_distance
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D,window", [
    (1, 64, 2, 2, 32, 0),
    (2, 128, 4, 2, 64, 0),
    (2, 200, 4, 1, 64, 0),        # ragged seq (padding path)
    (1, 256, 8, 8, 128, 0),       # MHA
    (2, 128, 4, 2, 64, 32),       # sliding window
    (1, 96, 6, 3, 48, 16),        # odd head dim / window
])
def test_flash_attention_matches_ref(B, S, H, KV, D, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              bq=64, bk=64)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True,
                          window=window).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    a = ops.flash_attention(q, k, v, bq=32, bk=32)
    b = ops.flash_attention(q, k, v, bq=128, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# csim / l0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,rng", [(32, 16, 1), (100, 60, 5), (64, 33, 8)])
def test_csim_matches_ref(n, d, rng):
    X = jax.random.normal(KEY, (n, d))
    np.testing.assert_allclose(float(ops.csim(X, rng)), csim_ref(X, rng),
                               rtol=1e-6)


def test_l0_rows_matches_ref():
    x = jax.random.normal(KEY, (70, 45))
    y = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, x.shape),
                  x, 0.0)
    np.testing.assert_allclose(np.asarray(ops.l0_rows(x, y)),
                               np.asarray(ref.l0_rows_ref(x, y)))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(1, 30), st.integers(1, 6))
def test_csim_permutation_invariant_total(n, d, rng):
    """Property: csim of identical rows is 0; of disjoint-support rows it's
    bounded by d."""
    X = jnp.ones((n, d))
    assert csim_ref(X, min(rng, n - 1)) == 0.0
    X2 = jnp.eye(n, d)
    v = csim_ref(X2, min(rng, n - 1))
    assert 0.0 <= v <= d


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8, 16])
@pytest.mark.parametrize("shape", [(16, 16), (64, 32), (7, 129)])
def test_quantize_matches_oracle(bits, shape):
    x = jax.random.normal(KEY, shape)
    q, s = ops.quantize_stochastic(x, KEY, bits=bits)
    qr, sr = quantize_oracle(x, KEY, bits=bits)
    assert float(s) == pytest.approx(float(sr))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))


def test_quantize_error_bound():
    x = jax.random.normal(KEY, (64, 64))
    q, s = ops.quantize_stochastic(x, KEY, bits=8)
    err = np.abs(np.asarray(ops.dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 1.0001   # stochastic rounding: < 1 ulp


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5))
def test_quantize_unbiased(seed):
    """E[C(x)] = x (paper Eq. 7 requirement) — mean over many keys."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 8))
    keys = jax.random.split(jax.random.PRNGKey(seed + 100), 256)
    deqs = [ops.dequantize(*ops.quantize_stochastic(x, k, bits=8))
            for k in keys[:64]]
    mean = np.mean([np.asarray(d) for d in deqs], axis=0)
    q, s = ops.quantize_stochastic(x, keys[0], bits=8)
    assert np.abs(mean - np.asarray(x)).max() < 3 * float(s)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d", [(8, 64), (300, 128), (5, 1152)])
def test_rmsnorm_matches_ref(n, d, dtype):
    x = jax.random.normal(KEY, (n, d), dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (d,), dtype)
    out = ops.rmsnorm(x, g)
    r = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=2e-2
                               if dtype == jnp.bfloat16 else 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 100.0))
def test_rmsnorm_scale_invariance(alpha):
    """Property: rmsnorm(a x) == rmsnorm(x) for a > 0."""
    x = jax.random.normal(KEY, (4, 32))
    g = jnp.ones((32,))
    a = ops.rmsnorm(x * alpha, g)
    b = ops.rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-3)
