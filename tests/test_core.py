"""Paper-core tests: metrics definitions, algorithm convergence, and the
paper's qualitative claims (the EXPERIMENTS.md validation in miniature)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import metrics as MX
from repro.core import scalability as SC
from repro.core.advisor import ScalabilityAdvisor
from repro.core.algorithms import (run_dadm, run_ecd_psgd, run_hogwild,
                                   run_minibatch)
from repro.data import synth

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# metrics (§IV)
# ---------------------------------------------------------------------------

def test_example2_csim_orderings():
    """Paper Example 2: same 6 samples, two orderings, different C_sim_2."""
    seq1 = jnp.array([[0, 0, 0], [0, 0, 1], [0, 1, 1],
                      [0, 1, 0], [1, 1, 0], [1, 0, 0]], jnp.float32)
    perm = jnp.array([0, 4, 1, 5, 3, 2])
    seq2 = seq1[perm]
    c1 = MX.csim_ref(seq1, 2)
    c2 = MX.csim_ref(seq2, 2)
    assert c1 != c2
    assert c1 < c2           # adjacent-similar ordering has smaller C_sim


def test_sparsity_and_variance_relation():
    """Paper §IV.B: sparse dataset => small feature variance."""
    sparse = synth.make_realsim_like(KEY, n=500, d=200, density=0.03)
    dense = synth.make_higgs_like(KEY, n=500, d=28)
    assert MX.sparsity(sparse.X) > 0.9
    assert MX.sparsity(dense.X) < 0.05
    assert (MX.mean_feature_variance(sparse.X)
            < MX.mean_feature_variance(dense.X))


def test_diversity_constructions():
    """real_sim2 / real_sim4 duplication halves/quarters diversity."""
    base = synth.make_realsim_like(KEY, n=400, d=100)
    high, mid, low = synth.make_diversity_variants(base)
    dh, dm, dl = (MX.diversity(x.X) for x in (high, mid, low))
    # sparse random rows can collide, so compare ratios, not exact counts
    assert dh > 0.9 * 400
    assert dm < 0.6 * dh and dl < 0.35 * dh
    assert high.X.shape == mid.X.shape == low.X.shape


def test_one_sample_dataset_diversity():
    """Paper Example 12: size can grow, diversity stays 1."""
    ds = synth.make_one_sample_dataset(KEY, n=256, d=16)
    assert MX.diversity(ds.X) == 1
    assert MX.diversity_ratio(ds.X) == pytest.approx(1 / 256)


def test_ls_sequences_order():
    small = synth.make_ls_sequence(KEY, n=400, d=50, mutate_frac=0.1)
    large = synth.make_ls_sequence(KEY, n=400, d=50, mutate_frac=0.9)
    assert MX.csim_ref(small.X, 4) < MX.csim_ref(large.X, 4)


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 60), st.integers(5, 40))
def test_sparsity_bounds(n, d):
    X = jax.random.normal(jax.random.PRNGKey(n * d), (n, d))
    assert 0.0 <= MX.sparsity(X) <= 1.0
    assert MX.diversity(X) <= n
    hw = MX.hogwild_params(X)
    assert 0.0 <= hw["delta"] <= 1.0 and 0.0 <= hw["rho"] <= 1.0
    assert 0 <= hw["omega_frac"] <= 1.0


# ---------------------------------------------------------------------------
# algorithms converge on their suitable datasets
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_split():
    ds = synth.make_higgs_like(KEY, n=2000, d=28)
    return ds.split(key=KEY)


@pytest.fixture(scope="module")
def sparse_split():
    ds = synth.make_realsim_like(KEY, n=2000, d=400, density=0.05)
    return ds.split(key=KEY)


@pytest.mark.parametrize("runner,kw", [
    (run_hogwild, {"m": 4}),
    (run_minibatch, {"batch_size": 4}),
    pytest.param(run_ecd_psgd, {"m": 4}, marks=pytest.mark.xfail(
        strict=True,
        reason="root-caused (ISSUE 6): ECD-PSGD's z-extrapolation range "
               "grows ~t*gamma, so stochastic-quantization noise "
               "(~ range * 2^-bits, injected with weight 2/t) settles at a "
               "constant floor ~ gamma * 2^-bits that exceeds this split's "
               "optimality gap at gamma=0.1 / 8 bits — faithful algorithm "
               "behaviour at an aggressive operating point, not an engine "
               "bug; see test_ecd_psgd_quantization_noise_floor")),
    (run_dadm, {"m": 4}),
])
def test_algorithms_decrease_loss(dense_split, runner, kw):
    tr, te = dense_split
    r = runner(tr, te, iters=1500, eval_every=100, **kw)
    assert r["losses"][-1] < r["losses"][0]
    assert np.isfinite(r["losses"]).all()


def test_ecd_psgd_quantization_noise_floor(dense_split):
    """Regression pin for the strict xfail above: the non-descent is the
    step-size x quantization interaction, so shrinking the noise floor on
    EITHER axis — more bits at the same gamma, or a smaller gamma at the
    same bits — restores descent on the identical split/seed/budget."""
    tr, te = dense_split
    kw = dict(m=4, iters=1500, eval_every=100)
    at_fault = run_ecd_psgd(tr, te, gamma=0.1, compress_bits=8, **kw)
    finer = run_ecd_psgd(tr, te, gamma=0.1, compress_bits=16, **kw)
    smaller = run_ecd_psgd(tr, te, gamma=0.02, compress_bits=8, **kw)
    # the failing point descends mid-run then wanders at its noise floor
    assert min(at_fault["losses"]) < at_fault["losses"][0]
    assert not at_fault["losses"][-1] < at_fault["losses"][0]
    for fixed in (finer, smaller):
        assert fixed["losses"][-1] < fixed["losses"][0]
        assert fixed["losses"][-1] < at_fault["losses"][-1]


def test_ecd_psgd_divergence_envelope():
    """Enforce the documented ECD-PSGD exemption (docs/distributed.md):
    stochastic quantization makes every execution-mode comparison chaotic
    at long horizons — but inside a measured envelope.  At 60 iterations
    the modes agree essentially exactly; by 120+ the same ulp-level
    reconvergence noise is amplified to the ~1e-2 class, and no further.
    A blow-up past the envelope (or a silent return to exactness after an
    engine change that skirts the quantizer) fails this pin."""
    from repro.experiments import engine

    key = jax.random.PRNGKey(0)
    ds = synth.make_higgs_like(key, n=160, d=10)
    tr, te = ds.split(key=key)
    ms = [1, 2, 4, 8]

    def modes(iters):
        kw = dict(iters=iters, eval_every=20, key=key)
        b = engine.run_algorithm_sweep("ecd_psgd", tr, te, ms,
                                       bucketed=True, **kw)
        f = engine.run_algorithm_sweep("ecd_psgd", tr, te, ms,
                                       bucketed=False, **kw)
        s = engine.run_algorithm_sweep("ecd_psgd", tr, te, ms,
                                       use_vmap=False, **kw)
        return (np.asarray(b["losses"]), np.asarray(f["losses"]),
                np.asarray(s["losses"]))

    b60, f60, s60 = modes(60)
    # short horizon: bucketed==flat to float32 ulps, sequential near-exact
    np.testing.assert_allclose(b60, f60, rtol=0, atol=1e-6)
    np.testing.assert_allclose(f60, s60, rtol=0, atol=1e-3)

    b120, f120, s120 = modes(120)
    for a, b in ((b120, f120), (f120, s120)):
        assert np.isfinite(a).all() and np.isfinite(b).all()
        assert np.abs(a - b).max() <= 2e-2    # the documented ~1e-2 class


@pytest.mark.slow
def test_paper_fig3_variance_sparsity_trend(dense_split, sparse_split):
    """Fig 3: mini-batch parallel gain is large on the dense/high-variance
    dataset and minor on the sparse dataset (gap between m=1 and m=8)."""
    gaps = {}
    for name, (tr, te) in [("dense", dense_split), ("sparse", sparse_split)]:
        r1 = run_minibatch(tr, te, batch_size=1, iters=800, eval_every=100)
        r8 = run_minibatch(tr, te, batch_size=8, iters=800, eval_every=100)
        gaps[name] = float(np.mean(np.array(r1["losses"])
                                   - np.array(r8["losses"])))
    assert gaps["dense"] > gaps["sparse"]
    assert gaps["dense"] > 0


@pytest.mark.slow
def test_paper_fig5_hogwild_sparse_tolerance(dense_split, sparse_split):
    """Fig 5: Hogwild!'s staleness penalty (gap between m=1 and m=8 at fixed
    server iteration) is smaller on the sparse dataset."""
    gap = {}
    for name, (tr, te) in [("dense", dense_split), ("sparse", sparse_split)]:
        r1 = run_hogwild(tr, te, m=1, iters=1200, eval_every=100, gamma=0.05)
        r8 = run_hogwild(tr, te, m=8, iters=1200, eval_every=100, gamma=0.05)
        gap[name] = float(np.mean(np.abs(np.array(r8["losses"])
                                         - np.array(r1["losses"]))))
    assert gap["sparse"] < gap["dense"]


@pytest.mark.slow
def test_paper_fig6_dadm_diversity(sparse_split):
    """Fig 6: DADM's parallel gain shrinks as diversity drops."""
    base = synth.make_realsim_like(KEY, n=1600, d=300, density=0.05)
    high, mid, low = synth.make_diversity_variants(base)
    gains = []
    for ds in (high, low):
        tr, te = ds.split(key=KEY)
        r1 = run_dadm(tr, te, m=1, iters=400, eval_every=100)
        r8 = run_dadm(tr, te, m=8, iters=400, eval_every=100)
        gains.append(float(np.mean(np.array(r1["losses"])
                                   - np.array(r8["losses"]))))
    assert gains[0] > gains[1]    # high diversity gains more from m=8


# ---------------------------------------------------------------------------
# scalability machinery
# ---------------------------------------------------------------------------

def test_gain_growth_and_upper_bound():
    costs = [100.0, 60.0, 45.0, 40.0, 41.0, 44.0]
    gg = SC.gain_growth_from_costs(costs)
    assert gg[0] == 40.0
    ms = [1, 2, 4, 8, 16, 24]
    assert SC.measured_upper_bound(ms[:-1], gg) == 8   # growth <= 0 at m=8


def test_hogwild_mmax_ordering():
    sparse = synth.make_realsim_like(KEY, n=600, d=400, density=0.03)
    dense = synth.make_higgs_like(KEY, n=600, d=28)
    ms = SC.predict_hogwild_mmax(sparse.X)["predicted_m_max"]
    md = SC.predict_hogwild_mmax(dense.X)["predicted_m_max"]
    assert ms > md        # paper Fig 1/2: sparse suits Hogwild!


def test_advisor_reports():
    adv = ScalabilityAdvisor()
    sparse = synth.make_realsim_like(KEY, n=300, d=200)
    rep = adv.from_dataset(sparse.X, tau_max=4, batch_size=4)
    assert "recommendation" in rep and rep["hogwild"]["predicted_m_max"] >= 1
    # gradient-level: fabricate shard grads with known sparsity
    g1 = {"w": jnp.array([0.0, 1.0, 0.0, 0.0])}
    g2 = {"w": jnp.array([0.0, 0.9, 0.0, 0.0])}
    rep = adv.from_grads([g1, g2])
    assert rep["grad_sparsity"] == pytest.approx(0.75)
    assert rep["shard_cosine_similarity"] == pytest.approx(1.0, abs=1e-5)


def test_iterations_to_epsilon():
    losses = np.array([0.9, 0.7, 0.5, 0.3])
    assert SC.iterations_to_epsilon(losses, 100, 0.5) == 300
    assert SC.iterations_to_epsilon(losses, 100, 0.1) == np.inf


def test_advisor_invalid_probes_are_structured():
    """Edge-case probes return a structured low-confidence report (valid
    False + reason + conservative m_max 1) — never NaN, never a raise."""
    adv = ScalabilityAdvisor()
    cases = [
        (adv.from_grads([]), "empty shard list"),
        (adv.from_grads(None), "empty shard list"),
        (adv.from_grads([{"w": jnp.ones(3)}]), "single gradient shard"),
        (adv.from_grads([{"w": jnp.ones(3)},
                         {"w": jnp.array([1.0, np.nan, 0.0])}]),
         "non-finite gradient"),
        (adv.from_dataset(None), "no dataset"),
        (adv.from_dataset(jnp.ones(5)), "matrix"),
        (adv.from_dataset(jnp.ones((1, 4))), "too small"),
        (adv.from_dataset(jnp.full((6, 3), np.inf)), "non-finite"),
    ]
    for rep, frag in cases:
        assert rep["valid"] is False, frag
        assert frag in rep["reason"], rep["reason"]
        assert rep["confidence"] == 0.0
        assert rep["predicted_m_max_conservative"] == 1
        assert "recommendation" in rep
        assert all(np.isfinite(v) for v in rep.values()
                   if isinstance(v, float))


def test_advisor_valid_reports_flagged_valid():
    adv = ScalabilityAdvisor()
    data = synth.make_higgs_like(KEY, n=80, d=6)
    assert adv.from_dataset(data.X)["valid"] is True
    grads = [{"w": jnp.ones(4) * i} for i in (1, 2)]
    assert adv.from_grads(grads)["valid"] is True


def test_advisor_batched_characters_match_scalar():
    """The masked-batch probe paths agree with the scalar paths and mark
    invalid entries None."""
    adv = ScalabilityAdvisor()
    X_ok = np.asarray(synth.make_realsim_like(KEY, n=60, d=40).X)
    X_bad = np.full((4, 2), np.nan)
    out = adv.dataset_characters_batch([X_ok, X_bad, X_ok[:30, :10]])
    assert out[1] is None
    seq = adv.from_dataset(X_ok)
    for k in ("mean_feature_variance", "sparsity", "omega_frac",
              "delta", "rho"):
        assert out[0][k] == pytest.approx(seq[k], abs=1e-6), k
    assert out[0]["diversity"] == seq["diversity"]

    g_ok = [{"w": jnp.arange(4.0)}, {"w": jnp.arange(4.0) * 2}]
    gout = adv.grad_characters_batch([g_ok, [], g_ok])
    assert gout[1] is None
    gseq = adv.grad_characters(g_ok)
    for k in gseq:
        assert gout[0][k] == pytest.approx(gseq[k], abs=1e-5), k
