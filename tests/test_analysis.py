"""repro.analysis: vectorized-vs-oracle parity (stats + predictors), the
Thm-2 cost-law fit, bootstrap statistics, the characters -> m_max
regression, scalar-oracle coverage for core.scalability, and the report
CLI end to end."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import fit, stats
from repro.core import scalability as SC
from repro.core.advisor import ScalabilityAdvisor
from repro.data import synth

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# core.scalability scalar oracles (direct coverage — previously exercised
# only through benchmarks)
# ---------------------------------------------------------------------------

def test_iterations_to_epsilon_never_hits_and_exact_hit():
    losses = np.array([0.9, 0.5, 0.3])
    assert SC.iterations_to_epsilon(losses, 50, 0.1) == math.inf
    # exact hit: the eval equal to epsilon counts as reaching it
    assert SC.iterations_to_epsilon(losses, 50, 0.5) == 100.0
    # first eval already below epsilon
    assert SC.iterations_to_epsilon(losses, 50, 2.0) == 50.0


def test_cost_per_worker_async_division():
    r = {"losses": [0.9, 0.4], "eval_every": 100, "m": 4}
    assert SC.cost_per_worker(r, 0.5, asynchronous=False) == 200.0
    assert SC.cost_per_worker(r, 0.5, asynchronous=True) == 50.0
    assert SC.cost_per_worker(r, 0.1, asynchronous=True) == math.inf


def test_gain_growth_from_costs():
    assert SC.gain_growth_from_costs([100.0, 60.0, 45.0]) == [40.0, 15.0]
    assert SC.gain_growth_from_costs([10.0]) == []


def test_gain_growth_from_losses_clamps_at_iteration_zero():
    """Regression: at_iteration=0 computed index min(0, len)-1 == -1 and
    silently read the LAST eval; it must clamp to the first."""
    results = [{"losses": [0.9, 0.2], "eval_every": 100},
               {"losses": [0.5, 0.1], "eval_every": 100}]
    assert SC.gain_growth_from_losses(results, 0) == \
        pytest.approx([0.9 - 0.5])
    # interior and beyond-budget reads are unchanged
    assert SC.gain_growth_from_losses(results, 100) == \
        pytest.approx([0.9 - 0.5])
    assert SC.gain_growth_from_losses(results, 200) == \
        pytest.approx([0.2 - 0.1])
    assert SC.gain_growth_from_losses(results, 10**6) == \
        pytest.approx([0.2 - 0.1])


# ---------------------------------------------------------------------------
# stats: vectorized forms pinned to the scalar oracles
# ---------------------------------------------------------------------------

def test_vectorized_iterations_to_epsilon_parity():
    rng = np.random.default_rng(0)
    curves = rng.uniform(0.1, 1.0, size=(3, 5, 8))
    for eps in (0.15, 0.5, 2.0, 0.05):
        vec = stats.iterations_to_epsilon(curves, 25, eps)
        for i in range(3):
            for j in range(5):
                assert vec[i, j] == SC.iterations_to_epsilon(
                    curves[i, j], 25, eps)


def test_iterations_to_epsilon_per_seed_broadcast():
    """A (n_seeds,) epsilon aligns with the SEED axis of (seeds, S, E)
    curves — one threshold per seed, applied to every grid row — and an
    over-ranked epsilon is rejected instead of mis-broadcast."""
    curves = np.array([[[0.9, 0.5], [0.8, 0.4]],      # seed 0
                       [[0.9, 0.5], [0.8, 0.4]]])     # seed 1 (same)
    eps = np.array([0.45, 0.85])                       # differs per seed
    out = stats.iterations_to_epsilon(curves, 10, eps)
    for j in range(2):                                 # every grid row
        assert out[0, j] == stats.iterations_to_epsilon(
            curves[0, j], 10, 0.45)
        assert out[1, j] == stats.iterations_to_epsilon(
            curves[1, j], 10, 0.85)
    with pytest.raises(ValueError):
        stats.iterations_to_epsilon(curves, 10, np.zeros((2, 2, 2, 2)))


def test_vectorized_cost_and_bound_parity():
    rng = np.random.default_rng(1)
    ms = [1, 2, 4, 8, 16]
    costs = rng.uniform(1.0, 100.0, size=(6, len(ms)))
    np.testing.assert_allclose(
        stats.cost_per_worker(costs, ms, True), costs / np.asarray(ms))
    np.testing.assert_allclose(
        stats.cost_per_worker(costs, ms, False), costs)
    gg = stats.gain_growth(costs)
    for row_gg, row_c in zip(gg, costs):
        assert row_gg.tolist() == SC.gain_growth_from_costs(row_c.tolist())
        assert stats.measured_upper_bound(ms[:-1], row_gg) == \
            SC.measured_upper_bound(ms[:-1], row_gg.tolist())


def test_seed_curves_single_seed_fallback():
    job = {"losses": [[0.9, 0.5], [0.8, 0.4]]}
    arr = stats.seed_curves(job)
    assert arr.shape == (1, 2, 2)
    seeded = {"losses": [[0.9, 0.5]],
              "losses_seeds": [[[0.9, 0.5], [0.7, 0.3]]]}
    arr = stats.seed_curves(seeded)
    assert arr.shape == (2, 1, 2)
    assert arr[1, 0].tolist() == [0.7, 0.3]


def _fake_seeded_job(n_seeds=5, ms=(1, 2, 4, 8), n_evals=10, seed=0):
    """Synthetic job whose per-seed curves decay like a known cost law
    cost(m) ~ 200/m + 5 + 2 m plus seed noise."""
    rng = np.random.default_rng(seed)
    ms = list(ms)
    curves = np.empty((len(ms), n_seeds, n_evals))
    for i, m in enumerate(ms):
        speed = 1.0 / (200.0 / m + 5.0 + 2.0 * m)
        t = np.arange(1, n_evals + 1)
        for s in range(n_seeds):
            curves[i, s] = np.exp(-8.0 * speed * t) \
                + rng.normal(0, 0.002, n_evals)
    return {"algorithm": "minibatch", "ms": ms, "iters": n_evals * 10,
            "eval_every": 10, "n_seeds": n_seeds,
            "losses": curves[:, 0].tolist(),
            "losses_seeds": curves.tolist()}


def test_epsilon_per_seed_matches_runner_policy():
    from repro.experiments import runner
    from repro.experiments.spec import EpsilonSpec
    job = _fake_seeded_job()
    eps_spec = EpsilonSpec(probe_m=2, frac=0.7)
    eps = stats.epsilon_per_seed(job, probe_m=2, frac=0.7)
    assert eps.shape == (5,)
    # seed 0 reproduces the runner's scalar probe epsilon
    assert eps[0] == pytest.approx(
        runner._epsilon_from_probe(job, eps_spec))


def test_curve_stats_and_bootstrap_determinism():
    job = _fake_seeded_job()
    cs1 = stats.curve_stats(job, rng_seed=3)
    cs2 = stats.curve_stats(job, rng_seed=3)
    assert cs1 == cs2
    mean = np.asarray(cs1["mean"])
    lo, hi = np.asarray(cs1["lo"]), np.asarray(cs1["hi"])
    assert mean.shape == (4, 10)
    assert (lo <= hi).all()
    # CI of the mean brackets the mean itself
    assert (lo <= mean + 1e-12).all() and (mean <= hi + 1e-12).all()


def test_mmax_bootstrap_shapes_and_grid_membership():
    job = _fake_seeded_job()
    boot = stats.mmax_bootstrap(job, probe_m=2, frac=0.7)
    assert boot["m_max"] in job["ms"]
    assert boot["lo"] <= boot["median"] <= boot["hi"]
    assert len(boot["per_seed"]) == 5
    assert pytest.approx(sum(boot["distribution"].values())) == 1.0
    assert boot == stats.mmax_bootstrap(job, probe_m=2, frac=0.7)


# ---------------------------------------------------------------------------
# fit: vectorized predictors pinned to the while-loop oracles
# ---------------------------------------------------------------------------

def _sync_loop(sigma, cost, m_cap=4096):
    """The legacy while-loop (verbatim `SC.predict_sync_mmax` semantics)."""
    m = 1
    while m < m_cap and SC.predict_sync_gain_growth(m, sigma) > cost:
        m += 1
    return m


def _dadm_loop(div, cost, m_cap=4096):
    m = 1
    while m < m_cap and div * (1.0 / m - 1.0 / (m + 1)) > cost:
        m += 1
    return m


def test_sync_mmax_matches_loop_oracle():
    for sigma in (0.0, 0.01, 0.2, 1.0, 5.0, 40.0, 1e4):
        for cost in (1e-3, 1e-2, 0.5):
            assert fit.sync_mmax(sigma, cost) == _sync_loop(sigma, cost), \
                (sigma, cost)


def test_dadm_mmax_matches_loop_oracle():
    for div in (0.0, 0.05, 0.3, 1.0):
        for cost in (1e-3, 1e-2):
            assert fit.dadm_mmax(div, cost) == _dadm_loop(div, cost)


@pytest.mark.parametrize("maker,kw", [
    (synth.make_higgs_like, {"n": 300, "d": 28}),
    (synth.make_realsim_like, {"n": 300, "d": 200, "density": 0.05}),
    (synth.make_upper_bound_dataset, {"n": 300, "d": 100, "density": 0.7}),
])
def test_dataset_predictors_match_scalability_oracles(maker, kw):
    X = maker(KEY, **kw).X
    assert fit.predict_hogwild_mmax(X) == SC.predict_hogwild_mmax(X)
    assert fit.predict_sync_mmax(X) == SC.predict_sync_mmax(X)
    assert fit.predict_dadm_mmax(X) == SC.predict_dadm_mmax(X)


def test_advisor_uses_vectorized_search_same_answers():
    """The advisor's predicted m_max must equal the legacy while-loop's
    answer (regression pin for the vectorized argmin)."""
    adv = ScalabilityAdvisor()
    g1 = {"w": jnp.array([0.0, 1.0, 0.0, 0.0])}
    g2 = {"w": jnp.array([0.0, 0.9, 0.0, 0.0])}
    rep = adv.from_grads([g1, g2])
    sigma = rep["grad_noise_scale"] ** 0.5
    m = 1
    while m < 4096 and SC.predict_sync_gain_growth(m, sigma) > \
            adv.parallel_cost:
        m += 1
    assert rep["predicted_m_max_sync"] == m
    X = synth.make_higgs_like(KEY, n=200, d=16).X
    ds_rep = adv.from_dataset(X, tau_max=4, batch_size=4)
    assert ds_rep["sync"] == SC.predict_sync_mmax(X)
    assert ds_rep["hogwild"] == SC.predict_hogwild_mmax(X)
    assert ds_rep["dadm"] == SC.predict_dadm_mmax(X)


# ---------------------------------------------------------------------------
# fit: the Thm-2 cost law
# ---------------------------------------------------------------------------

def test_fit_cost_curve_recovers_known_law():
    ms = [1, 2, 4, 8, 16, 32]
    A, B, C = 200.0, 5.0, 2.0
    costs = [A / m + B + C * m for m in ms]
    out = fit.fit_cost_curve(ms, costs)
    assert out["A"] == pytest.approx(A, rel=1e-6)
    assert out["B"] == pytest.approx(B, rel=1e-5, abs=1e-5)
    assert out["C"] == pytest.approx(C, rel=1e-6)
    assert out["r2"] == pytest.approx(1.0)
    assert out["m_star"] == pytest.approx(math.sqrt(A / C))
    # paper parameterization t/m = (1/m + a + b m) c
    assert out["c"] == pytest.approx(A)
    assert out["a"] == pytest.approx(B / A)
    assert out["b"] == pytest.approx(C / A)
    # fitted_m_max: largest m still beating the fitted 1-worker cost,
    # same contiguous-scan semantics as the theory-side predictors
    # (scan with the *fitted* coefficients: the true ones put m=100 on an
    # exact cost(m) == cost(1) tie, where lstsq epsilon decides the side)
    fA, fB, fC = out["A"], out["B"], out["C"]
    c1 = fA + fB + fC
    m, m_max = 2, 1
    while m <= fit.M_CAP and fA / m + fB + fC * m < c1:
        m_max, m = m, m + 1
    assert out["fitted_m_max"] == m_max
    assert out["fitted_m_max"] in (99, 100)   # the analytic neighborhood


def test_fit_cost_curve_monotone_decreasing_is_uncapped():
    ms = [1, 2, 4, 8]
    out = fit.fit_cost_curve(ms, [100.0 / m for m in ms])
    assert out["fitted_m_max"] == fit.M_CAP
    assert out["m_star"] == math.inf


def test_fit_job_bootstrap_brackets_point_fit():
    job = _fake_seeded_job()
    out = fit.fit_job(job, probe_m=2, frac=0.7)
    assert out["fitted_m_max_lo"] <= out["fitted_m_max_hi"]
    assert out["n_seeds"] == 5
    assert out == fit.fit_job(job, probe_m=2, frac=0.7)  # deterministic


# ---------------------------------------------------------------------------
# characters -> m_max regression
# ---------------------------------------------------------------------------

def test_characters_regression_recovers_planted_signs():
    rng = np.random.default_rng(0)
    points = []
    for _ in range(40):
        var = 10.0 ** rng.uniform(-1, 1)
        sp = rng.uniform(0.0, 0.9)
        div = rng.uniform(0.1, 1.0)
        log2_m = 1.0 + 0.8 * math.log10(var) - 1.5 * sp + 2.0 * div \
            + rng.normal(0, 0.05)
        points.append({"characters": {"mean_feature_variance": var,
                                      "sparsity": sp,
                                      "diversity_ratio": div},
                       "m_max": max(1, round(2.0 ** log2_m))})
    reg = fit.characters_regression(points)
    assert reg["r2"] > 0.8
    assert reg["coef"]["log10_variance"] > 0
    assert reg["coef"]["sparsity"] < 0
    assert reg["coef"]["diversity_ratio"] > 0
    assert fit.characters_regression(points[:3]) is None  # too few


def test_collect_character_points_prefers_bootstrap_for_seeded_jobs():
    job = _fake_seeded_job()
    job.update(dataset="d0", measured_m_max=job["ms"][0], epsilon=0.5)
    result = {"name": "t", "spec": {"epsilon": {"probe_m": 2, "frac": 0.7}},
              "datasets": {"d0": {"characters": {
                  "mean_feature_variance": 1.0, "sparsity": 0.1,
                  "diversity_ratio": 1.0}}},
              "jobs": {"minibatch/d0": job}}
    pts = fit.collect_character_points([result])
    assert len(pts) == 1
    boot = stats.mmax_bootstrap(job, probe_m=2, frac=0.7)
    assert pts[0]["m_max"] == boot["m_max"]


# ---------------------------------------------------------------------------
# report CLI end to end (tiny scale; the acceptance path)
# ---------------------------------------------------------------------------

def test_report_cli_quick(tmp_path, capsys):
    from repro.analysis import report
    out = tmp_path / "report.md"
    rc = report.main(["--quick", "--iters", "40", "--n", "120",
                      "--seeds", "2", "--cache-dir", str(tmp_path / "cache"),
                      "--out", str(out)])
    assert rc == 0
    md = out.read_text()
    # section 1: bootstrap-CI Table II
    assert "Table II" in md
    assert "measured m_max [CI]" in md
    assert "hogwild/ub" in md and "minibatch/dense" in md
    # curves with error bars: sparklines + inline SVG band
    assert "&#177;" in md
    assert "<svg" in md and "bootstrap CI" in md
    # section 2: fitted-vs-predicted from the character_surface spec
    assert "character_surface" in md
    assert "fitted m_max [CI]" in md and "predicted" in md
    # section 3: the regression across cached sweeps
    assert "m_max regression" in md
    assert "log10_variance" in md
    stdout = capsys.readouterr().out
    assert "wrote" in stdout
    # re-render is pure formatting: both sweeps come from the cache
    rc = report.main(["--quick", "--iters", "40", "--n", "120",
                      "--seeds", "2", "--cache-dir", str(tmp_path / "cache"),
                      "--out", str(out)])
    assert rc == 0
    assert "(cache)" in capsys.readouterr().out
