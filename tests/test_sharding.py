"""Sharding rules: spec construction, divisibility fitting, and a small
real-mesh train/serve step in a subprocess (8 virtual devices)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_arch
from repro.launch import specs as S
from repro.launch.hlo_stats import collective_stats, total_collective_bytes
from repro.distributed.rules import fit_spec, _leaf_spec, data_axes


class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.zeros(shape)

    @property
    def shape(self):
        return dict(zip(self.axis_names, self.devices.shape))


MESH = _FakeMesh((16, 16), ("data", "model"))


def test_fit_spec_drops_nondivisible_axes():
    assert fit_spec(P("model", "data"), (51865, 768), MESH) == P(None, "data")
    assert fit_spec(P("model", "data"), (51872, 768), MESH) == P("model", "data")
    assert fit_spec(P(None, "model"), (4, 4), MESH) == P(None, None)


def test_param_specs_structure():
    from repro.distributed import param_specs
    cfg = get_arch("qwen2.5-3b")
    shapes = S.param_shapes(cfg)
    specs = param_specs(shapes, MESH)
    # stacked segment weight: leading layer dim never sharded
    seg = specs["segments"][0]
    assert seg["attn"]["wq"][0] is None
    assert "model" in seg["attn"]["wq"]
    assert specs["embed"]["table"] == P("model", "data")


def test_leaf_spec_moe_expert_parallel():
    import jax.tree_util as jtu
    cfg = get_arch("arctic-480b")
    shapes = S.param_shapes(cfg)
    flat = jtu.tree_flatten_with_path(shapes)[0]
    # the expert bank is the 4D (L, E, d, ff) leaf (dense_residual is 3D)
    moe_wi = [x for p, x in flat
              if "moe" in str(p) and str(p).endswith(
                  "DictKey(key='wi_gate'))") and x.ndim == 4][0]
    spec = _leaf_spec(
        [jtu.DictKey("segments"), jtu.SequenceKey(0), jtu.DictKey("moe"),
         jtu.DictKey("wi_gate")], moe_wi, "data")
    assert spec == P(None, "model", "data", None)   # (L, E, d, ff)


def test_hlo_stats_parser():
    hlo = textwrap.dedent("""
      %ag = bf16[16,1024] all-gather(%x), replica_groups=[2,2]
      %ar.1 = (f32[8,8], f32[4]) all-reduce(%y, %z), channel_id=1
      %cp = f32[128] collective-permute(%w)
      %ar.s = f32[8] all-reduce-start(%q)
      %ar.d = f32[8] all-reduce-done(%ar.s)
    """)
    st = collective_stats(hlo)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 1024 * 2
    assert st["all-reduce"]["count"] == 2      # tuple one + start (not done)
    assert st["all-reduce"]["bytes"] == 8 * 8 * 4 + 4 * 4 + 8 * 4
    assert total_collective_bytes(hlo) > 0


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs.registry import get_arch
    from repro.launch.dryrun import lower_pair
    from repro.distributed import make_debug_mesh
    import dataclasses
    mesh = make_debug_mesh(data=2, model=2, pod=2)
    # reduced config through the REAL dryrun path on a tiny mesh
    import repro.launch.dryrun as DR
    import repro.configs.registry as REG
    cfg = get_arch("qwen2.5-3b").reduced()
    orig = DR.arch_for_pair
    DR.arch_for_pair = lambda a, s: cfg
    from repro.configs.base import INPUT_SHAPES, InputShape
    INPUT_SHAPES["tiny_train"] = InputShape("tiny_train", 64, 8, "train")
    INPUT_SHAPES["tiny_decode"] = InputShape("tiny_decode", 64, 8, "decode")
    for shape in ("tiny_train", "tiny_decode"):
        lowered, meta = lower_pair("qwen2.5-3b", shape, mesh, microbatches=2)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        print(shape, "OK", int(compiled.memory_analysis().temp_size_in_bytes))
""")


@pytest.mark.slow
def test_real_mesh_lowering_subprocess():
    """Multi-pod (2,2,2) debug mesh: lower+compile train & decode steps."""
    r = subprocess.run([sys.executable, "-c", SUBPROC], cwd=".",
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tiny_train OK" in r.stdout and "tiny_decode OK" in r.stdout


@pytest.mark.slow
@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure (ISSUE 2): the gossip ECD-PSGD "
           "example subprocess exits nonzero on this container")
def test_gossip_strategy_subprocess():
    """ECD-PSGD gossip step descends on a real (4 data x 2 model) mesh."""
    r = subprocess.run([sys.executable, "examples/gossip_ecd_psgd.py"],
                       cwd=".", capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
