"""Fused dataset-characters pipeline vs the retained pure-jnp oracles.

The §IV hot paths (`csim`, `ls_sync`, `batch_internal_similarity`) were
rewritten as single jitted `lax.scan` pipelines that can route the per-row
L0 count through the Pallas kernels (interpret mode off-TPU) or plain jnp.
Every fused route must agree with its Python-loop/broadcast oracle on
dense, sparse, and duplicate-row datasets — L0 counts are integers, so
agreement is essentially exact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as MX
from repro.data import synth

KEY = jax.random.PRNGKey(0)


def _datasets():
    dense = jax.random.normal(KEY, (64, 33))
    sparse = synth.make_realsim_like(KEY, n=80, d=50, density=0.05).X
    dup = jnp.tile(dense[:4], (16, 1))      # 16 copies of 4 distinct rows
    return {"dense": dense, "sparse": sparse, "duplicates": dup}


DATASETS = _datasets()


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jnp"])
@pytest.mark.parametrize("name", sorted(DATASETS))
def test_csim_fused_matches_ref(name, use_kernel):
    X = DATASETS[name]
    for rng in (1, 4, 9):
        got = MX.csim(X, rng, use_kernel=use_kernel)
        want = MX.csim_ref(X, rng)
        np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jnp"])
@pytest.mark.parametrize("name", sorted(DATASETS))
def test_batch_internal_similarity_fused_matches_ref(name, use_kernel):
    X = DATASETS[name]
    for b in (2, 7, 16):
        got = MX.batch_internal_similarity(X[:b], use_kernel=use_kernel)
        want = MX.batch_internal_similarity_ref(X[:b])
        np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("use_kernel", [True, False],
                         ids=["pallas", "jnp"])
@pytest.mark.parametrize("name", sorted(DATASETS))
def test_ls_sync_fused_matches_ref(name, use_kernel):
    X = DATASETS[name]
    for batch_size in (4, 8, 11):           # 11: trailing rows dropped
        got = MX.ls_sync(X, batch_size, use_kernel=use_kernel)
        want = MX.ls_sync_ref(X, batch_size)
        np.testing.assert_allclose(got, want, rtol=1e-6)


def test_singleton_batch_is_zero():
    """b == 1 has no pairs; both paths define the similarity as 0."""
    X = DATASETS["dense"]
    assert MX.batch_internal_similarity(X[:1]) == 0.0
    assert MX.batch_internal_similarity_ref(X[:1]) == 0.0


def test_tolerance_threads_through():
    """Coordinates differing by <= tol are not counted on any route."""
    Xb = jnp.array([[0.0, 0.0, 0.0], [0.05, 0.5, 0.0]], jnp.float32)
    for use_kernel in (True, False):
        assert MX.batch_internal_similarity(
            Xb, tol=0.1, use_kernel=use_kernel) == pytest.approx(1.0)
        assert MX.csim(Xb, 1, tol=0.1,
                       use_kernel=use_kernel) == pytest.approx(1.0)


def test_ls_async_routes_through_fused_csim():
    X = DATASETS["sparse"]
    assert MX.ls_async(X, 4) == pytest.approx(MX.csim_ref(X, 4), rel=1e-6)


def test_summarize_uses_fused_paths():
    """summarize must stay consistent with the oracle definitions."""
    X = DATASETS["duplicates"]
    s = MX.summarize(X, tau_max=3, batch_size=8)
    assert s["csim_async"] == pytest.approx(MX.csim_ref(X, 3), rel=1e-6)
    assert s["csim_sync"] == pytest.approx(MX.ls_sync_ref(X, 8), rel=1e-6)
    assert s["diversity"] == 4
