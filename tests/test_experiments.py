"""repro.experiments: spec registry round-trip, vmapped-vs-sequential sweep
equivalence, artifact cache hit/miss behavior, and a CLI smoke run."""

import json

import jax
import numpy as np
import pytest

from repro.data import synth
from repro.experiments import (SPEC_IDS, DatasetSpec, EpsilonSpec, JobSpec,
                               SweepSpec, curves_by_m, fingerprint, get_spec,
                               run_sweep)
from repro.experiments import engine
from repro.experiments import run as cli
from repro.core.algorithms import (run_dadm, run_ecd_psgd, run_hogwild,
                                   run_minibatch)

KEY = jax.random.PRNGKey(0)


def tiny_spec(name="tiny", algorithms=("minibatch",), ms=(1, 2, 4),
              epsilon=None, iters=60):
    return SweepSpec(
        name=name, description="test spec", ms=ms, iters=iters, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 120, "d": 8})},
        jobs=tuple(JobSpec(a, "d0") for a in algorithms),
        epsilon=epsilon).validate()


# ---------------------------------------------------------------------------
# spec registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SPEC_IDS)
def test_registry_roundtrip(name):
    """Every registered spec survives dict/JSON round-trip bit-exactly."""
    spec = get_spec(name, quick=True)
    clone = SweepSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert clone == spec
    assert fingerprint(clone) == fingerprint(spec)


def test_fingerprint_tracks_content():
    assert fingerprint(get_spec("ls", quick=True)) != \
        fingerprint(get_spec("ls", quick=False))
    assert fingerprint(tiny_spec(iters=60)) != fingerprint(tiny_spec(iters=80))


def test_spec_validation_rejects_bad_specs():
    with pytest.raises(KeyError):
        get_spec("nope")
    with pytest.raises(ValueError):
        tiny_spec(ms=(1, 2, 2))
    with pytest.raises(KeyError):
        SweepSpec(name="x", ms=(1,), iters=40, eval_every=20,
                  datasets={}, jobs=(JobSpec("minibatch", "ghost"),)
                  ).validate()
    with pytest.raises(ValueError):   # epsilon probe_m must be on the grid
        tiny_spec(epsilon=EpsilonSpec(probe_m=3))
    with pytest.raises(ValueError):   # epsilon frac must be a proper fraction
        tiny_spec(epsilon=EpsilonSpec(probe_m=2, frac=1.0))


# ---------------------------------------------------------------------------
# engine: the vmapped grid is the sequential loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sweeper", [engine.sweep_minibatch,
                                     engine.sweep_ecd_psgd,
                                     engine.sweep_dadm,
                                     engine.sweep_hogwild])
def test_vmapped_equals_sequential(sweeper):
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=60, eval_every=20)
    v = sweeper(tr, te, [1, 2, 4], use_vmap=True, **kw)
    s = sweeper(tr, te, [1, 2, 4], use_vmap=False, **kw)
    assert v["ms"] == s["ms"] == [1, 2, 4]
    np.testing.assert_allclose(v["losses"], s["losses"],
                               rtol=2e-4, atol=2e-5)
    assert np.isfinite(v["losses"]).all()


def test_hogwild_sweep_matches_single_runs():
    """The vmapped one-trace Hogwild! grid reproduces the legacy per-m
    runner (the original staleness recurrence with m static) within 1e-5
    for every m of the default grid — the acceptance bar for folding
    Hogwild! into the vmapped engine."""
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    tr, te = ds.split(key=KEY)
    ms = [1, 2, 4, 8]
    sw = engine.sweep_hogwild(tr, te, ms, iters=80, eval_every=20,
                              use_vmap=True)
    for m, curve in curves_by_m(sw).items():
        r = run_hogwild(tr, te, m=m, iters=80, eval_every=20)
        np.testing.assert_allclose(curve, r["losses"], rtol=1e-5)


def test_buckets_partition_properties():
    """_buckets covers every grid position once and bounds pad waste at
    MAX_PAD_RATIO x the smallest member of each bucket."""
    for ms in ([1, 2, 4, 8, 16, 32, 64], [1, 4, 16], [8, 1, 4, 2], [7],
               [3, 5, 6, 12, 13]):
        buckets = engine._buckets(ms)
        seen = sorted(i for pos, _ in buckets for i in pos)
        assert seen == list(range(len(ms)))
        for pos, m_pad in buckets:
            members = [ms[i] for i in pos]
            assert m_pad == max(members)
            assert max(members) <= engine.MAX_PAD_RATIO * min(members)


@pytest.mark.parametrize("sweeper", [engine.sweep_minibatch,
                                     engine.sweep_ecd_psgd,
                                     engine.sweep_dadm])
def test_bucketed_equals_flat(sweeper):
    """Bucketed padding must not change numerics: draws are made at the
    global m_top and sliced per bucket, so member m's computation is
    identical whichever bucket it lands in."""
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=60, eval_every=20)
    ms = [1, 2, 4, 8]                 # two buckets under MAX_PAD_RATIO=2
    b = sweeper(tr, te, ms, use_vmap=True, bucketed=True, **kw)
    f = sweeper(tr, te, ms, use_vmap=True, bucketed=False, **kw)
    np.testing.assert_allclose(b["losses"], f["losses"],
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("sweeper,legacy,kwname", [
    (engine.sweep_minibatch, run_minibatch, "batch_size"),
    (engine.sweep_ecd_psgd, run_ecd_psgd, "m"),
    (engine.sweep_dadm, run_dadm, "m"),
])
def test_engine_matches_legacy_at_full_m(sweeper, legacy, kwname):
    """At m == m_max the padded grid uses the same index draws as the legacy
    per-m runner (same key, same shapes, all-ones mask), so the sweep's last
    row must reproduce the original algorithm's curve almost exactly."""
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    tr, te = ds.split(key=KEY)
    m_max = 4
    sw = sweeper(tr, te, [1, 2, m_max], iters=60, eval_every=20)
    r = legacy(tr, te, iters=60, eval_every=20, **{kwname: m_max})
    np.testing.assert_allclose(curves_by_m(sw)[m_max], r["losses"],
                               rtol=2e-4, atol=2e-5)


def test_engine_rejects_unknown_algorithm():
    ds = synth.make_higgs_like(KEY, n=64, d=4)
    tr, te = ds.split(key=KEY)
    with pytest.raises(KeyError):
        engine.run_algorithm_sweep("sgd9000", tr, te, [1],
                                   iters=20, eval_every=20)


# ---------------------------------------------------------------------------
# engine: the seed axis (ENGINE_VERSION 4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["minibatch", "ecd_psgd", "dadm",
                                       "hogwild"])
def test_seeded_seed0_matches_single_seed_grid(algorithm):
    """Acceptance: an n_seeds=1 sweep is the ENGINE_VERSION-3 grid, and the
    seed-0 rows of a replicated sweep reproduce it at 1e-5."""
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=60, eval_every=20)
    single = engine.run_algorithm_sweep(algorithm, tr, te, [1, 2, 4], **kw)
    seeded = engine.run_algorithm_sweep(algorithm, tr, te, [1, 2, 4],
                                        n_seeds=3, **kw)
    assert single["n_seeds"] == 1 and "losses_seeds" not in single
    assert seeded["n_seeds"] == 3
    np.testing.assert_allclose(seeded["losses"], single["losses"],
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(
        [row[0] for row in seeded["losses_seeds"]], single["losses"],
        rtol=1e-5, atol=1e-7)


def test_seeded_replicates_match_independent_keyed_runs():
    """Seed s of the vmapped batch must equal a fresh single-seed sweep
    keyed with fold_in(key, s) — replicates are real independent draws,
    and growing n_seeds only appends."""
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=60, eval_every=20)
    seeded = engine.sweep("minibatch", tr, te, [1, 2, 4], n_seeds=3, **kw)
    for s in (1, 2):
        solo = engine.sweep("minibatch", tr, te, [1, 2, 4],
                            key=jax.random.fold_in(KEY, s), **kw)
        np.testing.assert_allclose(
            [row[s] for row in seeded["losses_seeds"]], solo["losses"],
            rtol=2e-4, atol=2e-5)


def test_seeded_grid_compiles_once_per_bucket():
    """Acceptance: n_seeds=8 runs as ONE vmapped trace — the jit count
    equals the bucket count, exactly as for a single seed."""
    ds = synth.make_higgs_like(KEY, n=120, d=8)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=40, eval_every=20)
    ms = [1, 2, 4, 8]                     # 2 buckets under MAX_PAD_RATIO=2
    j0 = engine.JIT_CALLS
    engine.sweep("minibatch", tr, te, ms, n_seeds=1, **kw)
    single = engine.JIT_CALLS - j0
    j0 = engine.JIT_CALLS
    engine.sweep("minibatch", tr, te, ms, n_seeds=8, **kw)
    assert engine.JIT_CALLS - j0 == single == 2
    j0 = engine.JIT_CALLS
    engine.sweep("hogwild", tr, te, ms, n_seeds=8, **kw)   # force_flat
    assert engine.JIT_CALLS - j0 == 1


def test_seeded_sequential_equals_vmapped():
    ds = synth.make_higgs_like(KEY, n=120, d=8)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=40, eval_every=20, n_seeds=3)
    v = engine.sweep("minibatch", tr, te, [1, 2, 4], use_vmap=True, **kw)
    s = engine.sweep("minibatch", tr, te, [1, 2, 4], use_vmap=False, **kw)
    np.testing.assert_allclose(v["losses_seeds"], s["losses_seeds"],
                               rtol=2e-4, atol=2e-5)


def test_spec_n_seeds_validation_and_fingerprint():
    base = tiny_spec()
    import dataclasses
    seeded = dataclasses.replace(base, n_seeds=4).validate()
    assert fingerprint(seeded) != fingerprint(base)   # cache key covers it
    with pytest.raises(ValueError):
        dataclasses.replace(base, n_seeds=0).validate()
    with pytest.raises(ValueError):
        engine.sweep("minibatch", None, None, [1], iters=20, eval_every=20,
                     n_seeds=0)
    # registry-level seeds override
    from repro.experiments import registry
    assert registry.get_spec("upper_bound", quick=True, seeds=5).n_seeds == 5
    # character_surface must measure §IV characters on EVERY row —
    # character_knob tiles duplicates after the unique head, so a capped
    # summary would misreport diversity to the m_max regression
    surf = registry.get_spec("character_surface", quick=True)
    assert surf.characters_rows == \
        surf.datasets[next(iter(surf.datasets))].kwargs["n"]


def test_runner_seeded_result_block(tmp_path):
    import dataclasses
    spec = dataclasses.replace(
        tiny_spec(name="tiny_seeded", algorithms=("minibatch", "hogwild"),
                  epsilon=EpsilonSpec(probe_m=2, frac=0.5)),
        n_seeds=3).validate()
    res = run_sweep(spec, cache_dir=str(tmp_path))
    for jr in res["jobs"].values():
        assert jr["n_seeds"] == 3
        block = np.asarray(jr["losses_seeds"])
        assert block.shape == (len(spec.ms), 3, 60 // 20)
        np.testing.assert_array_equal(block[:, 0], jr["losses"])
        # scalar readouts stay seed-0 / legacy-keyed
        assert jr["measured_m_max"] in spec.ms
    # the artifact round-trips the seed block through the cache
    hit = run_sweep(spec, cache_dir=str(tmp_path))
    assert hit["cache"]["hit"] is True
    assert hit["jobs"]["minibatch/d0"]["losses_seeds"] == \
        res["jobs"]["minibatch/d0"]["losses_seeds"]


# ---------------------------------------------------------------------------
# runner: epsilon/cost readout, predictions, caching
# ---------------------------------------------------------------------------

def test_epsilon_probe_clamps_to_last_eval():
    """Regression (ISSUE 2): frac == 1.0 used to index one past the end of
    the probe curve; the readout must clamp to the final eval instead."""
    from repro.experiments import runner
    job_result = {"ms": [2], "losses": [[0.9, 0.5, 0.3]]}
    eps = runner._epsilon_from_probe(job_result, EpsilonSpec(probe_m=2,
                                                             frac=1.0))
    assert eps == pytest.approx(0.3)
    # interior fractions are unchanged by the clamp
    eps = runner._epsilon_from_probe(job_result, EpsilonSpec(probe_m=2,
                                                             frac=0.5))
    assert eps == pytest.approx(0.5)


def test_runner_epsilon_cost_readout(tmp_path):
    spec = tiny_spec(algorithms=("minibatch", "hogwild"),
                     epsilon=EpsilonSpec(probe_m=2, frac=0.5))
    res = run_sweep(spec, cache_dir=str(tmp_path))
    for jr in res["jobs"].values():
        assert len(jr["costs"]) == len(spec.ms)
        assert len(jr["gain_growth"]) == len(spec.ms) - 1
        assert jr["measured_m_max"] in spec.ms
        assert np.isfinite(jr["epsilon"])


def test_runner_predictions():
    spec = SweepSpec(
        name="tiny_pred", ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("realsim_like",
                                    {"n": 100, "d": 40, "density": 0.1})},
        jobs=(JobSpec("hogwild", "d0", predict=True, predict_rows=80),)
    ).validate()
    res = run_sweep(spec, use_cache=False)
    pred = res["jobs"]["hogwild/d0"]["predicted"]
    assert pred["predicted_m_max"] >= 1
    assert res["cache"] == {"hit": False, "path": None}


def test_cache_hit_miss_and_force(tmp_path):
    spec = tiny_spec(name="tiny_cache")
    r1 = run_sweep(spec, cache_dir=str(tmp_path))
    assert r1["cache"]["hit"] is False
    r2 = run_sweep(spec, cache_dir=str(tmp_path))
    assert r2["cache"]["hit"] is True
    assert r2["jobs"]["minibatch/d0"]["losses"] == \
        r1["jobs"]["minibatch/d0"]["losses"]
    # content change -> different artifact -> miss
    r3 = run_sweep(tiny_spec(name="tiny_cache", iters=80),
                   cache_dir=str(tmp_path))
    assert r3["cache"]["hit"] is False
    # force recomputes even though the artifact exists
    r4 = run_sweep(spec, cache_dir=str(tmp_path), force=True)
    assert r4["cache"]["hit"] is False


def test_cache_artifact_is_json(tmp_path):
    spec = tiny_spec(name="tiny_json")
    r = run_sweep(spec, cache_dir=str(tmp_path))
    with open(r["cache"]["path"]) as f:
        payload = json.load(f)
    assert payload["fingerprint"] == fingerprint(spec)
    # JSON normalizes tuples to lists; the round-trip must still parse back
    assert SweepSpec.from_dict(payload["spec"]) == spec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_list(capsys):
    assert cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in SPEC_IDS:
        assert name in out


@pytest.mark.slow
def test_cli_smoke_quick(tmp_path, capsys):
    rc = cli.main(["--spec", "variance_sparsity", "--quick",
                   "--iters", "40", "--n", "120",
                   "--cache-dir", str(tmp_path),
                   "--json", str(tmp_path / "out.json")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "sweep variance_sparsity" in out
    assert "final loss" in out
    payload = json.loads((tmp_path / "out.json").read_text())
    assert set(payload["jobs"]) == {
        f"{a}/{d}" for d in ("higgs_like", "realsim_like")
        for a in ("minibatch", "ecd_psgd", "hogwild")}


# ---------------------------------------------------------------------------
# cache size cap (LRU) + single-flight dedup
# ---------------------------------------------------------------------------

def test_cache_cap_evicts_lru_and_warns_once(tmp_path):
    """The cap keeps the most-recently-USED artifacts (load bumps
    recency), evicts the rest, and warns exactly once per process."""
    import os
    import time
    import warnings
    from repro.experiments import cache as C

    cache_dir = str(tmp_path)
    for i in range(3):
        C.store(cache_dir, f"s{i}", f"fp{i:016d}", {"v": i})
        os.utime(C.artifact_path(cache_dir, f"s{i}", f"fp{i:016d}"),
                 (time.time() - 100 + i, time.time() - 100 + i))
    # touch s0: now s1 is the least recently used
    assert C.load(cache_dir, "s0", "fp" + "0" * 14 + "00") is not None

    C._EVICTION_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        C.store(cache_dir, "s3", "fp" + "0" * 13 + "003", {"v": 3},
                max_artifacts=3)
        first = [x for x in w if issubclass(x.category, RuntimeWarning)]
        assert len(first) == 1 and "cap" in str(first[0].message)
    assert len(C.list_artifacts(cache_dir)) == 3
    assert C.load(cache_dir, "s1", "fp" + "0" * 14 + "01") is None   # evicted
    assert C.load(cache_dir, "s0", "fp" + "0" * 14 + "00") is not None

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        C.store(cache_dir, "s4", "fp" + "0" * 13 + "004", {"v": 4},
                max_artifacts=3)
        assert not [x for x in w if issubclass(x.category, RuntimeWarning)]


def test_evicted_artifact_recomputes_byte_identical(tmp_path):
    """An evicted sweep that gets requested again recomputes into the
    SAME bytes (content-addressed determinism), checksum verified."""
    spec = tiny_spec(name="lru-refetch", epsilon=EpsilonSpec(probe_m=2))
    run_sweep(spec, cache_dir=str(tmp_path))
    from repro.experiments import cache as C
    from repro.experiments.spec import fingerprint as fp_fn
    path = C.artifact_path(str(tmp_path), spec.name, fp_fn(spec))
    first = open(path, "rb").read()
    C.enforce_cap(str(tmp_path), 0)                # evict everything
    assert C.list_artifacts(str(tmp_path)) == []
    result = run_sweep(spec, cache_dir=str(tmp_path))
    assert result["cache"]["hit"] is False         # really recomputed
    assert open(path, "rb").read() == first        # byte-identical
    assert C.load(str(tmp_path), spec.name, fp_fn(spec)) is not None


def test_inflight_table_single_leader():
    import threading
    from repro.experiments.cache import InFlightTable

    table = InFlightTable()
    grants = []
    start = threading.Barrier(8)

    def race():
        start.wait()
        grants.append(table.lease("fp-x"))

    ts = [threading.Thread(target=race) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert sum(grants) == 1                        # exactly one leader
    assert table.n_inflight == 1
    table.release("fp-x")
    assert table.n_inflight == 0
    assert table.wait("fp-x", timeout=0.01)        # nothing in flight
    assert table.lease("fp-x")                     # leasable again
    table.release("fp-x")


def test_run_sweep_dedup_concurrent_single_compute(tmp_path):
    """N concurrent run_sweep(dedup=True) calls on one fingerprint:
    exactly one compute; every caller gets the same computational
    payload."""
    import threading
    from repro.experiments import cache as C
    from repro.experiments import runner as R

    spec = tiny_spec(name="dedup-conc", iters=40)
    results = []
    lock = threading.Lock()

    def go():
        r = run_sweep(spec, cache_dir=str(tmp_path), dedup=True)
        with lock:
            results.append(r)

    before = R.SWEEP_COMPUTES
    ts = [threading.Thread(target=go) for _ in range(5)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert R.SWEEP_COMPUTES - before == 1
    assert sorted(r["cache"]["hit"] for r in results) == \
        [False, True, True, True, True]
    payloads = set()
    for r in results:
        body = {k: v for k, v in r.items()
                if k not in C.VOLATILE_KEYS + ("fingerprint", "checksum")}
        payloads.add(json.dumps(body, sort_keys=True, default=float))
    assert len(payloads) == 1
