"""Fallback shim for `hypothesis` on bare environments.

Test modules do ``from _hypothesis_compat import given, settings, st``.
When hypothesis is installed this re-exports the real thing; otherwise
``@given`` degrades to a ``pytest.mark.parametrize`` over a small
deterministic sample of each strategy, so property tests still run (with
reduced coverage) instead of killing collection for the whole suite.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import itertools

    import pytest

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(dict.fromkeys([lo, (lo + hi) // 2, hi]))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(dict.fromkeys([lo, (lo + hi) / 2.0, hi]))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

    st = _St()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            combos = list(itertools.product(*(s.samples for s in strats)))
            if len(combos) > 10:          # keep the fallback cheap
                combos = combos[::max(1, len(combos) // 10)][:10]

            @pytest.mark.parametrize("_hyp_args", combos)
            def wrapper(_hyp_args):
                fn(*_hyp_args)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
