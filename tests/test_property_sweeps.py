"""Property-based sweep invariants over randomly-shaped small SweepSpecs.

Each generated example builds ONE spec carrying a job for EVERY registered
algorithm (so a future registration is covered with zero edits here) with
a randomly drawn worker grid, seed-replicate count, and iteration budget,
then asserts the two engine-wide contracts the rest of the repo leans on:

  * **cache roundtrip** — a fresh `run_sweep` followed by a second call is
    a disk hit with byte-identical curves, and the persisted artifact
    carries no volatile per-run keys;
  * **mesh invariance** — recomputing the same spec under an explicit
    1-device mesh (`resolve`'s sharded entry path, vs the ``mesh=None``
    unsharded default) reproduces every curve bit-exactly, so the mesh
    can never split the cache.

Strategies come through `tests/_hypothesis_compat.py`: real hypothesis
when installed, a deterministic parametrize fallback otherwise.
"""

import dataclasses
import json
import os
import tempfile

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.algorithms import base as alg_base
from repro.distributed import mesh as dist_mesh
from repro.experiments import (DatasetSpec, JobSpec, SweepSpec, run_sweep)
from repro.experiments import cache as artifact_cache
from repro.experiments import spec as spec_mod

pytestmark = pytest.mark.slow

GRIDS = ((1, 2), (1, 2, 4), (2, 4, 8))


def _job(algo):
    """Per-algorithm job with a problem-stable step size iff it takes one
    (registry-derived, like the conformance suite's `_alg_kwargs`)."""
    cls = alg_base.ALGORITHMS[algo]
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = {"gamma": 0.1 * cls.gamma_scale} if "gamma" in fields else {}
    return JobSpec(algo, "d0", kw)


def _spec(grid_id, n_seeds, iters):
    return SweepSpec(
        name=f"prop_g{grid_id}_s{n_seeds}_i{iters}",
        ms=GRIDS[grid_id], iters=iters, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 120, "d": 8})},
        jobs=tuple(_job(a) for a in sorted(alg_base.ALGORITHMS)),
        n_seeds=n_seeds).validate()


@settings(max_examples=6, deadline=None)
@given(st.integers(0, len(GRIDS) - 1), st.integers(1, 2),
       st.sampled_from((40, 60)))
def test_random_spec_cache_roundtrip_and_mesh_invariance(
        grid_id, n_seeds, iters):
    spec = _spec(grid_id, n_seeds, iters)
    with tempfile.TemporaryDirectory() as td:
        fresh = run_sweep(spec, cache_dir=td)
        assert fresh["cache"]["hit"] is False

        # roundtrip: second call is a pure disk read, curves identical
        hit = run_sweep(spec, cache_dir=td)
        assert hit["cache"]["hit"] is True
        for key, jr in fresh["jobs"].items():
            np.testing.assert_array_equal(
                np.asarray(jr["losses"]), np.asarray(hit["jobs"][key]["losses"]))

        # the artifact on disk is execution-clean
        path = artifact_cache.artifact_path(td, spec.name,
                                            spec_mod.fingerprint(spec))
        assert os.path.exists(path)
        with open(path) as f:
            stored = json.load(f)
        for volatile in artifact_cache.VOLATILE_KEYS:
            assert volatile not in stored

        # mesh invariance: an explicit 1-device mesh recomputes the same
        # bytes the unsharded default produced
        meshed = run_sweep(spec, cache_dir=td, force=True,
                           mesh=dist_mesh.get_mesh(1))
        assert meshed["cache"]["hit"] is False
        for key, jr in fresh["jobs"].items():
            np.testing.assert_array_equal(
                np.asarray(jr.get("losses_seeds", jr["losses"])),
                np.asarray(meshed["jobs"][key].get("losses_seeds",
                                                   meshed["jobs"][key]["losses"])))
