"""repro.service: batched-vs-sequential character parity, tier gating,
single-flight escalation dedup, admission overflow, and the CLI."""

import json
import threading

import numpy as np
import pytest

from repro.core.advisor import ScalabilityAdvisor
from repro.experiments import runner as runner_mod
from repro.experiments.spec import DatasetSpec
from repro.service.api import AdvisorService, ProbeRequest
from repro.service.batcher import ProbeBatcher
from repro.service.queue import AdmissionQueue
from repro.service.tiers import TierRouter
from repro.service import __main__ as cli

RNG = np.random.default_rng(7)


def make_service(tmp_path, **kw):
    kw.setdefault("cache_dir", str(tmp_path / "cache"))
    kw.setdefault("sweep_iters", 50)
    kw.setdefault("sweep_eval_every", 10)
    kw.setdefault("n_slots", 4)
    return AdvisorService(**kw)


def small_ds(n=64, d=8, seed=0):
    return DatasetSpec("higgs_like", {"n": n, "d": d}, seed=seed)


# ---------------------------------------------------------------------------
# batched front end == sequential advisor
# ---------------------------------------------------------------------------

def test_batched_characters_match_sequential():
    """N mixed-shape probes through the slot batcher produce the same
    characters and the same integer m_max predictions as N sequential
    `from_dataset` calls."""
    adv = ScalabilityAdvisor()
    Xs = [RNG.normal(size=(30, 5)),
          (RNG.random(size=(44, 9)) > 0.8) * RNG.normal(size=(44, 9)),
          np.repeat(RNG.normal(size=(4, 6)), 10, axis=0)]   # duplicated rows
    batcher = ProbeBatcher(n_slots=2, max_rows=64, max_cols=16)
    measured = batcher.measure(list(enumerate(Xs)))
    for i, X in enumerate(Xs):
        seq = adv.from_dataset(X)
        ch = measured[i]
        for k in ("mean_feature_variance", "sparsity", "density",
                  "omega_frac", "delta", "rho"):
            assert abs(ch[k] - seq[k]) <= 1e-6, (i, k)
        for k in ("n", "d", "diversity"):
            assert ch[k] == seq[k], (i, k)


def test_batched_predictions_match_sequential_exactly():
    """Integer m_max per strategy must be EXACTLY the sequential answer —
    the analytic tier shares the from_characters formulas."""
    adv = ScalabilityAdvisor()
    X = (RNG.random(size=(50, 12)) > 0.7) * RNG.normal(size=(50, 12))
    router = TierRouter(cache_dir="/nonexistent-cache-dir")
    ch = ProbeBatcher(n_slots=1, max_rows=64, max_cols=16).measure(
        [("r", X)])["r"]
    report = router.analytic_dataset_report(ch, {})
    seq = adv.from_dataset(X)
    for strat in ("hogwild", "sync", "dadm", "momentum", "local_sgd",
                  "svrg"):
        assert report[strat]["predicted_m_max"] == \
            seq[strat]["predicted_m_max"], strat


def test_batcher_slot_recycling_beyond_capacity():
    """More probes than slots drain correctly across extra steps."""
    batcher = ProbeBatcher(n_slots=2, max_rows=32, max_cols=8)
    items = [(i, RNG.normal(size=(10 + i, 4))) for i in range(5)]
    out = batcher.measure(items)
    assert set(out) == set(range(5))
    assert all(out[i] is not None for i in range(5))
    assert out[3]["n"] == 13
    assert batcher.stats()["steps"] >= 3          # 5 probes / 2 slots


def test_batcher_oversize_fallback_matches():
    """Probes beyond the slot envelope fall back to the group-envelope
    masked batch and still match the sequential characters."""
    batcher = ProbeBatcher(n_slots=2, max_rows=16, max_cols=4)
    X = RNG.normal(size=(40, 10))                  # exceeds both dims
    out = batcher.measure([("big", X)])
    seq = ScalabilityAdvisor().from_dataset(X)
    assert abs(out["big"]["mean_feature_variance"] -
               seq["mean_feature_variance"]) <= 1e-6
    assert batcher.stats()["fallback"] == 1


# ---------------------------------------------------------------------------
# tier gating
# ---------------------------------------------------------------------------

def test_analytic_tier_answers_without_sweeps(tmp_path):
    """High-confidence probes exit at tier 1: zero sweeps executed."""
    svc = make_service(tmp_path)
    before = runner_mod.SWEEP_COMPUTES
    resp = svc.probe(ProbeRequest(X=RNG.normal(size=(40, 6))))
    assert resp.status == "ok" and resp.tier == "analytic"
    assert resp.confidence == pytest.approx(0.75)  # CONFIDENCE_PRIOR
    assert resp.confidence_detail["source"] == "prior"
    assert resp.escalation is None
    assert runner_mod.SWEEP_COMPUTES == before


def test_low_confidence_escalates_to_measured(tmp_path):
    """A threshold above the prior forces spec-carrying probes into the
    measured tier; the response carries the sweep readout."""
    svc = make_service(tmp_path, confidence_threshold=0.9)
    resp = svc.probe(ProbeRequest(dataset=small_ds()))
    assert resp.tier == "measured"
    assert resp.escalation["measured_m_max"] >= 1
    assert resp.escalation["healthy"]
    assert resp.escalation["status"] == "ok"
    # the measured artifact exists on disk where the response says
    with open(resp.escalation["artifact_path"]) as f:
        art = json.load(f)
    assert art["fingerprint"] == resp.escalation["fingerprint"]


def test_raw_probe_cannot_escalate_gets_note(tmp_path):
    """Raw arrays carry no reproducible identity: forced escalation
    returns the analytic answer plus a structured note, no sweep."""
    svc = make_service(tmp_path)
    before = runner_mod.SWEEP_COMPUTES
    resp = svc.probe(ProbeRequest(X=RNG.normal(size=(30, 4)),
                                  escalate=True))
    assert resp.tier == "analytic"
    assert "escalation unavailable" in resp.note
    assert runner_mod.SWEEP_COMPUTES == before


def test_escalate_false_never_sweeps(tmp_path):
    svc = make_service(tmp_path, confidence_threshold=0.99)
    before = runner_mod.SWEEP_COMPUTES
    resp = svc.probe(ProbeRequest(dataset=small_ds(), escalate=False))
    assert resp.tier == "analytic"
    assert runner_mod.SWEEP_COMPUTES == before


def test_invalid_probes_get_structured_reports(tmp_path):
    svc = make_service(tmp_path)
    for req, frag in [
            (ProbeRequest(X=np.full((4, 3), np.nan)), "non-finite"),
            (ProbeRequest(X=np.zeros((1, 3))), "too small"),
            (ProbeRequest(grads=[]), "empty shard list"),
            (ProbeRequest(grads=[[np.ones(3)]]), "single gradient shard")]:
        resp = svc.probe(req)
        assert resp.status == "invalid"
        assert resp.report["valid"] is False
        assert frag in resp.report["reason"]
        assert resp.report["predicted_m_max_conservative"] == 1


def test_grads_probe_analytic(tmp_path):
    svc = make_service(tmp_path)
    grads = [[RNG.normal(size=(6,))] for _ in range(4)]
    resp = svc.probe(ProbeRequest(grads=grads))
    assert resp.status == "ok" and resp.tier == "analytic"
    seq = ScalabilityAdvisor().from_grads(grads)
    assert resp.report["predicted_m_max_sync"] == \
        seq["predicted_m_max_sync"]
    assert abs(resp.report["grad_variance"] - seq["grad_variance"]) <= 1e-6


# ---------------------------------------------------------------------------
# E2E: concurrent escalations collapse into ONE sweep
# ---------------------------------------------------------------------------

def test_concurrent_shared_fingerprint_runs_one_sweep(tmp_path):
    """The PR's acceptance test: N concurrent probes sharing a SweepSpec
    fingerprint execute exactly one sweep, and every waiter receives the
    identical artifact."""
    svc = make_service(tmp_path)
    ds = small_ds(seed=3)
    before = runner_mod.SWEEP_COMPUTES
    responses = []
    lock = threading.Lock()

    def go():
        r = svc.probe(ProbeRequest(dataset=ds, escalate=True))
        with lock:
            responses.append(r)

    threads = [threading.Thread(target=go) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert len(responses) == 6
    assert all(r.tier == "measured" for r in responses)
    assert runner_mod.SWEEP_COMPUTES - before == 1     # exactly one sweep
    blobs = {json.dumps(r.escalation["artifact"], sort_keys=True,
                        default=float) for r in responses}
    assert len(blobs) == 1                             # identical artifact
    fps = {r.escalation["fingerprint"] for r in responses}
    assert len(fps) == 1


def test_batched_requests_one_sweep_via_cache(tmp_path):
    """probe_batch: identical escalated requests in one batch execute one
    sweep (leader) and the rest are cache hits."""
    svc = make_service(tmp_path)
    ds = small_ds(seed=5)
    before = runner_mod.SWEEP_COMPUTES
    reqs = [ProbeRequest(dataset=ds, escalate=True) for _ in range(3)]
    resp = svc.probe_batch(reqs)
    assert [r.tier for r in resp] == ["measured"] * 3
    assert runner_mod.SWEEP_COMPUTES - before == 1
    assert [r.escalation["cache_hit"] for r in resp] == [False, True, True]


# ---------------------------------------------------------------------------
# admission / overload
# ---------------------------------------------------------------------------

def test_queue_overflow_sheds_with_structured_response(tmp_path):
    """Requests beyond the depth get ``overloaded``; under-capacity
    requests in the same batch are still answered."""
    svc = make_service(tmp_path, queue_depth=2)
    reqs = [ProbeRequest(X=RNG.normal(size=(20, 4))) for _ in range(5)]
    responses = svc.probe_batch(reqs)
    by_status = {}
    for r in responses:
        by_status.setdefault(r.status, []).append(r)
    assert len(by_status["ok"]) == 2
    assert len(by_status["overloaded"]) == 3
    for r in by_status["overloaded"]:
        assert r.tier is None
        assert "admission queue full" in r.note
    for r in by_status["ok"]:
        assert r.report["valid"]
    # slots were released: the next probe is admitted again
    assert svc.probe(ProbeRequest(X=RNG.normal(size=(20, 4)))).status == "ok"
    assert svc.queue.stats()["shed"] == 3


def test_admission_queue_contract():
    q = AdmissionQueue(2)
    assert q.try_admit() and q.try_admit()
    assert not q.try_admit()
    q.release()
    assert q.try_admit()
    assert q.stats()["shed"] == 1
    with pytest.raises(ValueError):
        AdmissionQueue(0)


# ---------------------------------------------------------------------------
# confidence model over measured history
# ---------------------------------------------------------------------------

def test_confidence_moves_from_prior_to_regression(tmp_path):
    """After enough measured sweeps land in the cache, the analytic tier's
    confidence is regression-derived, not the prior."""
    svc = make_service(tmp_path)
    # 6 escalations over distinct datasets = 6 (characters, m_max) points
    for i in range(6):
        svc.probe(ProbeRequest(dataset=small_ds(n=48 + 8 * i, seed=i),
                               escalate=True))
    resp = svc.probe(ProbeRequest(dataset=small_ds(n=56, seed=1),
                                  escalate=False))
    assert resp.confidence_detail["source"] == "regression"
    assert 0.0 <= resp.confidence <= 1.0
    assert resp.confidence_detail["n_points"] >= 6


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_analytic_and_escalated(tmp_path, capsys):
    cache = str(tmp_path / "cli-cache")
    rc = cli.main(["--generator", "higgs_like", "--n", "64", "--d", "8",
                   "--cache-dir", cache, "--sweep-iters", "50"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "tier=analytic" in out
    rc = cli.main(["--generator", "higgs_like", "--n", "64", "--d", "8",
                   "--cache-dir", cache, "--sweep-iters", "50",
                   "--requests", "2", "--escalate", "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0
    tiers = [r["tier"] for r in payload["responses"]]
    assert tiers == ["measured", "measured"]
    assert payload["stats"]["tiers"]["escalations"] >= 2
