"""Protocol-conformance suite (ENGINE_VERSION 3).

Parametrized over every registered `Algorithm` x `Problem` pair: the
generic engine must produce identical curves across its execution modes
(vmapped grid == sequential single-m), states must keep their tree
structure through `step`, spec fingerprints must track the *registries*
(re-registering an entry with different source invalidates the cache), and
a brand-new problem/dataset must reach the full sweep + cache + CLI purely
via registration — zero engine edits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems as problems_mod
from repro.core.algorithms import base as alg_base
from repro.core.algorithms import run_minibatch
from repro.data import synth
from repro.experiments import (DatasetSpec, JobSpec, SweepSpec, fingerprint,
                               run_sweep)
from repro.experiments import engine
from repro.experiments import run as cli

KEY = jax.random.PRNGKey(0)

ALGOS = sorted(alg_base.ALGORITHMS)
PROBS = sorted(problems_mod.PROBLEMS)

#: step sizes that keep every objective stable on the higgs-like features
#: (ridge curvature ~ mean ||xi||^2 needs a much smaller gamma than Eq. 4)
GAMMAS = {"logistic": 0.1, "ridge": 0.01, "hinge": 0.05}


def _alg_kwargs(algo, prob):
    """Per-pair kwargs derived purely from the registry entry: pass the
    problem-stable step size iff the algorithm takes one, scaled by the
    algorithm's declared effective-step amplification (``gamma_scale``) —
    future registrations are covered with zero edits here."""
    cls = alg_base.ALGORITHMS[algo]
    fields = {f.name for f in dataclasses.fields(cls)}
    if "gamma" not in fields:
        return {}
    return {"gamma": GAMMAS[prob] * cls.gamma_scale}


@pytest.fixture(scope="module")
def split():
    ds = synth.make_higgs_like(KEY, n=160, d=10)
    return ds.split(key=KEY)


# ---------------------------------------------------------------------------
# every Algorithm x Problem: one-trace grid == sequential reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prob", PROBS)
@pytest.mark.parametrize("algo", ALGOS)
def test_vmapped_grid_equals_sequential(split, algo, prob):
    tr, te = split
    kw = _alg_kwargs(algo, prob)
    args = dict(iters=60, eval_every=20, problem=prob)
    v = engine.sweep(algo, tr, te, [1, 2, 4], use_vmap=True, **args, **kw)
    s = engine.sweep(algo, tr, te, [1, 2, 4], use_vmap=False, **args, **kw)
    assert v["ms"] == s["ms"] == [1, 2, 4]
    assert v["algorithm"] == algo and v["problem"] == prob
    np.testing.assert_allclose(v["losses"], s["losses"],
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(v["losses"]).all()


@pytest.mark.parametrize("prob", PROBS)
@pytest.mark.parametrize("algo", [a for a in ALGOS
                                  if not alg_base.ALGORITHMS[a].force_flat])
def test_bucketed_equals_flat(split, algo, prob):
    tr, te = split
    kw = _alg_kwargs(algo, prob)
    args = dict(iters=60, eval_every=20, problem=prob)
    ms = [1, 2, 4, 8]                 # two buckets under MAX_PAD_RATIO=2
    b = engine.sweep(algo, tr, te, ms, bucketed=True, **args, **kw)
    f = engine.sweep(algo, tr, te, ms, bucketed=False, **args, **kw)
    np.testing.assert_allclose(b["losses"], f["losses"],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("algo", ALGOS)
def test_state_contract(split, algo):
    """init_state/step keep the state's tree structure and shapes; draws
    carry the iteration axis; readout yields the (d,) model."""
    tr, _ = split
    n, d = tr.X.shape
    alg = alg_base.get_algorithm(algo)()
    prob = problems_mod.get_problem("logistic")()
    iters, m_pad = 8, 4

    draws = alg.make_draws(KEY, n, iters, m_pad)
    for leaf in jax.tree.leaves(draws):
        assert leaf.shape[0] == iters
    sliced = alg.slice_draws(draws, 2)
    for a, b in zip(jax.tree.leaves(sliced), jax.tree.leaves(draws)):
        assert a.ndim == b.ndim

    ctx = alg_base.SimContext(2, m_pad)
    assert ctx.active.shape == (m_pad,)
    assert float(ctx.active.sum()) == 2.0
    state = alg.init_state(prob, tr, ctx)
    batch = jax.tree.map(lambda a: a[0], alg.slice_draws(draws, m_pad))
    new = alg.step(prob, tr, ctx, state, batch, jnp.asarray(0, jnp.int32))
    assert (jax.tree.structure(new) == jax.tree.structure(state))
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(state)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert alg.readout(ctx, new).shape == (d,)


def test_registry_rejects_malformed_entries():
    with pytest.raises(TypeError):
        alg_base.register_algorithm(type("NoName", (alg_base.Algorithm,), {}))
    with pytest.raises(ValueError):
        alg_base.register_algorithm(
            type("BadPred", (alg_base.Algorithm,),
                 {"name": "badpred", "predictor": "astrology"}))
    with pytest.raises(KeyError):
        alg_base.get_algorithm("sgd9000")
    with pytest.raises(KeyError):
        problems_mod.get_problem("l0")
    with pytest.raises(KeyError):
        synth.get_generator("mnist")


# ---------------------------------------------------------------------------
# fingerprints track the registries
# ---------------------------------------------------------------------------

def _tiny_spec(algo="minibatch", **job_kw):
    return SweepSpec(
        name="proto_fp", ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 120, "d": 8})},
        jobs=(JobSpec(algo, "d0", **job_kw),)).validate()


@pytest.mark.parametrize("algo", ALGOS)
def test_fingerprint_tracks_algorithm_registry(algo):
    """Re-registering ANY algorithm with different source must orphan
    exactly the cached sweeps that reference it."""
    spec = _tiny_spec(algo)
    fp0 = fingerprint(spec)
    orig = alg_base.ALGORITHMS[algo]

    class Patched(orig):
        """Same name, different source — must orphan cached sweeps."""

    try:
        alg_base.register_algorithm(Patched)
        assert fingerprint(spec) != fp0
        # other algorithms' specs are untouched by this re-registration
        others = [a for a in ALGOS if a != algo]
        if others:
            fp_other = fingerprint(_tiny_spec(others[0]))
            alg_base.register_algorithm(orig)
            assert fingerprint(_tiny_spec(others[0])) == fp_other
    finally:
        alg_base.register_algorithm(orig)
    assert fingerprint(spec) == fp0


def test_fingerprint_tracks_problem_registry():
    spec = _tiny_spec(problem="ridge")
    fp0 = fingerprint(spec)
    orig = problems_mod.PROBLEMS["ridge"]

    class PatchedRidge(orig):
        """Same name, different source."""

    try:
        problems_mod.register_problem(PatchedRidge)
        assert fingerprint(spec) != fp0
    finally:
        problems_mod.register_problem(orig)
    assert fingerprint(spec) == fp0
    # and the problem field itself is hashed
    assert fingerprint(_tiny_spec()) != fp0


def test_fingerprint_tracks_wrapper_generator_base():
    """A wrapper generator (label_noise) names its base via the `base`
    kwarg; editing the *base* must orphan the wrapper's cached sweeps."""
    spec = SweepSpec(
        name="proto_fp_base", ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("label_noise",
                                    {"base": "higgs_like", "n": 120,
                                     "d": 8})},
        jobs=(JobSpec("minibatch", "d0"),)).validate()
    fp0 = fingerprint(spec)
    orig = synth.GENERATORS["higgs_like"]

    def patched_higgs(key, n=8000, d=28, lo=-4.0, hi=3.0):
        return orig(key, n=n, d=d, lo=lo, hi=hi)

    try:
        synth.register_generator("higgs_like")(patched_higgs)
        assert fingerprint(spec) != fp0
    finally:
        synth.register_generator("higgs_like")(orig)
    assert fingerprint(spec) == fp0


def test_runner_warns_on_divergent_curves(tmp_path):
    """Re-pointing a job at an objective whose curvature the step size
    can't handle must warn, not silently cache NaN readouts."""
    spec = SweepSpec(
        name="proto_diverge", ms=(1, 2), iters=120, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 120, "d": 28})},
        jobs=(JobSpec("minibatch", "d0", {"gamma": 0.1},
                      problem="ridge"),)).validate()
    with pytest.warns(RuntimeWarning, match="non-finite"):
        run_sweep(spec, cache_dir=str(tmp_path))


def test_fingerprint_tracks_generator_registry():
    spec = _tiny_spec()
    fp0 = fingerprint(spec)
    orig = synth.GENERATORS["higgs_like"]

    def patched_higgs(key, n=8000, d=28, lo=-4.0, hi=3.0):
        return orig(key, n=n, d=d, lo=lo, hi=hi)

    try:
        synth.register_generator("higgs_like")(patched_higgs)
        assert fingerprint(spec) != fp0
    finally:
        synth.register_generator("higgs_like")(orig)
    assert fingerprint(spec) == fp0


# ---------------------------------------------------------------------------
# acceptance: new problem + new dataset variant, zero engine edits
# ---------------------------------------------------------------------------

def test_new_problem_and_dataset_full_pipeline(tmp_path):
    """Ridge & hinge on the label-noise / heavy-tailed variants run the
    full m-grid sweep, epsilon/cost readout, predictor, and cache purely
    via registry names."""
    spec = SweepSpec(
        name="proto_accept", ms=(1, 2, 4), iters=60, eval_every=20,
        datasets={
            "noisy": DatasetSpec("label_noise",
                                 {"base": "higgs_like", "flip_frac": 0.1,
                                  "n": 120, "d": 8}),
            "heavy": DatasetSpec("heavy_tailed", {"n": 120, "d": 8}),
        },
        jobs=(JobSpec("minibatch", "noisy", {"gamma": 0.05},
                      problem="hinge", predict=True),
              JobSpec("dadm", "heavy", problem="ridge"),
              JobSpec("hogwild", "heavy", {"gamma": 0.01},
                      problem="ridge"))).validate()
    res = run_sweep(spec, cache_dir=str(tmp_path))
    assert set(res["jobs"]) == {"minibatch+hinge/noisy", "dadm+ridge/heavy",
                                "hogwild+ridge/heavy"}
    for name, jr in res["jobs"].items():
        assert jr["problem"] in ("hinge", "ridge")
        assert np.isfinite(jr["losses"]).all()
        assert len(jr["losses"]) == 3
    assert res["jobs"]["minibatch+hinge/noisy"]["predicted"][
        "predicted_m_max"] >= 1
    # every dataset self-reports its measured characters
    for info in res["datasets"].values():
        ch = info["characters"]
        assert {"mean_feature_variance", "sparsity", "diversity",
                "csim_async", "csim_sync"} <= set(ch)
    # second run is a cache hit under the registry-aware fingerprint
    res2 = run_sweep(spec, cache_dir=str(tmp_path))
    assert res2["cache"]["hit"] is True


def test_cli_lists_registries(capsys):
    """--list enumerates the live registries, so any registered algorithm,
    problem, generator, or named spec shows up with zero CLI edits."""
    from repro.experiments.registry import SPEC_IDS

    assert cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in (list(alg_base.ALGORITHMS) + list(problems_mod.PROBLEMS)
                 + ["label_noise", "heavy_tailed"] + list(SPEC_IDS)):
        assert name in out


def test_cli_problem_selection(tmp_path, capsys):
    rc = cli.main(["--spec", "diversity", "--quick", "--iters", "48",
                   "--n", "120", "--problem", "hinge",
                   "--cache-dir", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "+hinge/" in out
    with pytest.raises(KeyError):
        cli.main(["--spec", "diversity", "--quick", "--problem", "astrology"])


# ---------------------------------------------------------------------------
# satellite: the m-naming shim on the legacy minibatch entry point
# ---------------------------------------------------------------------------

def test_run_minibatch_batch_size_shim(split):
    tr, te = split
    with pytest.warns(DeprecationWarning, match="batch_size"):
        old = run_minibatch(tr, te, batch_size=3, iters=40, eval_every=20)
    new = run_minibatch(tr, te, m=3, iters=40, eval_every=20)
    np.testing.assert_array_equal(np.asarray(old["losses"]),
                                  np.asarray(new["losses"]))
    assert old["m"] == new["m"] == 3
    with pytest.warns(DeprecationWarning):
        with pytest.raises(TypeError):
            run_minibatch(tr, te, m=2, batch_size=3, iters=40, eval_every=20)
