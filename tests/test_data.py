"""Synthetic dataset generators: Table I characters + hypothesis properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import metrics as MX
from repro.data import synth
from repro.data.lm import LMConfig, hmm_stream, token_characters

KEY = jax.random.PRNGKey(0)


def test_ruler_labels():
    """label = sign(xi . ruler), ruler = (-1, 2, -3, ...)."""
    r = synth.ruler(4)
    np.testing.assert_array_equal(np.asarray(r), [-1.0, 2.0, -3.0, 4.0])
    X = jnp.array([[1.0, 0, 0, 0], [0, 1.0, 0, 0]])
    y = synth.label_with_ruler(X)
    np.testing.assert_array_equal(np.asarray(y), [-1.0, 1.0])


def test_realsim_like_characters():
    ds = synth.make_realsim_like(KEY, n=1000, d=500, density=0.03)
    assert abs(MX.density(ds.X) - 0.03) < 0.005          # Table I: <3%
    assert float(ds.X.min()) >= 0.0 and float(ds.X.max()) <= 1.0
    assert set(np.unique(np.asarray(ds.y))) <= {-1.0, 1.0}


def test_higgs_like_characters():
    ds = synth.make_higgs_like(KEY, n=1000)
    assert ds.X.shape[1] == 28                            # Table I
    assert float(ds.X.min()) >= -4.0 and float(ds.X.max()) <= 3.0
    assert MX.density(ds.X) == pytest.approx(1.0)


def test_split_fractions():
    ds = synth.make_higgs_like(KEY, n=1000)
    tr, va = ds.split(key=KEY)
    assert tr.X.shape[0] == 700 and va.X.shape[0] == 200  # paper: 70/20


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([0.1, 0.3, 0.6, 0.9]))
def test_ls_mutation_monotone(frac):
    """C_sim grows monotonically with the mutation fraction."""
    a = synth.make_ls_sequence(KEY, n=200, d=40, mutate_frac=frac)
    b = synth.make_ls_sequence(KEY, n=200, d=40, mutate_frac=min(1.0, frac + 0.3) if frac < 0.7 else frac)
    ca, cb = MX.csim_ref(a.X, 4), MX.csim_ref(b.X, 4)
    if frac < 0.7:
        assert ca < cb + 1e-6


def test_ls_sparse_keeps_density():
    ds = synth.make_ls_sequence(KEY, n=300, d=100, mutate_frac=0.1,
                                density=0.05, lo=0, hi=1)
    assert MX.density(ds.X) < 0.12


def test_hmm_stream_learnable_and_shaped():
    cfg = LMConfig(vocab_size=512, seq_len=32, batch_size=4)
    batches = list(hmm_stream(KEY, cfg, 3))
    assert len(batches) == 3
    b = batches[0]
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    assert int(b["tokens"].max()) < 512
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
    ch = token_characters(b["tokens"])
    assert 0 < ch["sequence_diversity"] <= 1.0
