"""Synthetic dataset generators: Table I characters + hypothesis properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import metrics as MX
from repro.data import synth
from repro.data.lm import LMConfig, hmm_stream, token_characters

KEY = jax.random.PRNGKey(0)


def test_ruler_labels():
    """label = sign(xi . ruler), ruler = (-1, 2, -3, ...)."""
    r = synth.ruler(4)
    np.testing.assert_array_equal(np.asarray(r), [-1.0, 2.0, -3.0, 4.0])
    X = jnp.array([[1.0, 0, 0, 0], [0, 1.0, 0, 0]])
    y = synth.label_with_ruler(X)
    np.testing.assert_array_equal(np.asarray(y), [-1.0, 1.0])


def test_realsim_like_characters():
    ds = synth.make_realsim_like(KEY, n=1000, d=500, density=0.03)
    assert abs(MX.density(ds.X) - 0.03) < 0.005          # Table I: <3%
    assert float(ds.X.min()) >= 0.0 and float(ds.X.max()) <= 1.0
    assert set(np.unique(np.asarray(ds.y))) <= {-1.0, 1.0}


def test_higgs_like_characters():
    ds = synth.make_higgs_like(KEY, n=1000)
    assert ds.X.shape[1] == 28                            # Table I
    assert float(ds.X.min()) >= -4.0 and float(ds.X.max()) <= 3.0
    assert MX.density(ds.X) == pytest.approx(1.0)


def test_split_fractions():
    ds = synth.make_higgs_like(KEY, n=1000)
    tr, va = ds.split(key=KEY)
    assert tr.X.shape[0] == 700 and va.X.shape[0] == 200  # paper: 70/20


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([0.1, 0.3, 0.6, 0.9]))
def test_ls_mutation_monotone(frac):
    """C_sim grows monotonically with the mutation fraction."""
    a = synth.make_ls_sequence(KEY, n=200, d=40, mutate_frac=frac)
    b = synth.make_ls_sequence(KEY, n=200, d=40, mutate_frac=min(1.0, frac + 0.3) if frac < 0.7 else frac)
    ca, cb = MX.csim_ref(a.X, 4), MX.csim_ref(b.X, 4)
    if frac < 0.7:
        assert ca < cb + 1e-6


def test_ls_sparse_keeps_density():
    ds = synth.make_ls_sequence(KEY, n=300, d=100, mutate_frac=0.1,
                                density=0.05, lo=0, hi=1)
    assert MX.density(ds.X) < 0.12


def test_hmm_stream_learnable_and_shaped():
    cfg = LMConfig(vocab_size=512, seq_len=32, batch_size=4)
    batches = list(hmm_stream(KEY, cfg, 3))
    assert len(batches) == 3
    b = batches[0]
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    assert int(b["tokens"].max()) < 512
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
    ch = token_characters(b["tokens"])
    assert 0 < ch["sequence_diversity"] <= 1.0


# ---------------------------------------------------------------------------
# Dataset.split v2: documented fractions + exposed held-out test slice
# ---------------------------------------------------------------------------

def test_split_exposes_test_slice():
    """The 10% tail is a real held-out test set, not silently discarded."""
    ds = synth.make_higgs_like(KEY, n=1000)
    tr, va, te = ds.split(key=KEY, with_test=True)
    assert tr.X.shape[0] == 700 and va.X.shape[0] == 200
    assert te.X.shape[0] == 100 and te.name.endswith(":test")
    # the three slices partition the dataset: no row lost, no row reused
    stacked = np.concatenate([np.asarray(s.X) for s in (tr, va, te)])
    assert stacked.shape[0] == 1000
    assert np.unique(stacked, axis=0).shape[0] == \
        np.unique(np.asarray(ds.X), axis=0).shape[0]


def test_split_without_key_keeps_row_order():
    """key=None is the documented no-shuffle mode (sampling-sequence
    datasets depend on row order) — slices must be contiguous prefixes."""
    ds = synth.make_higgs_like(KEY, n=200, d=6)
    tr, va, te = ds.split(with_test=True)
    np.testing.assert_array_equal(np.asarray(tr.X), np.asarray(ds.X[:140]))
    np.testing.assert_array_equal(np.asarray(va.X),
                                  np.asarray(ds.X[140:180]))
    np.testing.assert_array_equal(np.asarray(te.X), np.asarray(ds.X[180:]))


def test_split_rejects_bad_fractions():
    ds = synth.make_higgs_like(KEY, n=100, d=4)
    with pytest.raises(ValueError):
        ds.split(train_frac=0.8, valid_frac=0.3)   # sums past 1
    with pytest.raises(ValueError):
        ds.split(train_frac=0.0)
    with pytest.raises(ValueError):
        ds.split(train_frac=0.7, valid_frac=-0.1)


# ---------------------------------------------------------------------------
# new registered dataset-character generators
# ---------------------------------------------------------------------------

def test_label_noise_flips_only_labels():
    ds = synth.make_label_noise(KEY, base="higgs_like", flip_frac=0.25,
                                n=2000, d=8)
    kb, _ = jax.random.split(KEY)
    base = synth.make_higgs_like(kb, n=2000, d=8)
    np.testing.assert_array_equal(np.asarray(ds.X), np.asarray(base.X))
    flipped = float(np.mean(np.asarray(ds.y) != np.asarray(base.y)))
    assert abs(flipped - 0.25) < 0.05
    assert set(np.unique(np.asarray(ds.y))) <= {-1.0, 1.0}


def test_label_noise_rejects_unknown_base():
    with pytest.raises(KeyError):
        synth.make_label_noise(KEY, base="mnist")


def test_heavy_tailed_has_heavier_tails_than_uniform():
    ds = synth.make_heavy_tailed(KEY, n=2000, d=10, df=3.0)
    X = np.asarray(ds.X)
    assert np.isfinite(X).all()
    # excess kurtosis blows past any bounded-support distribution's
    z = (X - X.mean()) / X.std()
    assert float((z ** 4).mean()) > 5.0
    assert MX.mean_feature_variance(ds.X) > 0.0


def test_generator_registry_is_the_spec_surface():
    for name in ("higgs_like", "realsim_like", "ls_sequence", "upper_bound",
                 "one_sample", "label_noise", "heavy_tailed",
                 "character_knob"):
        assert name in synth.GENERATORS
    assert synth.get_generator("higgs_like") is synth.make_higgs_like


def test_character_knob_maps_knobs_to_characters():
    """Each knob hits exactly its §IV character: variance -> measured
    feature variance, density -> 1 - sparsity, duplication ->
    diversity_ratio (the character_surface spec depends on this)."""
    for target in (0.25, 1.0, 4.0):
        ds = synth.make_character_knob(KEY, n=3000, d=32, variance=target)
        assert MX.mean_feature_variance(ds.X) == pytest.approx(target,
                                                               rel=0.1)
    ds = synth.make_character_knob(KEY, n=2000, d=32, density=0.3)
    assert MX.sparsity(ds.X) == pytest.approx(0.7, abs=0.03)
    # the knobs are independent: the density mask must NOT deflate the
    # measured variance (the span compensates by 1/sqrt(density))
    assert MX.mean_feature_variance(ds.X) == pytest.approx(1.0, rel=0.1)
    ds = synth.make_character_knob(KEY, n=1000, d=32, duplication=0.75)
    assert MX.diversity_ratio(ds.X) == pytest.approx(0.25, abs=0.01)
    # duplicated rows are literal copies of the retained head
    X = np.asarray(ds.X)
    np.testing.assert_array_equal(X[250:500], X[:250])
    with pytest.raises(ValueError):
        synth.make_character_knob(KEY, duplication=1.0)
    with pytest.raises(ValueError):
        synth.make_character_knob(KEY, density=0.0)
