"""repro.telemetry: span schema/nesting, Perfetto export, registry
thread-safety, legacy-counter parity, the flight recorder, strict
Prometheus-text conformance, and the observational contract (artifact
bytes identical with telemetry on or off)."""

import json
import threading

import numpy as np
import pytest

import jax

from repro.data import synth
from repro.experiments import cache as artifact_cache
from repro.experiments import engine
from repro.experiments import runner
from repro.experiments import run as run_cli
from repro.experiments import spec as spec_mod
from repro.experiments.spec import (DatasetSpec, EpsilonSpec, JobSpec,
                                    SweepSpec)
from repro.service.api import AdvisorService, ProbeRequest
from repro.service.queue import AdmissionQueue
from repro.telemetry import RECORDER, MetricsRegistry, metrics, trace
from repro.telemetry import __main__ as telemetry_cli
from repro.telemetry.metrics import parse_prometheus_text
from repro.telemetry.recorder import FlightRecorder

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test starts and ends with tracing disabled — a leaked active
    tracer would silently put every later test on the traced path."""
    trace.stop()
    yield
    trace.stop()


def tiny_spec(name, **kw):
    kw.setdefault("ms", (1, 2))
    kw.setdefault("iters", 40)
    kw.setdefault("eval_every", 20)
    kw.setdefault("datasets",
                  {"d0": DatasetSpec("higgs_like", {"n": 96, "d": 8})})
    kw.setdefault("jobs", (JobSpec("minibatch", "d0"),))
    return SweepSpec(name=name, **kw).validate()


# ---------------------------------------------------------------------------
# span tracer: schema, nesting, export
# ---------------------------------------------------------------------------

def test_span_schema_nesting_and_export(tmp_path):
    """Spans export as Chrome-trace "X" events with the required keys;
    children are contained in their parent's interval and carry depth."""
    trace.start()
    with trace.span("sweep", spec="demo"):
        with trace.span("bucket", m_pad=4):
            with trace.span("compile"):
                pass
            with trace.span("execute"):
                pass
        with trace.span("store"):
            pass
    trace.stop()
    path = trace.export(str(tmp_path / "t.json"))
    payload = json.load(open(path))          # Perfetto-loadable JSON object
    evs = payload["traceEvents"]
    assert len(evs) == 5
    for e in evs:
        for k in ("name", "ph", "ts", "dur", "pid", "tid"):
            assert k in e, (k, e)
        assert e["ph"] == "X"
    by = {e["name"]: e for e in evs}
    assert by["sweep"]["args"]["depth"] == 0
    assert by["bucket"]["args"]["depth"] == 1
    assert by["compile"]["args"]["depth"] == 2
    assert by["bucket"]["args"]["m_pad"] == 4
    # containment: child interval inside parent interval
    for child, parent in (("bucket", "sweep"), ("compile", "bucket"),
                          ("execute", "bucket"), ("store", "sweep")):
        c, p = by[child], by[parent]
        assert c["ts"] >= p["ts"] - 1e-6
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-6
    # the CLI validator accepts it and scopes to the sweep root
    s = telemetry_cli.summarize(path, root="sweep")
    assert s["n_events"] == 5
    assert s["last_sweep"]["root"] == "sweep"
    assert set(s["last_sweep"]["phases"]) == {"bucket", "compile",
                                             "execute", "store"}


def test_disabled_spans_are_shared_noops():
    """With no tracer installed, span() returns one shared no-op object:
    nothing is allocated or recorded on the disabled hot path."""
    assert trace.active() is None and not trace.enabled()
    s1, s2 = trace.span("a", x=1), trace.span("b")
    assert s1 is s2
    with s1 as s:
        s.set(anything=True)


def test_spans_nest_per_thread():
    """Concurrent threads carry independent span stacks (contextvars):
    each thread's spans sit at depth 0/1 on its own tid."""
    trace.start()

    def work(i):
        with trace.span("outer", thread=i):
            with trace.span("inner", thread=i):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tracer = trace.stop()
    evs = tracer.events
    assert len(evs) == 8
    for i in range(4):
        mine = [e for e in evs if e["args"]["thread"] == i]
        assert sorted(e["args"]["depth"] for e in mine) == [0, 1]
        assert len({e["tid"] for e in mine}) == 1


def test_phase_breakdown_coverage_math():
    """Union coverage merges overlaps; the root's own span is excluded
    from the phase table."""
    mk = lambda name, ts, dur, depth: {
        "name": name, "ph": "X", "ts": ts, "dur": dur, "pid": 1, "tid": 1,
        "args": {"depth": depth}}
    evs = [mk("sweep", 0.0, 100.0, 0),
           mk("job", 0.0, 60.0, 1), mk("job", 50.0, 40.0, 1)]
    bd = trace.phase_breakdown(evs, root="sweep")
    assert bd["root"] == "sweep"
    assert bd["coverage"] == pytest.approx(0.9)      # [0,60)+[50,90) = 90
    assert set(bd["phases"]) == {"job"}
    assert bd["phases"]["job"]["count"] == 2
    # without a root: depth-0 coverage over the trace wall
    bd0 = trace.phase_breakdown(evs)
    assert bd0["coverage"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_exact_under_threads():
    """6 threads x 2000 increments land exactly 12000 — the locked
    registry fixes the legacy racy `+= 1` module globals."""
    reg = MetricsRegistry()
    c = reg.counter("race_total")

    def hammer():
        for _ in range(2000):
            c.inc()

    threads = [threading.Thread(target=hammer) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 12000


def test_registry_kinds_labels_and_exposition():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", help="requests")
    c.inc(3)
    assert reg.counter("reqs_total") is c            # get-or-create
    with pytest.raises(TypeError):                   # kind clash
        reg.gauge("reqs_total")
    with pytest.raises(ValueError):                  # counters are monotone
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(5); g.set_max(3)
    assert g.value == 5
    g.set_max(9)
    assert g.value == 9
    h = reg.histogram("lat_seconds", buckets=(0.01, 0.1, 1.0),
                      labels={"tier": "analytic"})
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == {"0.01": 1, "0.1": 2, "1.0": 3}  # cumulative
    assert snap["+inf"] == 4 and snap["count"] == 4
    txt = reg.render_prometheus()
    assert "# TYPE reqs_total counter" in txt
    assert "reqs_total 3" in txt
    assert 'lat_seconds_bucket{le="0.1",tier="analytic"} 2' in txt
    assert 'lat_seconds_count{tier="analytic"} 4' in txt
    d = reg.to_dict(prefix="reqs")
    assert d == {"reqs_total": 3}


# ---------------------------------------------------------------------------
# Prometheus text-format conformance (the strict parser is the oracle)
# ---------------------------------------------------------------------------

def test_render_prometheus_roundtrips_through_strict_parser():
    """What render_prometheus emits, a conformant scraper can read back:
    TYPE/HELP headers per family, escaped label values round-trip, and
    histogram families satisfy the cumulative/+Inf/_sum/_count
    invariants."""
    reg = MetricsRegistry()
    c = reg.counter("jobs_total", help='finished jobs ("stored")',
                    labels={"status": 'we"ird\\path\nx'})
    c.inc(7)
    reg.gauge("depth_now", help="current depth").set(2.5)
    h = reg.histogram("lat_seconds", help="latency",
                      buckets=(0.01, 0.1, 1.0), labels={"tier": "a"})
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.render_prometheus()
    fams = parse_prometheus_text(text)
    assert fams["jobs_total"]["type"] == "counter"
    assert fams["jobs_total"]["help"].startswith("finished jobs")
    name, labels, value = fams["jobs_total"]["samples"][0]
    assert labels == {"status": 'we"ird\\path\nx'}    # escaping round-trips
    assert value == 7
    assert fams["depth_now"]["samples"][0][2] == 2.5
    hist = fams["lat_seconds"]
    assert hist["type"] == "histogram"
    by_name = {}
    for n, ls, v in hist["samples"]:
        by_name.setdefault(n, []).append((ls, v))
    assert [v for ls, v in by_name["lat_seconds_bucket"]] == [1, 2, 3, 4]
    assert by_name["lat_seconds_bucket"][-1][0]["le"] == "+Inf"
    assert by_name["lat_seconds_count"][0][1] == 4
    assert by_name["lat_seconds_sum"][0][1] == pytest.approx(5.555)


def test_metric_and_label_name_validation():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("bad name")
    with pytest.raises(ValueError):
        reg.counter("2starts_with_digit")
    with pytest.raises(ValueError):
        reg.counter("ok_total", labels={"bad-label": "v"})
    reg.counter("rule:recorded_total")          # colons are legal in names


@pytest.mark.parametrize("bad, msg", [
    ("x_total 3", "newline"),                              # no trailing \n
    ("orphan_metric 1\n", "no preceding # TYPE"),
    ("# TYPE a counter\na 1\n# TYPE a counter\n", "duplicate TYPE"),
    ("# TYPE a counter\na -2\n", "negative"),
    ("# TYPE a wat\n", "unknown type"),
    ("# TYPE a counter\na{l=\"v\" 1\n", "malformed"),
    # histogram invariants
    ("# TYPE h histogram\n"
     'h_bucket{le="1.0"} 2\nh_bucket{le="+Inf"} 3\nh_sum 1\n',
     "missing _sum or _count"),
    ("# TYPE h histogram\n"
     'h_bucket{le="1.0"} 5\nh_bucket{le="+Inf"} 3\nh_sum 1\nh_count 3\n',
     "not cumulative"),
    ("# TYPE h histogram\n"
     'h_bucket{le="1.0"} 2\nh_sum 1\nh_count 2\n', r"\+Inf"),
    ("# TYPE h histogram\n"
     'h_bucket{le="1.0"} 2\nh_bucket{le="+Inf"} 3\nh_sum 1\nh_count 9\n',
     "!= _count"),
])
def test_parser_rejects_nonconformant_text(bad, msg):
    with pytest.raises(ValueError, match=msg):
        parse_prometheus_text(bad)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_recorder_ring_seq_and_cursor():
    rec = FlightRecorder(max_events=4, max_spans=2)
    for i in range(7):
        rec.publish("probe", i=i)
    snap = rec.snapshot()
    assert snap["seq"] == 7 and snap["published"] == 7
    # bounded ring: only the newest 4 events are held, oldest first
    assert [e["i"] for e in snap["events"]] == [3, 4, 5, 6]
    # cursor: only events strictly newer than `since`
    tail = rec.snapshot(since=5)
    assert [e["i"] for e in tail["events"]] == [5, 6]
    # limit keeps the newest
    lim = rec.snapshot(limit=2)
    assert [e["i"] for e in lim["events"]] == [5, 6]
    rec.clear()
    assert rec.snapshot()["events"] == []
    rec.publish("after_clear")
    assert rec.snapshot()["seq"] == 8        # seq never replays


def test_recorder_mirrors_spans_only_while_tracing():
    """The span sink feeds RECORDER only while a tracer is installed —
    with tracing off the span ring stays untouched."""
    seq0 = RECORDER.snapshot()["seq"]
    with trace.span("untraced"):
        pass
    assert RECORDER.snapshot(since=seq0)["spans"] == []
    trace.start()
    with trace.span("traced_probe", x=1):
        pass
    trace.stop()
    spans = RECORDER.snapshot(since=seq0)["spans"]
    assert [s["name"] for s in spans] == ["traced_probe"]
    assert spans[0]["args"]["x"] == 1


def test_run_sweep_publishes_flight_events(tmp_path):
    """A computed sweep leaves its progress trail in the recorder:
    sweep_started -> job_started -> job_stored (per job) -> sweep_stored,
    plus the engine's grid pad-waste event; a cache hit publishes
    nothing."""
    spec = tiny_spec("tel_flight", jobs=(JobSpec("minibatch", "d0"),
                                         JobSpec("hogwild", "d0")))
    seq0 = RECORDER.snapshot()["seq"]
    runner.run_sweep(spec, cache_dir=str(tmp_path / "c"))
    evs = RECORDER.snapshot(since=seq0)["events"]
    kinds = [e["kind"] for e in evs]
    assert kinds[0] == "sweep_started"
    assert kinds[-1] == "sweep_stored"
    assert kinds.count("job_started") == 2
    assert kinds.count("job_stored") == 2
    assert "grid" in kinds
    started = next(e for e in evs if e["kind"] == "sweep_started")
    assert started["sweep"] == "tel_flight" and started["jobs"] == 2
    stored = [e for e in evs if e["kind"] == "job_stored"]
    assert {e["job"] for e in stored} == \
        {"minibatch:d0", "hogwild:d0"} or all("job" in e for e in stored)
    assert all(e["status"] == "ok" and e["healthy"] for e in stored)
    # cache hit: nothing executes, nothing is published
    seq1 = RECORDER.snapshot()["seq"]
    runner.run_sweep(spec, cache_dir=str(tmp_path / "c"))
    assert RECORDER.snapshot(since=seq1)["events"] == []


def test_race_publishes_psum_event():
    from repro.distributed import hogwild_shards

    ds = synth.make_higgs_like(KEY, n=96, d=8)
    tr, te = ds.split(key=KEY)
    seq0 = RECORDER.snapshot()["seq"]
    r = hogwild_shards.run_hogwild_sharded(tr, te, m=4, iters=80,
                                           gamma=0.05, eval_every=40)
    races = [e for e in RECORDER.snapshot(since=seq0)["events"]
             if e["kind"] == "race"]
    assert len(races) == 1
    assert races[0]["psum_rounds"] == r["psum_rounds"]
    assert races[0]["m"] == 4 and races[0]["faulted"] is False


# ---------------------------------------------------------------------------
# legacy counter parity (engine.JIT_CALLS / runner.SWEEP_COMPUTES aliases)
# ---------------------------------------------------------------------------

def test_jit_calls_alias_counts_cold_vs_cached(tmp_path):
    """The registry-backed engine.JIT_CALLS counts exactly what the
    legacy global did: one compile per bucket on a cold sweep, zero on a
    cache hit — traced or not."""
    spec = tiny_spec("tel_parity", ms=(1, 2, 4, 8))   # 2 buckets @ ratio 2
    cd = str(tmp_path / "cache")

    j0, s0 = engine.JIT_CALLS, runner.SWEEP_COMPUTES
    runner.run_sweep(spec, cache_dir=cd)
    assert engine.JIT_CALLS - j0 == 2
    assert runner.SWEEP_COMPUTES - s0 == 1

    # cache hit: nothing executes, neither counter moves
    j0, s0 = engine.JIT_CALLS, runner.SWEEP_COMPUTES
    runner.run_sweep(spec, cache_dir=cd)
    assert engine.JIT_CALLS - j0 == 0
    assert runner.SWEEP_COMPUTES - s0 == 0

    # tracing ON changes neither count (dispatch AOT is still one
    # wrapper, counted at jit-wrap time)
    trace.start()
    j0 = engine.JIT_CALLS
    runner.run_sweep(spec, cache_dir=str(tmp_path / "cache2"))
    trace.stop()
    assert engine.JIT_CALLS - j0 == 2


def test_module_getattr_raises_for_unknown():
    with pytest.raises(AttributeError):
        engine.NO_SUCH_COUNTER
    with pytest.raises(AttributeError):
        runner.NO_SUCH_COUNTER


# ---------------------------------------------------------------------------
# the observational contract
# ---------------------------------------------------------------------------

def test_artifact_bytes_identical_with_tracing(tmp_path):
    """Acceptance: artifacts are byte-identical with telemetry on vs off
    — the AOT lower/compile/execute split produces the same executable
    from the same lowering, and no telemetry state enters the payload."""
    spec = tiny_spec("tel_bytes", ms=(1, 2, 4),
                     epsilon=EpsilonSpec(probe_m=2))
    fp = spec_mod.fingerprint(spec)

    runner.run_sweep(spec, cache_dir=str(tmp_path / "off"))
    trace.start()
    runner.run_sweep(spec, cache_dir=str(tmp_path / "on"))
    trace.stop()

    raw_off = open(artifact_cache.artifact_path(
        str(tmp_path / "off"), spec.name, fp), "rb").read()
    raw_on = open(artifact_cache.artifact_path(
        str(tmp_path / "on"), spec.name, fp), "rb").read()
    assert raw_on == raw_off


def test_trace_covers_sweep_with_bucket_split(tmp_path):
    """Acceptance: a traced sweep's root span attributes >=95% of the
    traced wall-clock, with per-bucket compile/execute children."""
    spec = tiny_spec("tel_cov", ms=(1, 2, 4))
    trace.start()
    runner.run_sweep(spec, cache_dir=str(tmp_path / "c"))
    trace.stop()
    path = trace.export(str(tmp_path / "trace.json"))
    evs = json.load(open(path))["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"sweep", "job", "grid", "bucket", "lower", "compile",
            "execute", "store"} <= names
    overall = trace.phase_breakdown(evs)
    assert overall["coverage"] >= 0.95
    scoped = trace.phase_breakdown(evs, root="sweep")
    assert scoped["root"] == "sweep"
    # every bucket span has compile+execute children inside its interval
    buckets = [e for e in evs if e["name"] == "bucket"]
    assert buckets
    for b in buckets:
        inside = [e["name"] for e in evs
                  if e["ts"] >= b["ts"] - 1e-6
                  and e["ts"] + e["dur"] <= b["ts"] + b["dur"] + 1e-6
                  and e["args"]["depth"] == b["args"]["depth"] + 1]
        assert "compile" in inside and "execute" in inside


def test_sequential_path_identical_traced(tmp_path):
    """use_vmap=False (repeated jit calls) takes the plain-span path —
    same losses traced or not, and no per-call recompiles."""
    ds = synth.make_higgs_like(KEY, n=96, d=8)
    tr, te = ds.split(key=KEY)
    kw = dict(iters=40, eval_every=20, use_vmap=False)
    j0 = engine.JIT_CALLS
    r_off = engine.sweep("minibatch", tr, te, [1, 2, 4], **kw)
    assert engine.JIT_CALLS - j0 == 1      # one jit serves every m
    trace.start()
    j0 = engine.JIT_CALLS
    r_on = engine.sweep("minibatch", tr, te, [1, 2, 4], **kw)
    tracer = trace.stop()
    assert engine.JIT_CALLS - j0 == 1
    np.testing.assert_array_equal(np.asarray(r_off["losses"]),
                                  np.asarray(r_on["losses"]))
    assert sum(e["name"] == "grid_member" for e in tracer.events) == 3


# ---------------------------------------------------------------------------
# instrumented subsystems
# ---------------------------------------------------------------------------

def test_queue_high_water_and_shed():
    q = AdmissionQueue(depth=3)
    assert q.try_admit() and q.try_admit()
    assert q.stats()["high_water"] == 2
    q.release()
    assert q.try_admit()                    # back to 2 in service
    assert q.stats()["high_water"] == 2     # high water holds the max
    assert q.try_admit()                    # 3/3
    assert not q.try_admit()                # shed
    st = q.stats()
    assert st == {"depth": 3, "in_service": 3, "admitted": 4, "shed": 1,
                  "high_water": 3}
    for _ in range(3):
        q.release()
    assert q.stats()["in_service"] == 0
    assert q.stats()["high_water"] == 3


def test_queue_wait_histogram_and_stats_reset():
    """try_admit() returns the admission stamp; handing it back through
    release(admitted_at=...) observes repro_service_queue_wait_seconds,
    and stats(reset=True) re-arms high_water to current occupancy so
    scrapers see per-window peaks instead of lifetime ones."""
    h = metrics.REGISTRY.histogram("repro_service_queue_wait_seconds")
    n0 = h.count
    q = AdmissionQueue(depth=2)
    stamp = q.try_admit()
    assert isinstance(stamp, float)
    q.release(admitted_at=stamp)
    assert h.count - n0 == 1
    # release without a stamp (legacy callers) must not observe
    assert q.try_admit()
    q.release()
    assert h.count - n0 == 1

    # windowed high-water: two in service, one released -> lifetime peak 2
    s1 = q.try_admit()
    s2 = q.try_admit()
    q.release(admitted_at=s2)
    st = q.stats(reset=True)
    assert st["high_water"] == 2            # pre-reset view is returned
    assert q.stats()["high_water"] == 1     # re-armed to current occupancy
    q.release(admitted_at=s1)
    assert h.count - n0 == 3


def test_psum_round_accounting():
    """Racing-mode comm accounting: psum_rounds = scheduled syncs
    (R_total // sync_every) + one forced reconcile per eval block."""
    from repro.distributed import hogwild_shards

    ds = synth.make_higgs_like(KEY, n=96, d=8)
    tr, te = ds.split(key=KEY)
    kw = dict(m=4, iters=240, gamma=0.05, eval_every=40)
    # n_evals=6, rounds_per_eval=10, R_total=60
    c0 = metrics.REGISTRY.counter(
        "repro_distributed_psum_rounds_total").value
    r1 = hogwild_shards.run_hogwild_sharded(tr, te, sync_every=1, **kw)
    assert r1["psum_rounds"] == 60 + 6
    r4 = hogwild_shards.run_hogwild_sharded(tr, te, sync_every=4, **kw)
    assert r4["psum_rounds"] == 15 + 6
    delta = metrics.REGISTRY.counter(
        "repro_distributed_psum_rounds_total").value - c0
    assert delta == 66 + 21
    # the compile-counter alias works here too
    assert isinstance(hogwild_shards.JIT_CALLS, int)


def test_service_stats_telemetry_block(tmp_path):
    """AdvisorService.stats() carries the registry-backed telemetry
    block: queue gauges/counters and the tier latency + confidence
    histograms observed by probe_batch."""
    svc = AdvisorService(cache_dir=str(tmp_path / "cache"), sweep_iters=50,
                         sweep_eval_every=10, n_slots=4)
    lat = metrics.REGISTRY.histogram("repro_service_tier_latency_seconds",
                                     labels={"tier": "analytic"})
    n0 = lat.count
    resp = svc.probe(ProbeRequest(X=np.random.default_rng(0)
                                  .normal(size=(40, 6)),
                                  escalate=False))
    assert resp.tier == "analytic"
    assert lat.count - n0 == 1
    st = svc.stats()
    assert st["queue"]["high_water"] >= 1
    tel = st["telemetry"]
    assert any(k.startswith("repro_service_tier_latency_seconds")
               for k in tel)
    assert tel["repro_service_queue_high_water"] >= 1
    conf = metrics.REGISTRY.histogram(
        "repro_service_confidence",
        buckets=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0))
    assert conf.count >= 1


# ---------------------------------------------------------------------------
# CLI surfacing
# ---------------------------------------------------------------------------

def test_run_cli_trace_flag(tmp_path, capsys):
    """--trace writes a validating Chrome-trace JSON whose root sweep
    span clears the CI coverage gate; --metrics dumps Prometheus text."""
    out = str(tmp_path / "cli_trace.json")
    rc = run_cli.main(["--spec", "upper_bound", "--quick", "--iters", "40",
                       "--n", "96", "--cache-dir",
                       str(tmp_path / "cache"), "--trace", out,
                       "--metrics"])
    assert rc == 0
    stdout = capsys.readouterr().out
    assert "repro_sweep_computes_total" in stdout
    assert telemetry_cli.main(
        ["--summarize", out, "--min-coverage", "0.95"]) == 0
    # re-validate the payload shape end to end
    s = telemetry_cli.summarize(out, root="sweep")
    assert s["last_sweep"]["root"] == "sweep"
    assert s["overall"]["coverage"] >= 0.95


def test_telemetry_cli_rejects_bad_trace(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"name": "x", "ph": "X"}]}))
    assert telemetry_cli.main(["--summarize", str(bad)]) == 2
    assert "missing required keys" in capsys.readouterr().err
