"""HTTP transport + live observability plane (PR-10 acceptance).

The server is a thin codec over the in-process `AdvisorService` the rest
of the suite pins — these tests check the wire contract (shapes, status
codes, strict Prometheus exposition, flight-recorder tailing) and the
observational contract (artifact bytes identical with the transport and
recorder active vs absent).
"""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.experiments import cache as artifact_cache
from repro.experiments import runner
from repro.experiments import spec as spec_mod
from repro.experiments.spec import DatasetSpec, JobSpec, SweepSpec
from repro.service.api import AdvisorService
from repro.service.http import ServiceServer
from repro.telemetry import trace
from repro.telemetry.metrics import parse_prometheus_text
from repro.telemetry.recorder import RECORDER

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _clean_slate():
    trace.stop()
    RECORDER.clear()
    yield
    trace.stop()


def make_service(tmp_path, **kw):
    kw.setdefault("cache_dir", str(tmp_path / "cache"))
    kw.setdefault("sweep_iters", 50)
    kw.setdefault("sweep_eval_every", 10)
    kw.setdefault("n_slots", 4)
    return AdvisorService(**kw)


def http_get(url):
    """(status, headers, body-bytes) — HTTPError is a response here."""
    try:
        with urllib.request.urlopen(url, timeout=30) as r:
            return r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def http_post_json(url, payload):
    body = json.dumps(payload).encode()
    req = urllib.request.Request(
        url, data=body, headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=120) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# ---------------------------------------------------------------------------
# lifecycle + routing
# ---------------------------------------------------------------------------

def test_server_lifecycle_ephemeral_port(tmp_path):
    """port=0 binds an ephemeral port; the context manager serves while
    open and releases the socket on exit."""
    svc = make_service(tmp_path)
    with ServiceServer(svc) as srv:
        assert srv.port > 0
        assert srv.url == f"http://127.0.0.1:{srv.port}"
        status, _, _ = http_get(srv.url + "/healthz")
        assert status == 200
        # second start() is a no-op, not a second thread
        assert srv.start() is srv
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(srv.url + "/healthz", timeout=2)


def test_unknown_route_404_lists_routes(tmp_path):
    with ServiceServer(make_service(tmp_path)) as srv:
        status, _, body = http_get(srv.url + "/nope")
        assert status == 404
        err = json.loads(body)["error"]
        assert "/probe" in err and "/metrics" in err
        status, resp = http_post_json(srv.url + "/metrics", {})
        assert status == 404                      # GET-only route


def test_bad_json_and_unknown_fields_400(tmp_path):
    with ServiceServer(make_service(tmp_path)) as srv:
        req = urllib.request.Request(
            srv.url + "/probe", data=b"{not json",
            headers={"Content-Type": "application/json"})
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req, timeout=30)
        assert ei.value.code == 400
        assert "invalid JSON" in json.loads(ei.value.read())["error"]

        status, resp = http_post_json(srv.url + "/probe",
                                      {"X": [[1.0]], "bogus": 1})
        assert status == 400 and "bogus" in resp["error"]
        status, resp = http_post_json(
            srv.url + "/probe",
            {"dataset": {"generator": "higgs_like", "surprise": True}})
        assert status == 400 and "surprise" in resp["error"]
        status, resp = http_post_json(
            srv.url + "/probe", {"dataset": {"generator": "no_such_gen",
                                             "kwargs": {"n": 8, "d": 2}}})
        assert status == 400 and "invalid dataset spec" in resp["error"]
        status, resp = http_post_json(srv.url + "/probe_batch",
                                      {"oops": []})
        assert status == 400
        status, resp = http_post_json(srv.url + "/flight?since=xyz", {})
        assert status == 404                      # POST to a GET route
        status, _, body = http_get(srv.url + "/flight?since=xyz")
        assert status == 400


def test_metrics_only_plane_answers_503():
    """run.py --serve mode: no advisor behind the transport — probes get
    a structured 503, the observability endpoints still serve."""
    with ServiceServer(None) as srv:
        status, resp = http_post_json(srv.url + "/probe", {"X": [[1.0]]})
        assert status == 503 and "metrics-only" in resp["error"]
        status, resp = http_post_json(srv.url + "/probe_batch",
                                      {"requests": []})
        assert status == 503
        status, _, body = http_get(srv.url + "/healthz")
        health = json.loads(body)
        assert status == 200
        assert health["service"] is False and health["queue"] is None
        assert health["status"] == "ok"
        status, _, _ = http_get(srv.url + "/metrics")
        assert status == 200


# ---------------------------------------------------------------------------
# probe round-trips
# ---------------------------------------------------------------------------

def test_probe_roundtrip_analytic(tmp_path):
    svc = make_service(tmp_path)
    with ServiceServer(svc) as srv:
        X = RNG.normal(size=(40, 6)).tolist()
        status, resp = http_post_json(
            srv.url + "/probe",
            {"X": X, "algorithm": "hogwild", "request_id": "wire-1"})
        assert status == 200
        assert resp["status"] == "ok" and resp["tier"] == "analytic"
        assert resp["request_id"] == "wire-1"
        # the transport must not perturb the answer: same probe
        # in-process gives the identical integer m_max per strategy
        from repro.service.api import ProbeRequest
        direct = svc.probe(ProbeRequest(X=np.asarray(X))).to_dict()
        for strat, block in direct["report"].items():
            if isinstance(block, dict) and "predicted_m_max" in block:
                assert resp["report"][strat]["predicted_m_max"] == \
                    block["predicted_m_max"], strat


def test_probe_batch_roundtrip(tmp_path):
    with ServiceServer(make_service(tmp_path)) as srv:
        reqs = [{"X": RNG.normal(size=(30 + 5 * i, 5)).tolist(),
                 "request_id": f"b{i}"} for i in range(3)]
        status, resp = http_post_json(srv.url + "/probe_batch",
                                      {"requests": reqs})
        assert status == 200
        assert [r["request_id"] for r in resp["responses"]] == \
            ["b0", "b1", "b2"]
        assert all(r["status"] == "ok" for r in resp["responses"])


@pytest.mark.slow
def test_escalated_probe_strips_artifact_unless_full(tmp_path):
    """A measured-tier response carries the escalation readout but not
    the bulky artifact — unless the caller opts in with ?full=1."""
    svc = make_service(tmp_path, confidence_threshold=0.9)
    ds = {"generator": "higgs_like", "kwargs": {"n": 64, "d": 8}}
    with ServiceServer(svc) as srv:
        status, resp = http_post_json(srv.url + "/probe", {"dataset": ds})
        assert status == 200 and resp["tier"] == "measured"
        assert "artifact" not in resp["escalation"]
        status, full = http_post_json(srv.url + "/probe?full=1",
                                      {"dataset": ds})
        assert status == 200
        assert "artifact" in full["escalation"]     # cached second sweep


# ---------------------------------------------------------------------------
# the observability plane
# ---------------------------------------------------------------------------

def test_metrics_endpoint_is_strictly_conformant(tmp_path):
    """GET /metrics parses under the strict v0.0.4 parser, advertises
    the exposition content type, and ?prefix= filters families."""
    svc = make_service(tmp_path)
    with ServiceServer(svc) as srv:
        from repro.service.api import ProbeRequest
        svc.probe(ProbeRequest(X=RNG.normal(size=(32, 4))))
        status, headers, body = http_get(srv.url + "/metrics")
        assert status == 200
        assert headers["Content-Type"].startswith(
            "text/plain; version=0.0.4")
        families = parse_prometheus_text(body.decode())
        assert "repro_service_admitted_total" in families
        assert "repro_http_requests_total" in families
        assert families["repro_http_request_seconds"]["type"] == \
            "histogram"
        status, _, body = http_get(srv.url + "/metrics?prefix=repro_http")
        sub = parse_prometheus_text(body.decode())
        assert sub and all(f.startswith("repro_http") for f in sub)


@pytest.mark.slow
def test_metrics_scrape_during_inflight_escalated_sweep(tmp_path):
    """Acceptance: GET /metrics *while* an escalated sweep runs returns
    strictly parseable text carrying engine, cache, queue, and
    psum-round families."""
    import repro.distributed  # noqa: F401 — registers the psum counter

    svc = make_service(tmp_path, confidence_threshold=0.9)
    with ServiceServer(svc) as srv:
        done = threading.Event()

        def escalate():
            http_post_json(srv.url + "/probe", {
                "dataset": {"generator": "higgs_like",
                            "kwargs": {"n": 64, "d": 8}}})
            done.set()

        t = threading.Thread(target=escalate)
        t.start()
        mid_flight = []
        while not done.is_set():
            _, _, body = http_get(srv.url + "/metrics")
            mid_flight.append(parse_prometheus_text(body.decode()))
        t.join(timeout=120)
        assert mid_flight
        last = mid_flight[-1]
        for family in ("repro_engine_jit_compiles_total",
                       "repro_cache_misses_total",
                       "repro_sweep_computes_total",
                       "repro_service_queue_depth",
                       "repro_service_escalations_total",
                       "repro_distributed_psum_rounds_total"):
            assert family in last, family


def test_healthz_reports_queue_and_recorder(tmp_path):
    svc = make_service(tmp_path, n_slots=2)
    with ServiceServer(svc) as srv:
        status, _, body = http_get(srv.url + "/healthz")
        h = json.loads(body)
        assert status == 200 and h["status"] == "ok"
        assert h["service"] is True and h["uptime_s"] >= 0
        assert h["queue"]["depth"] == 32          # service default queue
        assert set(h["recorder"]) == {"seq", "published", "events_held",
                                      "spans_held", "max_events",
                                      "max_spans"}
        assert h["tracing"] is False


def test_flight_endpoint_tails_a_live_sweep(tmp_path):
    """GET /flight?since=N tails a sweep running in another thread: the
    poller sees sweep_started, per-job progress, and sweep_stored, in
    order, without rereading old events."""
    spec = SweepSpec(
        name="http_flight", ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 96, "d": 8})},
        jobs=(JobSpec("minibatch", "d0"),
              JobSpec("hogwild", "d0"))).validate()
    with ServiceServer(None) as srv:
        t = threading.Thread(
            target=runner.run_sweep, args=(spec,),
            kwargs={"cache_dir": str(tmp_path / "c")})
        t.start()
        seen, since = [], 0
        while t.is_alive() or not any(
                e["kind"] == "sweep_stored" for e in seen):
            _, _, body = http_get(srv.url + f"/flight?since={since}")
            snap = json.loads(body)
            seen += snap["events"]
            since = snap["seq"]
            if any(e["kind"] == "sweep_stored" for e in seen):
                break
        t.join(timeout=60)
        kinds = [e["kind"] for e in seen
                 if e.get("sweep") == "http_flight" or
                 e["kind"] in ("grid", "race")]
        assert kinds[0] == "sweep_started"
        assert kinds[-1] == "sweep_stored"
        assert kinds.count("job_started") == 2
        assert kinds.count("job_stored") == 2
        # cursor semantics: no event delivered twice
        seqs = [e["seq"] for e in seen]
        assert seqs == sorted(set(seqs))


def test_trace_endpoint_payload_and_drain(tmp_path):
    with ServiceServer(None) as srv:
        status, _, body = http_get(srv.url + "/trace")
        empty = json.loads(body)
        assert status == 200 and empty["traceEvents"] == []
        trace.start()
        with trace.span("sweep", spec="wire"):
            with trace.span("bucket"):
                pass
        status, _, body = http_get(srv.url + "/trace")
        names = [e["name"] for e in json.loads(body)["traceEvents"]]
        assert "sweep" in names and "bucket" in names
        # drain pops: the second drain starts empty
        status, _, body = http_get(srv.url + "/trace?drain=1")
        drained = json.loads(body)
        assert drained["otherData"]["drained"] is True
        assert len(drained["traceEvents"]) == 2
        status, _, body = http_get(srv.url + "/trace?drain=1")
        assert json.loads(body)["traceEvents"] == []
        trace.stop()


# ---------------------------------------------------------------------------
# the observational contract, extended to the transport
# ---------------------------------------------------------------------------

def test_artifact_bytes_identical_under_scraping(tmp_path):
    """PR-9's contract extended: a sweep run while the HTTP plane is up
    and actively scraped (metrics + flight + trace) produces artifacts
    byte-identical to a bare run."""
    spec = SweepSpec(
        name="http_bytes", ms=(1, 2), iters=40, eval_every=20,
        datasets={"d0": DatasetSpec("higgs_like", {"n": 96, "d": 8})},
        jobs=(JobSpec("minibatch", "d0"),)).validate()
    fp = spec_mod.fingerprint(spec)

    runner.run_sweep(spec, cache_dir=str(tmp_path / "off"))

    stop = threading.Event()

    def scrape(url):
        while not stop.is_set():
            http_get(url + "/metrics")
            http_get(url + "/flight")
            http_get(url + "/trace")

    trace.start()
    with ServiceServer(None) as srv:
        t = threading.Thread(target=scrape, args=(srv.url,))
        t.start()
        runner.run_sweep(spec, cache_dir=str(tmp_path / "on"))
        stop.set()
        t.join(timeout=10)
    trace.stop()

    raw_off = open(artifact_cache.artifact_path(
        str(tmp_path / "off"), spec.name, fp), "rb").read()
    raw_on = open(artifact_cache.artifact_path(
        str(tmp_path / "on"), spec.name, fp), "rb").read()
    assert raw_on == raw_off
