import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dryrun.py-only, per the project contract).  A couple of mesh tests want a
# few virtual devices — they use their own subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
